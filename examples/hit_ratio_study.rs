//! Hit-ratio study (evaluation question 1): what does approximating
//! strict LRU with the hash-table-embedded CLOCK policy cost?
//!
//! ```bash
//! cargo run --release --example hit_ratio_study
//! ```
//!
//! Replays *identical* zipfian traces against all three engines with a
//! memory budget far below the catalog size, then prints the measured
//! hit-ratios next to the analytic model (Che/LRU and FIFO bounds) when
//! the AOT artifacts are available. The paper's claim: CLOCK "does not
//! significantly impact the hit-ratio".

use fleec::cache::{build_engine, CacheConfig, ENGINES};
use fleec::runtime::{artifacts_dir, HitRatioModule, Runtime};
use fleec::workload::{driver::replay_trace, Trace, ValueSize, WorkloadSpec};

fn main() -> fleec::Result<()> {
    let mem_mb = 2usize;
    let catalog = 100_000u64;
    let value_bytes = 64usize;
    let trace_len = 300_000usize;

    // Model column is optional (requires `make artifacts`).
    let model = Runtime::new()
        .ok()
        .and_then(|rt| HitRatioModule::load(&rt, &artifacts_dir()).ok().map(|m| (rt, m)));

    println!(
        "hit-ratio study: catalog={catalog}, mem={mem_mb} MiB, {value_bytes} B values, trace={trace_len} ops\n"
    );
    println!(
        "{:>6} | {:>10} {:>10} {:>10} | {:>9} {:>9}",
        "alpha", "memcached", "memclock", "fleec", "model-LRU", "model-FIFO"
    );
    for &alpha in &[0.50, 0.70, 0.90, 0.99, 1.10, 1.30] {
        let spec = WorkloadSpec {
            catalog,
            alpha,
            read_ratio: 0.99,
            value_size: ValueSize::Fixed(value_bytes),
            seed: 7,
        };
        let trace = Trace::generate(&spec, trace_len);
        let mut measured = Vec::new();
        for engine in ENGINES {
            let cache = build_engine(engine, CacheConfig {
                mem_limit: mem_mb << 20,
                ..CacheConfig::default()
            })?;
            let (ratio, _, _) = replay_trace(cache.as_ref(), &trace);
            measured.push(ratio);
        }
        // Capacity in items ≈ budget / (value + per-item overhead).
        let capacity = ((mem_mb << 20) / (value_bytes + 88)) as f32;
        let (m_lru, m_fifo) = match &model {
            Some((_rt, m)) => {
                let est = m.run(alpha as f32, capacity)?;
                (format!("{:.4}", est.lru), format!("{:.4}", est.fifo))
            }
            None => ("n/a".into(), "n/a".into()),
        };
        println!(
            "{:>6.2} | {:>10.4} {:>10.4} {:>10.4} | {:>9} {:>9}",
            alpha, measured[0], measured[1], measured[2], m_lru, m_fifo
        );
    }
    println!("\npaper claim: CLOCK ≈ LRU hit-ratio (memclock/fleec columns ≈ memcached column)");
    Ok(())
}
