//! End-to-end driver (experiment E5): full server + protocol + clients.
//!
//! ```bash
//! cargo run --release --example serve_and_query [-- <engine> <clients> <requests>]
//! ```
//!
//! Proves all layers compose: a FLeeC engine is wrapped by the TCP server
//! and the coordinator (which loads the AOT planner artifact when
//! `make artifacts` has run); multiple protocol clients then issue a
//! batched zipfian request mix over real sockets, and the run reports
//! throughput, latency percentiles and server-side stats. Recorded in
//! EXPERIMENTS.md §E5.

use std::sync::Arc;
use std::time::Instant;

use fleec::cache::{build_engine, CacheConfig};
use fleec::client::Client;
use fleec::coordinator::{Coordinator, CoordinatorConfig};
use fleec::metrics::LatencyHistogram;
use fleec::runtime::artifacts_dir;
use fleec::server::{Server, ServerConfig};
use fleec::sync::Xoshiro256;
use fleec::workload::{encode_key, fill_value, Zipf, KEY_LEN};

fn main() -> fleec::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let engine = args.first().map(String::as_str).unwrap_or("fleec").to_string();
    let clients: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let requests: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let catalog: u64 = 20_000;
    let value_len = 64;

    // --- Server side: engine + coordinator (with planner if built) + TCP.
    let cache = build_engine(&engine, CacheConfig {
        mem_limit: 32 << 20,
        ..CacheConfig::default()
    })?;
    let planner_dir = artifacts_dir();
    let planner = planner_dir.join("planner.hlo.txt").exists().then_some(planner_dir);
    if planner.is_none() {
        eprintln!("note: artifacts missing (run `make artifacts`); coordinator uses defaults");
    }
    let _coordinator = Coordinator::start(Arc::clone(&cache), planner, CoordinatorConfig::default());
    let server = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            ..ServerConfig::default()
        },
        Arc::clone(&cache),
    )?;
    let addr = server.addr();
    println!("serving engine={engine} on {addr}; {clients} clients × {requests} requests");

    // --- Warm the cache over the wire.
    {
        let mut c = Client::connect(addr)?;
        let mut key = [0u8; KEY_LEN];
        let mut value = vec![0u8; value_len];
        for id in 0..catalog {
            fill_value(id, &mut value);
            c.set_noreply(encode_key(&mut key, id), &value)?;
        }
        // One replied op to flush the pipeline.
        c.set(b"warmup-done", b"1", 0, 0)?;
    }

    // --- Client fleet: 99% reads, zipf(0.99), measured per request.
    let start = Instant::now();
    let histogram = Arc::new(LatencyHistogram::new());
    let mut handles = Vec::new();
    for cid in 0..clients {
        let histogram = Arc::clone(&histogram);
        handles.push(std::thread::spawn(move || -> fleec::Result<(u64, u64)> {
            let mut client = Client::connect(addr)?;
            let zipf = Zipf::new(catalog, 0.99);
            let mut rng = Xoshiro256::seeded(0xE2E + cid as u64);
            let mut key = [0u8; KEY_LEN];
            let mut value = vec![0u8; value_len];
            let (mut hits, mut gets) = (0u64, 0u64);
            for _ in 0..requests {
                let id = zipf.sample(&mut rng) - 1;
                let k = encode_key(&mut key, id);
                let t0 = Instant::now();
                if rng.chance(0.99) {
                    gets += 1;
                    if client.get(k)?.is_some() {
                        hits += 1;
                    }
                } else {
                    fill_value(id, &mut value);
                    client.set(k, &value, 0, 0)?;
                }
                histogram.record(t0.elapsed().as_nanos() as u64);
            }
            Ok((hits, gets))
        }));
    }
    let (mut hits, mut gets) = (0u64, 0u64);
    for h in handles {
        let (h_, g_) = h.join().expect("client thread")?;
        hits += h_;
        gets += g_;
    }
    let elapsed = start.elapsed();
    let total = clients as u64 * requests;
    let s = histogram.summary();

    println!("\n=== end-to-end results (engine={engine}) ===");
    println!("requests        : {total}");
    println!("elapsed         : {:.2}s", elapsed.as_secs_f64());
    println!("throughput      : {:.0} req/s", total as f64 / elapsed.as_secs_f64());
    println!("hit ratio       : {:.4}", hits as f64 / gets.max(1) as f64);
    println!(
        "latency         : p50={}µs p95={}µs p99={}µs p999={}µs max={}µs",
        s.p50_ns / 1000,
        s.p95_ns / 1000,
        s.p99_ns / 1000,
        s.p999_ns / 1000,
        s.max_ns / 1000
    );

    // --- Server-side stats over the wire (protocol `stats`).
    let mut c = Client::connect(addr)?;
    println!("\nserver stats:");
    for (k, v) in c.stats()? {
        println!("  {k:<20} {v}");
    }
    assert!(hits > 0, "end-to-end path must produce hits");
    Ok(())
}
