//! Quickstart: use FLeeC as an embedded cache library.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the engine-neutral [`Cache`] API: store/lookup/CAS/counters,
//! eviction under a tight memory budget, and the stats surface. Swap
//! `"fleec"` for `"memcached"` or `"memclock"` to drive the paper's
//! baselines through the identical interface.

use fleec::cache::{build_engine, CacheConfig, StoreOutcome};

fn main() -> fleec::Result<()> {
    // A 4 MiB cache with the paper's defaults (1.5 load factor,
    // multi-bit CLOCK with max=3).
    let cache = build_engine(
        "fleec",
        CacheConfig {
            mem_limit: 4 << 20,
            ..CacheConfig::default()
        },
    )?;

    // Basic store + lookup.
    assert_eq!(cache.set(b"greeting", b"hello fleec", 0, 0), StoreOutcome::Stored);
    let hit = cache.get(b"greeting").expect("just stored");
    println!("greeting = {:?}", String::from_utf8_lossy(&hit.data));

    // Conditional stores.
    assert_eq!(cache.add(b"greeting", b"nope", 0, 0), StoreOutcome::NotStored);
    assert_eq!(cache.replace(b"greeting", b"hello again", 0, 0), StoreOutcome::Stored);

    // Optimistic concurrency with CAS tokens.
    let token = cache.get(b"greeting").unwrap().cas;
    assert_eq!(cache.cas(b"greeting", b"v2", 0, 0, token), StoreOutcome::Stored);
    assert_eq!(
        cache.cas(b"greeting", b"v3", 0, 0, token),
        StoreOutcome::Exists,
        "stale token must be rejected"
    );

    // Counters.
    cache.set(b"visits", b"0", 0, 0);
    for _ in 0..10 {
        cache.incr(b"visits", 1);
    }
    println!("visits = {:?}", cache.incr(b"visits", 0));

    // Fill past the memory budget: the embedded CLOCK policy evicts cold
    // buckets while sets keep succeeding (a cache never refuses writes).
    let value = vec![0u8; 4096];
    for i in 0..5_000u32 {
        let key = format!("bulk-{i}");
        assert_eq!(cache.set(key.as_bytes(), &value, 0, 0), StoreOutcome::Stored);
        // Keep one key hot: CLOCK should protect it.
        if i % 64 == 0 {
            cache.get(b"greeting");
        }
    }
    assert!(
        cache.get(b"greeting").is_some(),
        "hot key survived 5k evicting inserts"
    );

    let m = cache.stats().metrics;
    println!(
        "items={} buckets={} mem={}B evictions={} expansions={} hit_ratio={:.3}",
        cache.item_count(),
        cache.bucket_count(),
        cache.mem_used(),
        m.evictions,
        m.expansions,
        m.hit_ratio(),
    );
    assert!(m.evictions > 0, "the 4 MiB budget must have forced eviction");
    Ok(())
}
