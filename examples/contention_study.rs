//! Contention study (miniature Figure 1): throughput of the three
//! engines as zipfian skew (α) grows, in-process.
//!
//! ```bash
//! cargo run --release --example contention_study [-- <threads> <ops_per_thread>]
//! ```
//!
//! The paper mediates contention through access skew: higher α focuses
//! traffic on fewer keys (and their buckets/locks). This example runs a
//! scaled-down version of the Fig. 1 sweep; the full regeneration lives
//! in `cargo bench --bench fig1_throughput`.

use fleec::cache::{build_engine, CacheConfig, ENGINES};
use fleec::workload::{
    driver::StopRule, run_driver, DriverOptions, ValueSize, WorkloadSpec,
};

fn main() -> fleec::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let threads: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let ops: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100_000);

    println!("contention study: {threads} threads, {ops} ops/thread, 99% reads, 64 B values\n");
    println!(
        "{:>6} | {:>12} {:>12} {:>12} | {:>8} {:>8}",
        "alpha", "memcached/s", "memclock/s", "fleec/s", "mclk ×", "fleec ×"
    );
    for &alpha in &[0.50, 0.90, 0.99, 1.20] {
        let spec = WorkloadSpec {
            catalog: 100_000,
            alpha,
            read_ratio: 0.99,
            value_size: ValueSize::Fixed(64),
            seed: 42,
        };
        let opts = DriverOptions {
            threads,
            stop: StopRule::OpsPerThread(ops),
            prefill: true,
            sample_every: 8,
            validate: false,
            batch: 1,
        };
        let mut tputs = Vec::new();
        for engine in ENGINES {
            let cache = build_engine(engine, CacheConfig {
                mem_limit: 64 << 20,
                ..CacheConfig::default()
            })?;
            let report = run_driver(&cache, &spec, &opts);
            tputs.push(report.throughput());
        }
        println!(
            "{:>6.2} | {:>12.0} {:>12.0} {:>12.0} | {:>7.2}x {:>7.2}x",
            alpha,
            tputs[0],
            tputs[1],
            tputs[2],
            tputs[1] / tputs[0],
            tputs[2] / tputs[0],
        );
    }
    println!("\n(single-core host: see DESIGN.md §4 on how contention is simulated)");
    Ok(())
}
