//! Batch-depth sweep — the experiment behind the batched command API.
//!
//! ```bash
//! cargo bench --bench batch_pipeline
//! ```
//!
//! Sections:
//!   in-process — the workload driver issuing depth-1/4/16/64 batches
//!                through `Cache::execute_batch`, all three engines. The
//!                blocking engines run the default per-op delegation (a
//!                batch costs what its ops cost); fleec's override pins
//!                one EBR guard per batch, so its ops/s should be
//!                non-decreasing as depth grows.
//!   sharded    — the same driver over `Sharded<_>` routers, sweeping
//!                shard count 1/2/4/8 × batch depth for every engine:
//!                the batch → shard → sub-batch composition. Shards cut
//!                contention (biggest for the blocking engines, whose
//!                LRU/stripe locks stop being global), batching cuts
//!                per-op synchronization, and the two should compound.
//!   wire       — a single pipelined connection against the served fleec
//!                engine (`Client::pipeline`), measuring the end-to-end
//!                win of one `execute_batch` call per socket read.

use std::sync::Arc;
use std::time::Instant;

use fleec::cache::{build_engine, build_sharded, CacheConfig, ENGINES};
use fleec::client::{Client, PipelineReply};
use fleec::server::{Server, ServerConfig};
use fleec::workload::{driver::StopRule, run_driver, DriverOptions, ValueSize, WorkloadSpec};

const DEPTHS: [usize; 4] = [1, 4, 16, 64];

fn main() {
    let spec = WorkloadSpec {
        catalog: 50_000,
        alpha: 0.99,
        read_ratio: 0.95,
        value_size: ValueSize::Fixed(64),
        seed: 0xBA7C_4ED0,
    };

    println!("== in-process: batch depth vs throughput (threads=4) ==============");
    println!("{:>10} {:>6} {:>12} {:>8}", "engine", "batch", "ops/s", "hit");
    for engine in ENGINES {
        let mut prev = 0.0f64;
        for &depth in &DEPTHS {
            let cache = build_engine(
                engine,
                CacheConfig {
                    mem_limit: 64 << 20,
                    ..CacheConfig::default()
                },
            )
            .unwrap();
            let opts = DriverOptions {
                threads: 4,
                stop: StopRule::OpsPerThread(150_000),
                prefill: true,
                sample_every: 16,
                validate: false,
                batch: depth,
            };
            let report = run_driver(&cache, &spec, &opts);
            let tput = report.throughput();
            // Flag regressions >5% against the previous depth: fleec's
            // batched fast path should keep this column non-decreasing.
            let trend = if prev > 0.0 && tput < prev * 0.95 { "  <- dip" } else { "" };
            println!(
                "{:>10} {:>6} {:>12.0} {:>8.4}{trend}",
                engine,
                depth,
                tput,
                report.hit_ratio()
            );
            prev = tput;
        }
        println!();
    }

    println!("== sharded: shard count x batch depth (threads=8) =================");
    println!(
        "{:>12} {:>6} {:>6} {:>12} {:>8}",
        "engine", "shards", "batch", "ops/s", "hit"
    );
    const SHARDS: [usize; 4] = [1, 2, 4, 8];
    for engine in ENGINES {
        for &shards in &SHARDS {
            for &depth in &DEPTHS {
                let cache = build_sharded(
                    engine,
                    shards,
                    CacheConfig {
                        mem_limit: 64 << 20,
                        ..CacheConfig::default()
                    },
                )
                .unwrap();
                let opts = DriverOptions {
                    threads: 8,
                    stop: StopRule::OpsPerThread(100_000),
                    prefill: true,
                    sample_every: 16,
                    validate: false,
                    batch: depth,
                };
                let report = run_driver(&cache, &spec, &opts);
                println!(
                    "{:>12} {:>6} {:>6} {:>12.0} {:>8.4}",
                    cache.engine_name(),
                    shards,
                    depth,
                    report.throughput(),
                    report.hit_ratio()
                );
            }
        }
        println!();
    }

    println!("== wire: fleec, one connection, pipelined mixed get/set ===========");
    let cache = build_engine("fleec", CacheConfig::default()).unwrap();
    let server = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            nodelay: true,
        },
        Arc::clone(&cache),
    )
    .unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let catalog = 1024usize;
    for i in 0..catalog {
        client
            .set(format!("net-{i}").as_bytes(), b"0123456789abcdef", 0, 0)
            .unwrap();
    }
    for &depth in &DEPTHS {
        let rounds = 20_000 / depth;
        let mut hits = 0usize;
        let t0 = Instant::now();
        for r in 0..rounds {
            let mut p = client.pipeline();
            for j in 0..depth {
                let id = (r * depth + j) % catalog;
                if (r * depth + j) % 20 == 19 {
                    p.set(format!("net-{id}").as_bytes(), b"fedcba9876543210", 0, 0);
                } else {
                    p.get(format!("net-{id}").as_bytes());
                }
            }
            for reply in p.run().unwrap() {
                if matches!(&reply, PipelineReply::Values(v) if !v.is_empty()) {
                    hits += 1;
                }
            }
        }
        let ops = rounds * depth;
        let tput = ops as f64 / t0.elapsed().as_secs_f64();
        println!(
            "depth {:>3}: {:>10.0} ops/s   ({ops} ops, {hits} get hits)",
            depth, tput
        );
    }
}
