//! Batch-depth × shard × connection sweep — the experiments behind the
//! batched command API, the shard router, and the reactor front-end.
//!
//! ```bash
//! cargo bench --bench batch_pipeline
//! ```
//!
//! Sections:
//!   in-process — the workload driver issuing depth-1/4/16/64 batches
//!                through `Cache::execute_batch`, all three engines. The
//!                blocking engines run the default per-op delegation (a
//!                batch costs what its ops cost); fleec's override pins
//!                one EBR guard per batch, so its ops/s should be
//!                non-decreasing as depth grows.
//!   sharded    — the same driver over `Sharded<_>` routers, sweeping
//!                shard count 1/2/4/8 × batch depth for every engine:
//!                the batch → shard → sub-batch composition.
//!   wire-depth — a single pipelined connection against the served fleec
//!                engine (`Client::pipeline`), measuring the end-to-end
//!                win of one `execute_batch` call per socket read.
//!   wire-conns — the connection-scaling sweep: 1/64/512 simultaneous
//!                pipelined connections (`workload::driver::run_wire`)
//!                against **both** front-end models (`thread` vs
//!                `reactor`), the experiment the reactor exists for.
//!   alloc-path — the write-side memory-path sweep behind the per-thread
//!                slab magazines and staged batched RMW: value size
//!                64B/1KiB/8KiB × batch depth × a set-heavy and an
//!                RMW-heavy mix, fleec only (the slab's one consumer),
//!                4 threads. Emits `BENCH_alloc_path.json`.
//!   read-path  — the read-side memory-path sweep behind the
//!                guard-scoped sink API: 64-deep GET batches rendered to
//!                wire bytes through the **owned** tier (`execute_batch`
//!                → copy out of `GetResult`) vs the **sink** tier
//!                (`execute_batch_into` → value bytes lent straight into
//!                the reply buffer), value size 64B/1KiB/8KiB ×
//!                hit-ratio 0.5/0.9/1.0, engine fleec vs oaflash (the
//!                chained/open-addressing race — same item substrate,
//!                probe structure is the only delta), 4 threads. The
//!                sink column's edge over owned is the copy+allocation
//!                the redesign removed. Emits `BENCH_read_path.json`.
//!   obs-overhead — the observability-plane cost sweep: the in-process
//!                workload with the sampled latency clock off / 1-in-64
//!                (default) / on every batch, fleec only. Emits
//!                `BENCH_obs_overhead.json`.
//!
//! Every row is also appended to `BENCH_batch_pipeline.json` (flat array
//! of records; the alloc-path and read-path sweeps write their own
//! files) so the perf trajectory is machine-readable across PRs.

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use fleec::cache::{build_engine, build_sharded, Cache as _, CacheConfig, ENGINES};
use fleec::client::{Client, PipelineReply};
use fleec::server::{Server, ServerConfig, ServerModel};
use fleec::workload::{
    driver::StopRule, run_driver, run_wire, DriverOptions, ValueSize, WireOptions, WorkloadSpec,
};

const DEPTHS: [usize; 4] = [1, 4, 16, 64];
const JSON_PATH: &str = "BENCH_batch_pipeline.json";

/// One sweep point, serialized into `BENCH_batch_pipeline.json`.
struct Rec {
    section: &'static str,
    engine: String,
    model: &'static str,
    shards: usize,
    depth: usize,
    conns: usize,
    ops_per_s: f64,
    hit_ratio: f64,
}

impl Rec {
    fn json(&self) -> String {
        format!(
            concat!(
                "{{\"section\":\"{}\",\"engine\":\"{}\",\"model\":\"{}\",",
                "\"shards\":{},\"depth\":{},\"conns\":{},",
                "\"ops_per_s\":{:.1},\"hit_ratio\":{:.4}}}"
            ),
            self.section,
            self.engine,
            self.model,
            self.shards,
            self.depth,
            self.conns,
            self.ops_per_s,
            self.hit_ratio
        )
    }
}

fn write_json(records: &[Rec]) {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.json());
        out.push_str(if i + 1 == records.len() { "\n" } else { ",\n" });
    }
    out.push_str("]\n");
    match std::fs::File::create(JSON_PATH).and_then(|mut f| f.write_all(out.as_bytes())) {
        Ok(()) => println!("\nwrote {} records to {JSON_PATH}", records.len()),
        Err(e) => eprintln!("\n!! could not write {JSON_PATH}: {e}"),
    }
}

const ALLOC_JSON_PATH: &str = "BENCH_alloc_path.json";

/// One alloc-path sweep point, serialized into `BENCH_alloc_path.json`.
struct AllocRec {
    mix: &'static str,
    value_size: usize,
    depth: usize,
    ops_per_s: f64,
}

fn write_alloc_json(records: &[AllocRec]) {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"section\":\"alloc_path\",\"engine\":\"fleec\",\"mix\":\"{}\",\"value_size\":{},\"depth\":{},\"ops_per_s\":{:.1}}}{}\n",
            r.mix,
            r.value_size,
            r.depth,
            r.ops_per_s,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    match std::fs::File::create(ALLOC_JSON_PATH).and_then(|mut f| f.write_all(out.as_bytes())) {
        Ok(()) => println!("wrote {} records to {ALLOC_JSON_PATH}", records.len()),
        Err(e) => eprintln!("!! could not write {ALLOC_JSON_PATH}: {e}"),
    }
}

/// The write-side memory-path sweep: per-thread batches through
/// `execute_batch` with allocation-dominated mixes, so the magazine
/// layer's privatized alloc/free and the staged RMW path are what the
/// numbers move with. Appends write at most a handful of times per key
/// between sets, so value growth stays bounded.
fn alloc_path_sweep() {
    const SIZES: [usize; 3] = [64, 1024, 8192];
    const ALLOC_DEPTHS: [usize; 3] = [1, 16, 64];
    const CATALOG: u64 = 4096;
    const THREADS: u64 = 4;
    const OPS_PER_THREAD: u64 = 25_000;
    println!("== alloc-path: value size x depth x mix (fleec, threads=4) ========");
    println!(
        "{:>10} {:>7} {:>6} {:>12}",
        "mix", "vsize", "batch", "ops/s"
    );
    let mut records: Vec<AllocRec> = Vec::new();
    for mix in ["set_heavy", "rmw_heavy"] {
        for &vsize in &SIZES {
            for &depth in &ALLOC_DEPTHS {
                let cache = build_engine(
                    "fleec",
                    CacheConfig {
                        mem_limit: 256 << 20,
                        ..CacheConfig::default()
                    },
                )
                .unwrap();
                let template = vec![0xA5u8; vsize];
                // Prefill: every value key at its sweep size, plus a
                // numeric-counter catalog for incr.
                for id in 0..CATALOG {
                    cache.set(format!("ap-{id}").as_bytes(), &template, 0, 0);
                    cache.set(format!("ct-{id}").as_bytes(), b"0", 0, 0);
                }
                let t0 = Instant::now();
                std::thread::scope(|s| {
                    for t in 0..THREADS {
                        let cache = &cache;
                        let template = &template;
                        s.spawn(move || {
                            let mut rng = fleec::sync::Xoshiro256::seeded(0xA110C ^ t);
                            let vkeys: Vec<Vec<u8>> = (0..CATALOG)
                                .map(|id| format!("ap-{id}").into_bytes())
                                .collect();
                            let ckeys: Vec<Vec<u8>> = (0..CATALOG)
                                .map(|id| format!("ct-{id}").into_bytes())
                                .collect();
                            let mut done = 0u64;
                            while done < OPS_PER_THREAD {
                                let mut ops: Vec<fleec::cache::Op<'_>> =
                                    Vec::with_capacity(depth);
                                for _ in 0..depth {
                                    let vk = vkeys[rng.next_below(CATALOG) as usize].as_slice();
                                    let ck = ckeys[rng.next_below(CATALOG) as usize].as_slice();
                                    let roll = rng.next_below(100);
                                    ops.push(if mix == "set_heavy" {
                                        match roll {
                                            0..=79 => fleec::cache::Op::Set {
                                                key: vk,
                                                value: template,
                                                flags: 0,
                                                exptime: 0,
                                            },
                                            _ => fleec::cache::Op::Get { key: vk },
                                        }
                                    } else {
                                        match roll {
                                            0..=19 => fleec::cache::Op::Set {
                                                key: vk,
                                                value: template,
                                                flags: 0,
                                                exptime: 0,
                                            },
                                            20..=44 => fleec::cache::Op::Append {
                                                key: vk,
                                                suffix: b"-app-suffix-16b-",
                                            },
                                            45..=69 => fleec::cache::Op::Incr { key: ck, delta: 1 },
                                            70..=79 => fleec::cache::Op::Touch {
                                                key: vk,
                                                exptime: 3600,
                                            },
                                            _ => fleec::cache::Op::Get { key: vk },
                                        }
                                    });
                                }
                                let _ = cache.execute_batch(&ops);
                                done += depth as u64;
                            }
                        });
                    }
                });
                let total = THREADS * OPS_PER_THREAD;
                let tput = total as f64 / t0.elapsed().as_secs_f64();
                println!("{:>10} {:>7} {:>6} {:>12.0}", mix, vsize, depth, tput);
                records.push(AllocRec {
                    mix,
                    value_size: vsize,
                    depth,
                    ops_per_s: tput,
                });
            }
        }
        println!();
    }
    write_alloc_json(&records);
}

const READ_JSON_PATH: &str = "BENCH_read_path.json";

/// One read-path sweep point, serialized into `BENCH_read_path.json`.
struct ReadRec {
    engine: &'static str,
    mode: &'static str,
    value_size: usize,
    hit_ratio: f64,
    ops_per_s: f64,
}

fn write_read_json(records: &[ReadRec]) {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"section\":\"read_path\",\"engine\":\"{}\",\"mode\":\"{}\",\"value_size\":{},\"hit_ratio\":{},\"ops_per_s\":{:.1}}}{}\n",
            r.engine,
            r.mode,
            r.value_size,
            r.hit_ratio,
            r.ops_per_s,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    match std::fs::File::create(READ_JSON_PATH).and_then(|mut f| f.write_all(out.as_bytes())) {
        Ok(()) => println!("wrote {} records to {READ_JSON_PATH}", records.len()),
        Err(e) => eprintln!("!! could not write {READ_JSON_PATH}: {e}"),
    }
}

/// A reply-rendering [`fleec::cache::BatchSink`]: value bytes go
/// engine→reply buffer in one copy, exactly what the server's emitter
/// does with the connection outbuf.
struct WireSink<'a> {
    out: &'a mut Vec<u8>,
}

impl fleec::cache::BatchSink for WireSink<'_> {
    fn value(&mut self, _idx: usize, key: &[u8], flags: u32, _cas: u64, data: &[u8]) {
        fleec::proto::write_value(self.out, key, flags, data, None);
    }
    fn miss(&mut self, _idx: usize) {}
    fn store(&mut self, _idx: usize, _outcome: fleec::cache::StoreOutcome) {}
    fn deleted(&mut self, _idx: usize, _existed: bool) {}
    fn counter(&mut self, _idx: usize, _value: Option<u64>) {}
    fn touched(&mut self, _idx: usize, _existed: bool) {}
}

/// The read-side memory-path sweep: GET-only 64-deep batches rendered to
/// wire bytes, owned tier vs sink tier. Hit ratio is steered by mixing
/// prefilled keys with absent ones; the reply buffer is recycled across
/// batches so the sink column measures the engine+render path, not
/// buffer growth.
fn read_path_sweep() {
    const SIZES: [usize; 3] = [64, 1024, 8192];
    const HIT_RATIOS: [f64; 3] = [0.5, 0.9, 1.0];
    const DEPTH: usize = 64;
    const CATALOG: u64 = 4096;
    const THREADS: u64 = 4;
    const OPS_PER_THREAD: u64 = 100_000;
    println!("== read-path: engine x owned vs sink x value size x hit ratio =====");
    println!(
        "{:>8} {:>6} {:>7} {:>5} {:>12}",
        "engine", "mode", "vsize", "hit", "ops/s"
    );
    let mut records: Vec<ReadRec> = Vec::new();
    // The chained-vs-open-addressing race: identical workload, identical
    // item substrate — the delta is purely the probe structure (pointer
    // chase vs cache-line scan), sharpest at 8192-byte values / 0.9 hits.
    for engine in ["fleec", "oaflash"] {
        for &vsize in &SIZES {
        for &hit_ratio in &HIT_RATIOS {
            for mode in ["owned", "sink"] {
                let cache = build_engine(
                    engine,
                    CacheConfig {
                        mem_limit: 256 << 20,
                        ..CacheConfig::default()
                    },
                )
                .unwrap();
                let template = vec![0x5Au8; vsize];
                for id in 0..CATALOG {
                    cache.set(format!("rg-{id}").as_bytes(), &template, 0, 0);
                }
                let t0 = Instant::now();
                std::thread::scope(|s| {
                    for t in 0..THREADS {
                        let cache = &cache;
                        s.spawn(move || {
                            let mut rng = fleec::sync::Xoshiro256::seeded(0x8EAD ^ t);
                            let hit_keys: Vec<Vec<u8>> = (0..CATALOG)
                                .map(|id| format!("rg-{id}").into_bytes())
                                .collect();
                            let miss_keys: Vec<Vec<u8>> = (0..CATALOG)
                                .map(|id| format!("xx-{id}").into_bytes())
                                .collect();
                            let mut reply = Vec::with_capacity(DEPTH * (vsize + 64));
                            let mut done = 0u64;
                            while done < OPS_PER_THREAD {
                                let mut ops: Vec<fleec::cache::Op<'_>> =
                                    Vec::with_capacity(DEPTH);
                                for _ in 0..DEPTH {
                                    let id = rng.next_below(CATALOG) as usize;
                                    let key = if rng.chance(hit_ratio) {
                                        hit_keys[id].as_slice()
                                    } else {
                                        miss_keys[id].as_slice()
                                    };
                                    ops.push(fleec::cache::Op::Get { key });
                                }
                                reply.clear();
                                if mode == "owned" {
                                    let results = cache.execute_batch(&ops);
                                    for (op, r) in ops.iter().zip(&results) {
                                        if let fleec::cache::OpResult::Value(Some(g)) = r {
                                            fleec::proto::write_value(
                                                &mut reply,
                                                op.key(),
                                                g.flags,
                                                &g.data,
                                                None,
                                            );
                                        }
                                    }
                                } else {
                                    let mut sink = WireSink { out: &mut reply };
                                    cache.execute_batch_into(&ops, &mut sink);
                                }
                                std::hint::black_box(reply.len());
                                done += DEPTH as u64;
                            }
                        });
                    }
                });
                let total = THREADS * OPS_PER_THREAD;
                let tput = total as f64 / t0.elapsed().as_secs_f64();
                println!(
                    "{:>8} {:>6} {:>7} {:>5.2} {:>12.0}",
                    engine, mode, vsize, hit_ratio, tput
                );
                records.push(ReadRec {
                    engine,
                    mode,
                    value_size: vsize,
                    hit_ratio,
                    ops_per_s: tput,
                });
            }
        }
        println!();
        }
    }
    write_read_json(&records);
}

fn main() {
    let mut records: Vec<Rec> = Vec::new();
    let spec = WorkloadSpec {
        catalog: 50_000,
        alpha: 0.99,
        read_ratio: 0.95,
        value_size: ValueSize::Fixed(64),
        seed: 0xBA7C_4ED0,
    };

    println!("== in-process: batch depth vs throughput (threads=4) ==============");
    println!("{:>10} {:>6} {:>12} {:>8}", "engine", "batch", "ops/s", "hit");
    for engine in ENGINES {
        let mut prev = 0.0f64;
        for &depth in &DEPTHS {
            let cache = build_engine(
                engine,
                CacheConfig {
                    mem_limit: 64 << 20,
                    ..CacheConfig::default()
                },
            )
            .unwrap();
            let opts = DriverOptions {
                threads: 4,
                stop: StopRule::OpsPerThread(150_000),
                prefill: true,
                sample_every: 16,
                validate: false,
                batch: depth,
            };
            let report = run_driver(&cache, &spec, &opts);
            let tput = report.throughput();
            // Flag regressions >5% against the previous depth: fleec's
            // batched fast path should keep this column non-decreasing.
            let trend = if prev > 0.0 && tput < prev * 0.95 { "  <- dip" } else { "" };
            println!(
                "{:>10} {:>6} {:>12.0} {:>8.4}{trend}",
                engine,
                depth,
                tput,
                report.hit_ratio()
            );
            records.push(Rec {
                section: "in_process",
                engine: engine.to_string(),
                model: "",
                shards: 1,
                depth,
                conns: 0,
                ops_per_s: tput,
                hit_ratio: report.hit_ratio(),
            });
            prev = tput;
        }
        println!();
    }

    println!("== sharded: shard count x batch depth (threads=8) =================");
    println!(
        "{:>12} {:>6} {:>6} {:>12} {:>8}",
        "engine", "shards", "batch", "ops/s", "hit"
    );
    const SHARDS: [usize; 4] = [1, 2, 4, 8];
    for engine in ENGINES {
        for &shards in &SHARDS {
            for &depth in &DEPTHS {
                let cache = build_sharded(
                    engine,
                    shards,
                    CacheConfig {
                        mem_limit: 64 << 20,
                        ..CacheConfig::default()
                    },
                )
                .unwrap();
                let opts = DriverOptions {
                    threads: 8,
                    stop: StopRule::OpsPerThread(100_000),
                    prefill: true,
                    sample_every: 16,
                    validate: false,
                    batch: depth,
                };
                let report = run_driver(&cache, &spec, &opts);
                println!(
                    "{:>12} {:>6} {:>6} {:>12.0} {:>8.4}",
                    cache.engine_name(),
                    shards,
                    depth,
                    report.throughput(),
                    report.hit_ratio()
                );
                records.push(Rec {
                    section: "sharded",
                    engine: engine.to_string(),
                    model: "",
                    shards,
                    depth,
                    conns: 0,
                    ops_per_s: report.throughput(),
                    hit_ratio: report.hit_ratio(),
                });
            }
        }
        println!();
    }

    println!("== wire-depth: fleec, one connection, pipelined mixed get/set =====");
    let cache = build_engine("fleec", CacheConfig::default()).unwrap();
    let server = Server::start(
        ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            ..ServerConfig::default()
        },
        Arc::clone(&cache),
    )
    .unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let catalog = 1024usize;
    for i in 0..catalog {
        client
            .set(format!("net-{i}").as_bytes(), b"0123456789abcdef", 0, 0)
            .unwrap();
    }
    for &depth in &DEPTHS {
        let rounds = 20_000 / depth;
        let mut hits = 0usize;
        let t0 = Instant::now();
        for r in 0..rounds {
            let mut p = client.pipeline();
            for j in 0..depth {
                let id = (r * depth + j) % catalog;
                if (r * depth + j) % 20 == 19 {
                    p.set(format!("net-{id}").as_bytes(), b"fedcba9876543210", 0, 0);
                } else {
                    p.get(format!("net-{id}").as_bytes());
                }
            }
            for reply in p.run().unwrap() {
                if matches!(&reply, PipelineReply::Values(v) if !v.is_empty()) {
                    hits += 1;
                }
            }
        }
        let ops = rounds * depth;
        let tput = ops as f64 / t0.elapsed().as_secs_f64();
        println!(
            "depth {:>3}: {:>10.0} ops/s   ({ops} ops, {hits} get hits)",
            depth, tput
        );
        records.push(Rec {
            section: "wire_depth",
            engine: "fleec".to_string(),
            model: "thread",
            shards: 1,
            depth,
            conns: 1,
            ops_per_s: tput,
            hit_ratio: 0.0,
        });
    }
    drop(client);
    drop(server);

    println!();
    println!("== wire-conns: connection scaling x front-end model (fleec) =======");
    println!("{:>8} {:>8} {:>12} {:>8}", "model", "conns", "ops/s", "hit");
    let wire_spec = WorkloadSpec {
        catalog: 16_384,
        alpha: 0.99,
        read_ratio: 0.95,
        value_size: ValueSize::Fixed(64),
        seed: 0xBA7C_4ED0,
    };
    const CONNS: [usize; 3] = [1, 64, 512];
    let mut models: Vec<(&str, ServerModel)> = vec![("thread", ServerModel::Thread)];
    if cfg!(unix) {
        models.push(("reactor", ServerModel::Reactor { io_threads: 0 }));
    }
    const DEPTH: usize = 16;
    const TOTAL_OPS: u64 = 131_072;
    for &(model_name, model) in &models {
        for &conns in &CONNS {
            let cache = build_engine(
                "fleec",
                CacheConfig {
                    mem_limit: 64 << 20,
                    ..CacheConfig::default()
                },
            )
            .unwrap();
            let server = Server::start(
                ServerConfig {
                    addr: "127.0.0.1:0".parse().unwrap(),
                    model,
                    ..ServerConfig::default()
                },
                Arc::clone(&cache),
            )
            .unwrap();
            let opts = WireOptions {
                conns,
                depth: DEPTH,
                ops_per_conn: (TOTAL_OPS / conns as u64).max(DEPTH as u64),
                workers: 0,
                prefill: true,
                read_timeout: None,
            };
            match run_wire(server.addr(), &wire_spec, &opts) {
                Ok(report) => {
                    println!(
                        "{:>8} {:>8} {:>12.0} {:>8.4}",
                        model_name,
                        conns,
                        report.throughput(),
                        report.hit_ratio()
                    );
                    records.push(Rec {
                        section: "wire_conns",
                        engine: "fleec".to_string(),
                        model: model_name,
                        shards: 1,
                        depth: DEPTH,
                        conns,
                        ops_per_s: report.throughput(),
                        hit_ratio: report.hit_ratio(),
                    });
                }
                Err(e) => eprintln!("{model_name}/{conns}: wire run failed: {e:#}"),
            }
        }
        println!();
    }

    write_json(&records);

    println!();
    alloc_path_sweep();

    println!();
    read_path_sweep();

    println!();
    obs_overhead_sweep();
}

const OBS_JSON_PATH: &str = "BENCH_obs_overhead.json";

/// The observability-overhead sweep: the identical in-process workload
/// with the latency clock off (`latency_sample: 0`), at the shipping
/// default (1-in-64), and fully on (every batch timed). The deltas are
/// the cost of the sampled clock itself — the counters and histogram
/// buckets are always live. Emits `BENCH_obs_overhead.json`.
fn obs_overhead_sweep() {
    const SAMPLES: [u32; 3] = [0, 64, 1];
    println!("== obs-overhead: latency-sample stride vs throughput (fleec, threads=4, depth=16) ==");
    println!("{:>8} {:>12} {:>10}", "stride", "ops/s", "vs off");
    let spec = WorkloadSpec {
        catalog: 50_000,
        alpha: 0.99,
        read_ratio: 0.95,
        value_size: ValueSize::Fixed(64),
        seed: 0xBA7C_4ED0,
    };
    let opts = DriverOptions {
        threads: 4,
        stop: StopRule::OpsPerThread(150_000),
        prefill: true,
        sample_every: 16,
        validate: false,
        batch: 16,
    };
    let mut rows = Vec::new();
    let mut baseline = 0.0f64;
    for &stride in &SAMPLES {
        let cache = build_engine(
            "fleec",
            CacheConfig {
                mem_limit: 64 << 20,
                latency_sample: stride,
                ..CacheConfig::default()
            },
        )
        .unwrap();
        let report = run_driver(&cache, &spec, &opts);
        let tput = report.throughput();
        if stride == 0 {
            baseline = tput;
        }
        let rel = if baseline > 0.0 { tput / baseline } else { 1.0 };
        println!("{:>8} {:>12.0} {:>9.1}%", stride, tput, rel * 100.0);
        rows.push((stride, tput, rel));
        // Sanity: a timed run must actually have timed something.
        if stride > 0 {
            let lat = cache.stats().latency;
            assert!(
                lat.class(fleec::metrics::OpClass::Get).count > 0,
                "stride {stride}: latency clock never fired"
            );
        }
    }
    let mut out = String::from("[\n");
    for (i, (stride, tput, rel)) in rows.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"section\":\"obs_overhead\",\"engine\":\"fleec\",\"latency_sample\":{},\"ops_per_s\":{:.1},\"vs_off\":{:.4}}}{}\n",
            stride,
            tput,
            rel,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    match std::fs::File::create(OBS_JSON_PATH).and_then(|mut f| f.write_all(out.as_bytes())) {
        Ok(()) => println!("wrote {} records to {OBS_JSON_PATH}", rows.len()),
        Err(e) => eprintln!("!! could not write {OBS_JSON_PATH}: {e}"),
    }
}
