//! Regenerates **Figure 1a + 1b**: throughput and speedup of
//! {Memcached, MemcLock, FLeeC} under a read-intensive (99 % reads)
//! workload with small items, sweeping zipfian α.
//!
//! ```bash
//! cargo bench --bench fig1_throughput
//! # knobs: FLEEC_BENCH_THREADS, FLEEC_BENCH_OPS, FLEEC_BENCH_ALPHAS
//! ```
//!
//! Paper shape to reproduce: FLeeC ≥ the others everywhere, with the gap
//! growing as α (contention) grows; MemcLock ≈ Memcached. Absolute
//! numbers differ from the paper (single-core host — DESIGN.md §4).

use fleec::cache::{build_engine, CacheConfig, ENGINES};
use fleec::workload::{
    driver::StopRule, run_driver, DriverOptions, ValueSize, WorkloadSpec,
};

fn env_or<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let threads: usize = env_or("FLEEC_BENCH_THREADS", 8);
    let ops: u64 = env_or("FLEEC_BENCH_OPS", 150_000);
    let alphas: Vec<f64> = std::env::var("FLEEC_BENCH_ALPHAS")
        .map(|s| s.split(',').filter_map(|a| a.parse().ok()).collect())
        .unwrap_or_else(|_| vec![0.50, 0.70, 0.90, 0.99, 1.10, 1.30]);

    println!("# Figure 1 regeneration: 99% reads, 64 B items, catalog=100k,");
    println!("# {threads} threads × {ops} ops, mem=64 MiB (no eviction pressure — Fig 1 isolates concurrency)");
    println!();
    println!(
        "{:>6} | {:>12} {:>12} {:>12} | {:>9} {:>9}   <- Fig 1a (ops/s) | Fig 1b (speedup vs memcached)",
        "alpha", "memcached", "memclock", "fleec", "memclock", "fleec"
    );

    let mut rows = Vec::new();
    for &alpha in &alphas {
        let spec = WorkloadSpec {
            catalog: 100_000,
            alpha,
            read_ratio: 0.99,
            value_size: ValueSize::Fixed(64),
            seed: 0xF16_1A,
        };
        let opts = DriverOptions {
            threads,
            stop: StopRule::OpsPerThread(ops),
            prefill: true,
            sample_every: 16,
            validate: false,
            batch: 1,
        };
        let mut tput = Vec::new();
        for engine in ENGINES {
            let cache = build_engine(
                engine,
                CacheConfig {
                    mem_limit: 64 << 20,
                    initial_buckets: 1 << 16, // steady-state table, like the paper's warm runs
                    ..CacheConfig::default()
                },
            )
            .expect("engine");
            let report = run_driver(&cache, &spec, &opts);
            assert_eq!(report.validation_failures, 0);
            tput.push(report.throughput());
        }
        println!(
            "{:>6.2} | {:>12.0} {:>12.0} {:>12.0} | {:>8.2}x {:>8.2}x",
            alpha,
            tput[0],
            tput[1],
            tput[2],
            tput[1] / tput[0],
            tput[2] / tput[0],
        );
        rows.push((alpha, tput[0], tput[1], tput[2]));
    }

    // Machine-readable block for EXPERIMENTS.md extraction.
    println!("\n# csv: alpha,memcached,memclock,fleec,speedup_memclock,speedup_fleec");
    for (alpha, a, b, c) in rows {
        println!("csv,{alpha},{a:.0},{b:.0},{c:.0},{:.3},{:.3}", b / a, c / a);
    }
}
