//! Regenerates the **latency claim**: "FLeeC … up to 1/6 of the latency
//! w.r.t. Memcached under very high contention".
//!
//! ```bash
//! cargo bench --bench latency
//! # knobs: FLEEC_BENCH_THREADS, FLEEC_BENCH_OPS
//! ```
//!
//! Reports p50/p95/p99/p999 per engine per α. Under blocking designs the
//! tail (p99+) is where lock convoys and lock-holder preemption appear;
//! lock-free ops cannot be stalled by a descheduled peer, so the paper's
//! latency gap should reappear in the tail percentiles.

use fleec::cache::{build_engine, CacheConfig, ENGINES};
use fleec::workload::{
    driver::StopRule, run_driver, DriverOptions, ValueSize, WorkloadSpec,
};

fn env_or<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let threads: usize = env_or("FLEEC_BENCH_THREADS", 16);
    let ops: u64 = env_or("FLEEC_BENCH_OPS", 80_000);

    println!("# Latency percentiles (ns): 99% reads, 64 B items, {threads} threads × {ops} ops");
    println!(
        "{:>6} {:>10} | {:>9} {:>9} {:>9} {:>10} {:>10}",
        "alpha", "engine", "p50", "p95", "p99", "p999", "max"
    );
    for &alpha in &[0.50, 0.99, 1.30] {
        let spec = WorkloadSpec {
            catalog: 100_000,
            alpha,
            read_ratio: 0.99,
            value_size: ValueSize::Fixed(64),
            seed: 0x1A7,
        };
        let opts = DriverOptions {
            threads,
            stop: StopRule::OpsPerThread(ops),
            prefill: true,
            sample_every: 1, // every op: tails need samples
            validate: false,
            batch: 1,
        };
        let mut p99s = Vec::new();
        for engine in ENGINES {
            let cache = build_engine(
                engine,
                CacheConfig {
                    mem_limit: 64 << 20,
                    initial_buckets: 1 << 16,
                    ..CacheConfig::default()
                },
            )
            .expect("engine");
            let report = run_driver(&cache, &spec, &opts);
            let l = &report.latency;
            println!(
                "{:>6.2} {:>10} | {:>9} {:>9} {:>9} {:>10} {:>10}",
                alpha, engine, l.p50_ns, l.p95_ns, l.p99_ns, l.p999_ns, l.max_ns
            );
            p99s.push(l.p99_ns as f64);
        }
        println!(
            "       {:>10} | fleec p99 = {:.2}x memcached (paper: down to ~1/6 under high contention)",
            "ratio",
            p99s[2] / p99s[0].max(1.0),
        );
    }
}
