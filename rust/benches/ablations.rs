//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   A1  multi-bit CLOCK (`clock_max`) — hit-ratio vs eviction precision
//!       (the paper: "CLOCK values are not limited to just one bit").
//!   A2  eviction batch size — OOM-stall amortization vs overshoot.
//!   A3  DEBRA-variant laziness (`retire_threshold`) — the paper's "only
//!       progress when absolutely necessary" vs eager reclamation.
//!   A4  lock stripes in the blocking engines — how much of the paper's
//!       gap is just "not enough stripes".
//!
//! ```bash
//! cargo bench --bench ablations
//! ```

use std::sync::Arc;

use fleec::cache::fleec::FleecCache;
use fleec::cache::{build_engine, Cache, CacheConfig};
use fleec::ebr::{Collector, Config as EbrConfig};
use fleec::workload::{
    driver::{replay_trace, run_driver, StopRule},
    DriverOptions, Trace, ValueSize, WorkloadSpec,
};

fn main() {
    ablation_clock_max();
    ablation_evict_batch();
    ablation_ebr_laziness();
    ablation_lock_stripes();
}

/// A1: 1-bit CLOCK (classic second chance) vs multi-bit.
fn ablation_clock_max() {
    println!("== A1: clock_max (multi-bit CLOCK) — hit-ratio at 2 MiB =========");
    println!("{:>10} | {:>10} {:>10}", "clock_max", "memclock", "fleec");
    let spec = WorkloadSpec {
        catalog: 100_000,
        alpha: 0.99,
        read_ratio: 0.99,
        value_size: ValueSize::Fixed(64),
        seed: 21,
    };
    let trace = Trace::generate(&spec, 200_000);
    for clock_max in [1u8, 2, 3, 7] {
        let mut ratios = Vec::new();
        for engine in ["memclock", "fleec"] {
            let cache = build_engine(
                engine,
                CacheConfig {
                    mem_limit: 2 << 20,
                    clock_max,
                    ..CacheConfig::default()
                },
            )
            .unwrap();
            let (r, _, _) = replay_trace(cache.as_ref(), &trace);
            ratios.push(r);
        }
        println!("{:>10} | {:>10.4} {:>10.4}", clock_max, ratios[0], ratios[1]);
    }
    println!("# paper: multi-bit distinguishes mildly vs highly popular buckets\n");
}

/// A2: eviction batch under write pressure.
fn ablation_evict_batch() {
    println!("== A2: evict_batch — write throughput at the memory limit ========");
    println!("{:>10} | {:>12} {:>12}", "batch", "sets/s", "oom_stalls");
    for batch in [1u32, 8, 32, 128] {
        let cache: Arc<dyn Cache> = Arc::new(FleecCache::new(CacheConfig {
            mem_limit: 4 << 20,
            evict_batch: batch,
            ..CacheConfig::default()
        }));
        let spec = WorkloadSpec {
            catalog: 50_000,
            alpha: 0.8,
            read_ratio: 0.0, // pure writes: maximal eviction pressure
            value_size: ValueSize::Fixed(1024),
            seed: 3,
        };
        let opts = DriverOptions {
            threads: 4,
            stop: StopRule::OpsPerThread(10_000),
            prefill: false,
            sample_every: 32,
            validate: false,
            batch: 1,
        };
        let report = run_driver(&cache, &spec, &opts);
        let m = cache.stats().metrics;
        println!(
            "{:>10} | {:>12.0} {:>12}",
            batch,
            report.throughput(),
            m.oom_stalls
        );
    }
    println!();
}

/// A3: the paper's lazy reclamation vs eager (low threshold).
fn ablation_ebr_laziness() {
    println!("== A3: DEBRA-variant laziness — retire_threshold sweep ===========");
    println!(
        "{:>10} | {:>12} {:>14} {:>12}",
        "threshold", "ns/retire", "advance_tries", "peak_pending"
    );
    for threshold in [8usize, 64, 512, 4096] {
        let c = Collector::new(EbrConfig {
            retire_threshold: threshold,
        });
        let iters = 200_000u64;
        let t0 = std::time::Instant::now();
        let mut peak = 0usize;
        for i in 0..iters {
            let g = c.pin();
            unsafe { g.defer_drop_box(Box::into_raw(Box::new([0u64; 4]))) };
            if i % 1024 == 0 {
                peak = peak.max(c.pending_items());
            }
        }
        drop(c.pin());
        let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let (attempts, _) = c.advance_stats();
        println!("{:>10} | {:>12.1} {:>14} {:>12}", threshold, ns, attempts, peak);
        c.force_reclaim(4);
    }
    println!("# paper: high threshold (lazy) trades bounded limbo memory for fewer scans\n");
}

/// A4: does giving the blocking baseline more stripes close the gap?
fn ablation_lock_stripes() {
    println!("== A4: lock stripes in the memcached baseline ====================");
    println!("{:>10} | {:>12} {:>12}", "stripes", "memcached/s", "fleec ×");
    let spec = WorkloadSpec {
        catalog: 100_000,
        alpha: 0.99,
        read_ratio: 0.99,
        value_size: ValueSize::Fixed(64),
        seed: 5,
    };
    let opts = DriverOptions {
        threads: 8,
        stop: StopRule::OpsPerThread(60_000),
        prefill: true,
        sample_every: 16,
        validate: false,
        batch: 1,
    };
    // FLeeC reference point.
    let fleec = build_engine(
        "fleec",
        CacheConfig {
            mem_limit: 64 << 20,
            initial_buckets: 1 << 16,
            ..CacheConfig::default()
        },
    )
    .unwrap();
    let fleec_tput = run_driver(&fleec, &spec, &opts).throughput();
    for stripes in [1usize, 4, 16, 64, 256] {
        let cache = build_engine(
            "memcached",
            CacheConfig {
                mem_limit: 64 << 20,
                initial_buckets: 1 << 16,
                lock_stripes: stripes,
                ..CacheConfig::default()
            },
        )
        .unwrap();
        let tput = run_driver(&cache, &spec, &opts).throughput();
        println!(
            "{:>10} | {:>12.0} {:>11.2}x",
            stripes,
            tput,
            fleec_tput / tput
        );
    }
    println!("# paper's point: the strict-LRU list serializes hits regardless of stripes");
}
