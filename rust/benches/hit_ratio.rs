//! Regenerates the **hit-ratio experiment** (evaluation question 1):
//! strict LRU (Memcached) vs per-bucket multi-bit CLOCK (MemcLock,
//! FLeeC), replaying identical traces, with the analytic model columns
//! (Che/LRU + FIFO fixed point) from the AOT artifact when present.
//!
//! ```bash
//! cargo bench --bench hit_ratio
//! # knobs: FLEEC_BENCH_TRACE (ops), FLEEC_BENCH_MEM_MB
//! ```
//!
//! Paper claim: the CLOCK-based policy "does not significantly impact
//! the hit-ratio" — the three measured columns should agree closely and
//! sit between the FIFO and LRU model bounds (CLOCK has use-bits).

use fleec::cache::{build_engine, CacheConfig, ENGINES};
use fleec::runtime::{artifacts_dir, HitRatioModule, Runtime};
use fleec::workload::{driver::replay_trace, Trace, ValueSize, WorkloadSpec};

fn env_or<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let trace_len: usize = env_or("FLEEC_BENCH_TRACE", 300_000);
    let mem_mb: usize = env_or("FLEEC_BENCH_MEM_MB", 2);
    let catalog = 100_000u64;
    let value_bytes = 64usize;

    let model = Runtime::new()
        .ok()
        .and_then(|rt| HitRatioModule::load(&rt, &artifacts_dir()).ok().map(|m| (rt, m)));
    if model.is_none() {
        eprintln!("note: run `make artifacts` for the model columns");
    }

    println!("# Hit-ratio: catalog={catalog}, cache={mem_mb} MiB, {value_bytes} B values, trace={trace_len}");
    println!(
        "{:>6} | {:>10} {:>10} {:>10} | {:>9} {:>9} | {:>8}",
        "alpha", "memcached", "memclock", "fleec", "model-LRU", "model-FIFO", "Δclock"
    );
    for &alpha in &[0.50, 0.70, 0.90, 0.99, 1.10, 1.30] {
        let spec = WorkloadSpec {
            catalog,
            alpha,
            read_ratio: 0.99,
            value_size: ValueSize::Fixed(value_bytes),
            seed: 7,
        };
        let trace = Trace::generate(&spec, trace_len);
        let mut measured = Vec::new();
        for engine in ENGINES {
            let cache = build_engine(
                engine,
                CacheConfig {
                    mem_limit: mem_mb << 20,
                    ..CacheConfig::default()
                },
            )
            .expect("engine");
            let (ratio, _, _) = replay_trace(cache.as_ref(), &trace);
            measured.push(ratio);
        }
        let capacity = ((mem_mb << 20) / (value_bytes + 88)) as f32;
        let (m_lru, m_fifo) = match &model {
            Some((_rt, m)) => {
                let est = m.run(alpha as f32, capacity).expect("model run");
                (format!("{:.4}", est.lru), format!("{:.4}", est.fifo))
            }
            None => ("n/a".into(), "n/a".into()),
        };
        println!(
            "{:>6.2} | {:>10.4} {:>10.4} {:>10.4} | {:>9} {:>9} | {:>+8.4}",
            alpha,
            measured[0],
            measured[1],
            measured[2],
            m_lru,
            m_fifo,
            measured[1] - measured[0], // CLOCK-vs-LRU delta on identical table design
        );
    }
    println!("\n# Δclock = memclock − memcached: the cost of approximating LRU (paper: ≈0)");
}
