//! Component micro-benchmarks (experiment E4): per-operation costs of
//! the substrates FLeeC is built from, and of the design choices
//! DESIGN.md calls out.
//!
//! ```bash
//! cargo bench --bench micro
//! ```
//!
//! Sections:
//!   list      — Harris lock-free list vs a mutexed BTreeSet, 1..N threads
//!   ebr       — pin/unpin cost; retire+reclaim cost
//!   slab      — alloc/free fast path vs malloc (Box)
//!   stack     — tagged Treiber stack push/pop
//!   clock     — eviction sweep over a warm vs cold CLOCK array
//!   proto     — text-protocol parse throughput
//!   engines   — single-threaded get/set per engine (baseline op cost)

use std::sync::{Arc, Mutex};
use std::time::Instant;

use fleec::cache::{build_engine, Cache as _, CacheConfig};
use fleec::ebr::Collector;
use fleec::lockfree::{HarrisList, TaggedStack};
use fleec::slab::{Slab, SlabConfig};
use fleec::sync::Xoshiro256;

fn bench(name: &str, iters: u64, f: impl FnOnce()) {
    let t0 = Instant::now();
    f();
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:<48} {ns:>10.1} ns/op   ({iters} iters)");
}

fn bench_threads(name: &str, threads: usize, iters_per_thread: u64, f: impl Fn(u64) + Send + Sync) {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            s.spawn(move || f(t as u64));
        }
    });
    let total = threads as u64 * iters_per_thread;
    let ns = t0.elapsed().as_nanos() as f64 / total as f64;
    println!("{name:<48} {ns:>10.1} ns/op   ({threads}×{iters_per_thread})");
}

fn main() {
    println!("== list: Harris lock-free vs Mutex<BTreeSet> =====================");
    for &threads in &[1usize, 4, 16] {
        let iters = 50_000u64;
        let collector = Collector::default();
        let harris: Arc<HarrisList<u64, u64>> = Arc::new(HarrisList::new(collector));
        bench_threads(
            &format!("harris list mixed ops ({threads} thr)"),
            threads,
            iters,
            |t| {
                let mut rng = Xoshiro256::seeded(t);
                for _ in 0..iters {
                    let k = rng.next_below(512);
                    match rng.next_below(10) {
                        0..=6 => {
                            let _ = harris.get(&k, |v| *v);
                        }
                        7..=8 => {
                            let _ = harris.insert(k, t);
                        }
                        _ => {
                            let _ = harris.remove(&k);
                        }
                    }
                }
            },
        );
        let locked: Arc<Mutex<std::collections::BTreeMap<u64, u64>>> =
            Arc::new(Mutex::new(std::collections::BTreeMap::new()));
        bench_threads(
            &format!("mutex btreemap mixed ops ({threads} thr)"),
            threads,
            iters,
            |t| {
                let mut rng = Xoshiro256::seeded(t);
                for _ in 0..iters {
                    let k = rng.next_below(512);
                    let mut m = locked.lock().unwrap();
                    match rng.next_below(10) {
                        0..=6 => {
                            let _ = m.get(&k).copied();
                        }
                        7..=8 => {
                            m.insert(k, t);
                        }
                        _ => {
                            m.remove(&k);
                        }
                    }
                }
            },
        );
    }

    println!("\n== ebr ============================================================");
    {
        let c = Collector::default();
        let iters = 2_000_000u64;
        bench("ebr pin+unpin", iters, || {
            for _ in 0..iters {
                drop(c.pin());
            }
        });
        let iters = 200_000u64;
        bench("ebr retire box + amortized reclaim", iters, || {
            for _ in 0..iters {
                let g = c.pin();
                unsafe { g.defer_drop_box(Box::into_raw(Box::new(0u64))) };
            }
            c.force_reclaim(3);
        });
    }

    println!("\n== slab vs malloc =================================================");
    {
        let slab = Slab::new(SlabConfig::default());
        let iters = 1_000_000u64;
        bench("slab alloc+free 100 B", iters, || {
            for _ in 0..iters {
                let (p, c) = slab.alloc(100).unwrap();
                unsafe { slab.free(p, c) };
            }
        });
        bench("box alloc+free 100 B", iters, || {
            for _ in 0..iters {
                drop(std::hint::black_box(vec![0u8; 100]));
            }
        });
    }

    println!("\n== tagged stack ===================================================");
    {
        let stack = TaggedStack::new();
        let mut blocks: Vec<Box<[u8; 64]>> = (0..64).map(|_| Box::new([0u8; 64])).collect();
        for b in blocks.iter_mut() {
            unsafe { stack.push(b.as_mut_ptr()) };
        }
        let iters = 2_000_000u64;
        bench("tagged stack pop+push", iters, || {
            for _ in 0..iters {
                let p = unsafe { stack.pop() }.unwrap();
                unsafe { stack.push(p) };
            }
        });
    }

    println!("\n== clock sweep (engine eviction path) =============================");
    {
        // Warm cache at its memory limit: every set drives the CLOCK hand.
        let cache = build_engine(
            "fleec",
            CacheConfig {
                mem_limit: 4 << 20,
                ..CacheConfig::default()
            },
        )
        .unwrap();
        let value = vec![0u8; 1024];
        for i in 0..8_000u32 {
            cache.set(format!("warm-{i}").as_bytes(), &value, 0, 0);
        }
        let iters = 20_000u64;
        bench("set on full cache (evicting)", iters, || {
            for i in 0..iters {
                cache.set(format!("evict-{i}").as_bytes(), &value, 0, 0);
            }
        });
        let m = cache.stats().metrics;
        println!("  (evictions={} oom_stalls={})", m.evictions, m.oom_stalls);
    }

    println!("\n== proto parse ====================================================");
    {
        let wire = b"set somekey0001 7 60 64\r\nxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxx\r\n";
        let iters = 2_000_000u64;
        bench("parse storage command (64 B payload)", iters, || {
            for _ in 0..iters {
                match fleec::proto::parse(std::hint::black_box(wire)) {
                    fleec::proto::Parsed::Done(_, n) => {
                        assert_eq!(n, wire.len());
                    }
                    _ => unreachable!(),
                }
            }
        });
        let getw = b"get somekey0001\r\n";
        bench("parse get command", iters, || {
            for _ in 0..iters {
                let _ = std::hint::black_box(fleec::proto::parse(std::hint::black_box(getw)));
            }
        });
    }

    println!("\n== engines: single-thread op cost =================================");
    for engine in fleec::cache::ENGINES {
        let cache = build_engine(
            engine,
            CacheConfig {
                mem_limit: 64 << 20,
                ..CacheConfig::default()
            },
        )
        .unwrap();
        let iters = 500_000u64;
        for i in 0..10_000u32 {
            cache.set(format!("k{i:08}").as_bytes(), b"0123456789abcdef", 0, 0);
        }
        let mut rng = Xoshiro256::seeded(1);
        bench(&format!("{engine}: get hit (16 B value)"), iters, || {
            for _ in 0..iters {
                let k = format!("k{:08}", rng.next_below(10_000));
                std::hint::black_box(cache.get(k.as_bytes()));
            }
        });
        let mut rng = Xoshiro256::seeded(2);
        let iters = 200_000u64;
        bench(&format!("{engine}: set overwrite (16 B)"), iters, || {
            for _ in 0..iters {
                let k = format!("k{:08}", rng.next_below(10_000));
                std::hint::black_box(cache.set(k.as_bytes(), b"fedcba9876543210", 0, 0));
            }
        });
    }
}
