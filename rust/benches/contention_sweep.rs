//! Regenerates the **contention-mediation sweep** (evaluation setup):
//! the paper notes contention is mediated by item size, access skew and
//! bandwidth. This bench sweeps item size × thread count at fixed α and
//! reports throughput per engine — with large items, memory copies (and
//! on the paper's testbed, the network) dominate and the engines
//! converge; with small items the concurrency design decides.
//!
//! ```bash
//! cargo bench --bench contention_sweep
//! # knobs: FLEEC_BENCH_OPS
//! ```

use fleec::cache::{build_engine, CacheConfig, ENGINES};
use fleec::workload::{
    driver::StopRule, run_driver, DriverOptions, ValueSize, WorkloadSpec,
};

fn env_or<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let ops: u64 = env_or("FLEEC_BENCH_OPS", 60_000);
    println!("# Contention sweep: α=0.99, 99% reads; throughput (ops/s)");
    println!(
        "{:>8} {:>8} | {:>12} {:>12} {:>12} | {:>8}",
        "value_B", "threads", "memcached", "memclock", "fleec", "fleec ×"
    );
    for &value_bytes in &[64usize, 1024, 8192, 65536] {
        for &threads in &[2usize, 8, 32] {
            let spec = WorkloadSpec {
                catalog: 10_000,
                alpha: 0.99,
                read_ratio: 0.99,
                value_size: ValueSize::Fixed(value_bytes),
                seed: 0xC0,
            };
            let opts = DriverOptions {
                threads,
                stop: StopRule::OpsPerThread(ops / threads as u64),
                prefill: true,
                sample_every: 16,
                validate: false,
                batch: 1,
            };
            let mut tput = Vec::new();
            for engine in ENGINES {
                let cache = build_engine(
                    engine,
                    CacheConfig {
                        // Budget sized so the catalog always fits: this
                        // sweep isolates copy/concurrency costs, not
                        // eviction.
                        mem_limit: (value_bytes + 256) * 10_000 * 2,
                        ..CacheConfig::default()
                    },
                )
                .expect("engine");
                let report = run_driver(&cache, &spec, &opts);
                tput.push(report.throughput());
            }
            println!(
                "{:>8} {:>8} | {:>12.0} {:>12.0} {:>12.0} | {:>7.2}x",
                value_bytes,
                threads,
                tput[0],
                tput[1],
                tput[2],
                tput[2] / tput[0]
            );
        }
    }
    println!("\n# expected shape: fleec× largest at small values (concurrency-bound),");
    println!("# converging toward 1.0 as copies dominate (bandwidth-bound).");
}
