//! Deterministic fault injection: named failpoints for the chaos tests.
//!
//! A **failpoint** is a named site in the serving/memory stack where a
//! test can make the code misbehave on purpose: `slab.alloc` can be made
//! to fail as if memory were exhausted, `conn.write` can be made to
//! short-write or error, `batch.drain` can be made to panic. Production
//! builds compile every probe to a constant `None` — the `faults` cargo
//! feature is off by default, so the hot paths carry **zero** cost and
//! zero branches from this module.
//!
//! With the feature on, faults are configured by a spec string — either
//! the `FLEEC_FAULTS` environment variable (read once, at the first
//! probe) or [`configure`] (tests; replaces the whole table):
//!
//! ```text
//! FLEEC_FAULTS = entry[,entry...]
//! entry        = site:kind:rate:seed
//! site         = failpoint name (see the inventory in
//!                rust/docs/robustness.md: slab.alloc, poller.wait,
//!                poller.arm, accept, conn.read, conn.write, batch.drain)
//! kind         = error-return | delay | partial-write | oom | panic
//! rate         = probability in [0,1], or "once" (fire exactly one time)
//! seed         = u64 (decimal or 0x-hex) driving the per-site decision
//!                sequence
//! ```
//!
//! Example: `FLEEC_FAULTS=slab.alloc:oom:0.02:0xF1EE,conn.write:partial-write:0.1:7`.
//!
//! **Determinism.** Each rule decides its *n*-th probe independently of
//! wall clock and of every other rule: probe `n` fires iff
//! `splitmix64(seed ^ n)` falls below `rate` (as a fraction of `2⁶⁴`).
//! Re-running with the same seed replays the same per-site decision
//! *sequence*; which thread draws the n-th probe still depends on
//! scheduling, which is exactly the nondeterminism a chaos test wants to
//! keep. The CI `chaos` job pins the seed (`FLEEC_SEED` convention) and
//! prints it so any failure replays.
//!
//! **Call-site contract.** Sites call the cheapest probe that fits:
//! [`fail`] for error-return/oom decisions (it also services delay —
//! sleeps inline — and panic — unwinds, to be caught by the reactor's
//! per-connection `catch_unwind`), [`io`] when an injected error should
//! surface as an `io::Error`, [`write_len`] for partial-write
//! truncation. [`hit`] is the raw probe when a site wants to handle the
//! kinds itself. A fault kind a site does not model is ignored there.

use std::time::Duration;

/// What an armed failpoint asks the call site to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Return the site's injected-error path (I/O error, `None`, ...).
    ErrorReturn,
    /// Sleep this long, then proceed normally (slow peer / slow disk).
    Delay(Duration),
    /// Truncate this write (the state machine must resume correctly).
    PartialWrite,
    /// Fail as if memory were exhausted (alias of `ErrorReturn` at
    /// allocation sites; kept distinct so specs read naturally).
    Oom,
    /// Panic at the site (exercises the panic-isolation layer).
    Panic,
}

/// Injected sleep for `delay` faults — long enough to reorder events,
/// short enough that chaos runs stay fast.
pub const DELAY: Duration = Duration::from_millis(2);

#[cfg(feature = "faults")]
mod imp {
    use super::{Fault, DELAY};
    use once_cell::sync::Lazy;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::RwLock;

    /// One configured failpoint rule.
    struct Rule {
        site: String,
        kind: RuleKind,
        /// Firing threshold: probe `n` fires iff `splitmix64(seed ^ n) <
        /// threshold` (`rate` scaled to the u64 range).
        threshold: u64,
        seed: u64,
        /// Cap on total firings (0 = unlimited; `once` sets 1).
        max_fires: u64,
        /// Probes seen at this site (the deterministic sequence index).
        probes: AtomicU64,
        /// Times this rule fired.
        fires: AtomicU64,
    }

    #[derive(Clone, Copy)]
    enum RuleKind {
        ErrorReturn,
        Delay,
        PartialWrite,
        Oom,
        Panic,
    }

    /// The active rule table. `Lazy` seeds it from `FLEEC_FAULTS` on the
    /// first probe; [`super::configure`] replaces it wholesale. A
    /// read-mostly `RwLock` is fine here: the probe path only ever takes
    /// the read lock, and the `faults` feature is never on in production
    /// builds.
    static RULES: Lazy<RwLock<Vec<Rule>>> = Lazy::new(|| {
        let spec = std::env::var("FLEEC_FAULTS").unwrap_or_default();
        RwLock::new(parse(&spec).unwrap_or_default())
    });

    /// SplitMix64: the standard 64-bit finalizer-style mixer. Chosen for
    /// the same reason the workload generator uses it — one multiply
    /// chain, full avalanche, trivially reproducible in any language.
    fn splitmix64(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^ (x >> 31)
    }

    fn parse_u64(s: &str) -> Option<u64> {
        if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
            u64::from_str_radix(hex, 16).ok()
        } else {
            s.parse().ok()
        }
    }

    /// Parse a spec string into rules. `Err` carries the offending entry.
    fn parse(spec: &str) -> Result<Vec<Rule>, String> {
        let mut rules = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let parts: Vec<&str> = entry.split(':').collect();
            if parts.len() != 4 {
                return Err(format!("bad fault entry {entry:?} (want site:kind:rate:seed)"));
            }
            let kind = match parts[1] {
                "error-return" => RuleKind::ErrorReturn,
                "delay" => RuleKind::Delay,
                "partial-write" => RuleKind::PartialWrite,
                "oom" => RuleKind::Oom,
                "panic" => RuleKind::Panic,
                k => return Err(format!("bad fault kind {k:?} in {entry:?}")),
            };
            let (threshold, max_fires) = if parts[2] == "once" {
                (u64::MAX, 1)
            } else {
                let rate: f64 = parts[2]
                    .parse()
                    .ok()
                    .filter(|r| (0.0..=1.0).contains(r))
                    .ok_or_else(|| format!("bad fault rate {:?} in {entry:?}", parts[2]))?;
                // rate 1.0 must always fire; scale everything else.
                if rate >= 1.0 {
                    (u64::MAX, 0)
                } else {
                    ((rate * u64::MAX as f64) as u64, 0)
                }
            };
            let seed = parse_u64(parts[3])
                .ok_or_else(|| format!("bad fault seed {:?} in {entry:?}", parts[3]))?;
            rules.push(Rule {
                site: parts[0].to_string(),
                kind,
                threshold,
                seed,
                max_fires,
                probes: AtomicU64::new(0),
                fires: AtomicU64::new(0),
            });
        }
        Ok(rules)
    }

    pub fn configure(spec: &str) -> Result<(), String> {
        let rules = parse(spec)?;
        *RULES.write().unwrap() = rules;
        Ok(())
    }

    pub fn hit(site: &str) -> Option<Fault> {
        let rules = RULES.read().unwrap();
        if rules.is_empty() {
            return None;
        }
        for rule in rules.iter() {
            if rule.site != site {
                continue;
            }
            // ord: relaxed-ok — the probe index is a private sequence
            // counter; it orders nothing and cross-thread interleaving of
            // indices is inherent to a multi-threaded chaos run.
            let n = rule.probes.fetch_add(1, Ordering::Relaxed);
            if rule.threshold != u64::MAX && splitmix64(rule.seed ^ n) >= rule.threshold {
                continue;
            }
            if rule.max_fires != 0 {
                // ord: relaxed-ok — stats-grade firing cap; a rare
                // over-count race would fire one extra fault, which a
                // chaos harness tolerates by construction.
                if rule.fires.load(Ordering::Relaxed) >= rule.max_fires {
                    continue;
                }
            }
            rule.fires.fetch_add(1, Ordering::Relaxed);
            return Some(match rule.kind {
                RuleKind::ErrorReturn => Fault::ErrorReturn,
                RuleKind::Delay => Fault::Delay(DELAY),
                RuleKind::PartialWrite => Fault::PartialWrite,
                RuleKind::Oom => Fault::Oom,
                RuleKind::Panic => Fault::Panic,
            });
        }
        None
    }

    pub fn fired(site: &str) -> u64 {
        RULES
            .read()
            .unwrap()
            .iter()
            .filter(|r| r.site == site)
            // ord: relaxed-ok — stats-grade read for test assertions.
            .map(|r| r.fires.load(Ordering::Relaxed))
            .sum()
    }

    pub fn active() -> bool {
        !RULES.read().unwrap().is_empty()
    }
}

/// Probe a failpoint: `None` (always, with the feature off) or the fault
/// the site should act out. Prefer [`fail`]/[`clamp_write`] unless the
/// site needs kind-specific handling.
#[cfg(feature = "faults")]
pub fn hit(site: &str) -> Option<Fault> {
    imp::hit(site)
}

/// Probe a failpoint (no-op build: the `faults` feature is off).
#[cfg(not(feature = "faults"))]
#[inline(always)]
pub fn hit(_site: &str) -> Option<Fault> {
    None
}

/// Replace the fault table from a spec string (tests; see module docs
/// for the grammar). With the feature off this is a no-op `Ok`.
#[cfg(feature = "faults")]
pub fn configure(spec: &str) -> Result<(), String> {
    imp::configure(spec)
}

/// Replace the fault table (no-op build).
#[cfg(not(feature = "faults"))]
pub fn configure(_spec: &str) -> Result<(), String> {
    Ok(())
}

/// How many times rules at `site` have fired (test assertions).
#[cfg(feature = "faults")]
pub fn fired(site: &str) -> u64 {
    imp::fired(site)
}

/// Firing count (no-op build: always 0).
#[cfg(not(feature = "faults"))]
pub fn fired(_site: &str) -> u64 {
    0
}

/// Whether any fault rule is configured.
#[cfg(feature = "faults")]
pub fn active() -> bool {
    imp::active()
}

/// Whether any fault rule is configured (no-op build: never).
#[cfg(not(feature = "faults"))]
#[inline(always)]
pub fn active() -> bool {
    false
}

/// The common error-style probe: `true` when the site should take its
/// injected-failure path. `delay` faults sleep here and return `false`
/// (the site then proceeds normally); `panic` faults unwind here — the
/// serving plane's per-connection `catch_unwind` is the designed catcher.
#[inline]
pub fn fail(site: &str) -> bool {
    match hit(site) {
        None => false,
        Some(Fault::ErrorReturn) | Some(Fault::Oom) => true,
        Some(Fault::Delay(d)) => {
            std::thread::sleep(d);
            false
        }
        // A partial-write kind at a non-write site degrades to a no-op.
        Some(Fault::PartialWrite) => false,
        Some(Fault::Panic) => panic!("fleec::faults — injected panic at failpoint {site:?}"),
    }
}

/// I/O-site probe: `Err` (an injected `ConnectionReset`) when an
/// error-return/oom fault fires, so call sites can `faults::io(site)?`
/// straight into their normal error handling. Delay faults sleep and
/// return `Ok`; panic faults unwind.
#[inline]
pub fn io(site: &str) -> std::io::Result<()> {
    if fail(site) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::ConnectionReset,
            "fleec::faults — injected I/O error",
        ));
    }
    Ok(())
}

/// Write-site probe: the number of bytes the site should actually write
/// (`len`, a truncation when a `partial-write` fault fires, or `Err`
/// when an error-return fault fires). Truncation never extends and never
/// returns 0 for a non-empty write, so what gets exercised is the
/// caller's short-write resumption logic, not a fake EOF.
#[inline]
pub fn write_len(site: &str, len: usize) -> std::io::Result<usize> {
    match hit(site) {
        Some(Fault::ErrorReturn) | Some(Fault::Oom) => Err(std::io::Error::new(
            std::io::ErrorKind::ConnectionReset,
            "fleec::faults — injected write error",
        )),
        Some(Fault::PartialWrite) if len > 1 => Ok((len / 2).max(1)),
        Some(Fault::Delay(d)) => {
            std::thread::sleep(d);
            Ok(len)
        }
        Some(Fault::Panic) => panic!("fleec::faults — injected panic at failpoint {site:?}"),
        _ => Ok(len),
    }
}

#[cfg(all(test, feature = "faults"))]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// The rule table is process-global; serialize these tests (and use
    /// site names no production code probes, so a full `cargo test
    /// --features faults` can't destabilize concurrently-running tests).
    static GATE: Mutex<()> = Mutex::new(());

    fn gate() -> std::sync::MutexGuard<'static, ()> {
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn spec_parses_and_replays_deterministically() {
        let _g = gate();
        configure("t.alpha:oom:0.5:42").unwrap();
        let first: Vec<bool> = (0..64).map(|_| fail("t.alpha")).collect();
        assert!(first.iter().any(|&b| b), "rate 0.5 must fire in 64 probes");
        assert!(first.iter().any(|&b| !b), "rate 0.5 must also pass");
        // Reconfiguring resets the probe counter: same seed, same sequence.
        configure("t.alpha:oom:0.5:42").unwrap();
        let second: Vec<bool> = (0..64).map(|_| fail("t.alpha")).collect();
        assert_eq!(first, second, "seeded decision sequence must replay");
        configure("").unwrap();
    }

    #[test]
    fn once_fires_exactly_one_time() {
        let _g = gate();
        configure("t.beta:error-return:once:7").unwrap();
        let fires: usize = (0..100).filter(|_| fail("t.beta")).count();
        assert_eq!(fires, 1);
        assert_eq!(fired("t.beta"), 1);
        configure("").unwrap();
    }

    #[test]
    fn partial_write_truncates_but_never_zeroes() {
        let _g = gate();
        configure("t.gamma:partial-write:1.0:1").unwrap();
        assert_eq!(write_len("t.gamma", 100).unwrap(), 50);
        assert_eq!(write_len("t.gamma", 1).unwrap(), 1);
        configure("").unwrap();
    }

    #[test]
    fn error_return_surfaces_as_io_error() {
        let _g = gate();
        configure("t.delta:error-return:1.0:1").unwrap();
        assert!(write_len("t.delta", 100).is_err());
        assert!(io("t.delta").is_err());
        configure("").unwrap();
        assert_eq!(write_len("t.delta", 100).unwrap(), 100);
        assert!(io("t.delta").is_ok());
    }

    #[test]
    fn unknown_site_never_fires_and_bad_specs_error() {
        let _g = gate();
        configure("t.epsilon:oom:1.0:1").unwrap();
        assert!(!fail("not.a.site"));
        configure("").unwrap();
        assert!(configure("t.epsilon:frobnicate:1.0:1").is_err());
        assert!(configure("t.epsilon:oom:2.5:1").is_err());
        assert!(configure("missing:fields").is_err());
    }
}
