//! Command-line interface (hand-rolled; the offline crate set has no
//! clap). Subcommands:
//!
//! ```text
//! fleec serve   --engine fleec --port 11211 --mem-mb 64 [--no-planner]
//!               [--model reactor|thread] [--io-threads N]
//!               [--latency-sample N] [--metrics-addr HOST:PORT]
//!               [--max-conns N] [--conn-idle-timeout SECS]
//! fleec bench   --engine all --alpha 0.99 --threads 8 --ops 200000 ...
//!               [--conns N] (over-the-wire connection-scaling mode)
//! fleec hit-ratio --alpha 0.99 --catalog 100000 --mem-mb 4
//! fleec planner-demo
//! fleec version
//! ```

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::cache::{build_sharded, CacheConfig, ENGINES};
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::runtime::{artifacts_dir, HitRatioModule, PlannerModule, Runtime};
use crate::server::{Server, ServerConfig, ServerModel};
use crate::workload::{
    run_driver, run_wire, DriverOptions, ValueSize, WireOptions, WorkloadSpec,
    driver::StopRule,
    tenants::{footprints, run_tenant_bench, TenantBenchReport, TenantBenchSpec},
};
use crate::Result;

/// Parsed `--key value` options plus positional arguments.
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

/// Boolean flags (never consume a value).
const BOOL_FLAGS: &[&str] = &["validate", "no-planner", "nodelay", "quiet", "no-arbiter"];

/// Parse raw argv (after the subcommand) into [`Args`].
pub fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut options = HashMap::new();
    let mut flags = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if !BOOL_FLAGS.contains(&name) && i + 1 < argv.len() && !argv[i + 1].starts_with("--")
            {
                options.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.push(name.to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args {
        positional,
        options,
        flags,
    }
}

impl Args {
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.options.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// The default front-end model: the event-driven reactor wherever the
/// poller exists, the portable thread-per-connection model elsewhere.
pub fn default_model() -> &'static str {
    if cfg!(unix) {
        "reactor"
    } else {
        "thread"
    }
}

/// Resolve `--model`/`--io-threads` into a [`ServerModel`].
pub fn server_model(args: &Args) -> Result<ServerModel> {
    let io_threads: usize = args.get_or("io-threads", 0usize);
    match args.get_str("model", default_model()) {
        "thread" => Ok(ServerModel::Thread),
        "reactor" => {
            if cfg!(unix) {
                Ok(ServerModel::Reactor { io_threads })
            } else {
                anyhow::bail!("--model reactor requires a Unix poller; use --model thread")
            }
        }
        other => anyhow::bail!("unknown --model '{other}' (expected reactor|thread)"),
    }
}

/// Build a [`CacheConfig`] from common options.
pub fn cache_config(args: &Args) -> CacheConfig {
    CacheConfig {
        mem_limit: args.get_or("mem-mb", 64usize) << 20,
        initial_buckets: args.get_or("buckets", 1024usize),
        load_factor: args.get_or("load-factor", 1.5f64),
        clock_max: args.get_or("clock-max", 3u8),
        lock_stripes: args.get_or("stripes", 16usize),
        evict_batch: args.get_or("evict-batch", 8u32),
        latency_sample: args.get_or("latency-sample", 64u32),
    }
}

/// Top-level dispatch. Returns the process exit code.
pub fn run(argv: Vec<String>) -> Result<i32> {
    let Some(sub) = argv.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(2);
    };
    let args = parse_args(&argv[1..]);
    match sub {
        "serve" => cmd_serve(&args),
        "bench" => cmd_bench(&args),
        "hit-ratio" => cmd_hit_ratio(&args),
        "planner-demo" => cmd_planner_demo(),
        "version" => {
            println!("fleec 0.1.0 — FLeeC reproduction (CS.DC 2024)");
            Ok(0)
        }
        _ => {
            print_usage();
            Ok(2)
        }
    }
}

fn print_usage() {
    eprintln!(
        "fleec — a fast lock-free application cache (paper reproduction)\n\
         \n\
         USAGE: fleec <subcommand> [options]\n\
         \n\
         serve         --engine fleec|oaflash|memcached|memclock --port 11211 --mem-mb 64\n\
                       [--buckets N] [--clock-max K] [--no-planner]\n\
                       [--shards N]  (engine instances behind the key-hash\n\
                                      router; rounded up to a power of two,\n\
                                      mem/buckets divided across shards)\n\
                       [--model reactor|thread]\n\
                                     (front-end: 'reactor' = event-driven — N\n\
                                      event-loop threads multiplex non-blocking\n\
                                      connections over epoll/poll, the default\n\
                                      on Unix; 'thread' = one blocking thread\n\
                                      per connection, the portable fallback)\n\
                       [--io-threads N]\n\
                                     (reactor threads; 0 = one per core)\n\
                       [--latency-sample N]\n\
                                     (time 1-in-N batches for the latency\n\
                                      histograms; 0 = off, 1 = every batch;\n\
                                      default 64 — see `stats latency`)\n\
                       [--metrics-addr HOST:PORT]\n\
                                     (serve Prometheus text exposition at\n\
                                      GET /metrics on this address)\n\
                       [--max-conns N]\n\
                                     (admission cap: shed accepts past N live\n\
                                      connections with SERVER_ERROR busy;\n\
                                      0 = unlimited, the default)\n\
                       [--conn-idle-timeout SECS]\n\
                                     (reap connections idle this long;\n\
                                      0 = never, the default)\n\
                       [--tenants]  (multi-tenant plane: per-connection\n\
                                     `tenant <name>` namespaces, per-tenant\n\
                                     accounting, `stats tenants`, and the\n\
                                     slab budget arbiter;\n\
                                     --no-arbiter keeps the static split)\n\
         bench         --engine all|<name> --alpha 0.99 --threads 8 --ops 200000\n\
                       [--catalog N] [--value-bytes N] [--read-ratio R] [--mem-mb N]\n\
                       [--batch N]  (ops per engine crossing; >1 uses execute_batch)\n\
                       [--shards N] (shard count for every engine under test)\n\
                       [--conns N]  (over-the-wire mode: serve in-process and\n\
                                     drive N TCP connections with pipelined\n\
                                     ops — --batch is the pipeline depth,\n\
                                     --ops the per-connection op count;\n\
                                     --model/--io-threads pick the front-end)\n\
                       [--read-timeout-ms N]\n\
                                     (wire mode: per-reply client read timeout;\n\
                                      timed-out connections are dropped and\n\
                                      counted, not fatal; 0 = wait forever)\n\
                       [--tenants N] (multi-tenant arbiter sweep: N tenants\n\
                                      with power-law footprints\n\
                                      [--tenant-skew S, default 1.0], same\n\
                                      deterministic workload with the arbiter\n\
                                      off then on; writes --out, default\n\
                                      BENCH_tenants.json)\n\
         hit-ratio     --alpha 0.99 --catalog 100000 --mem-mb 4 [--trace-len N]\n\
                       [--shards N] (splits mem/buckets per shard — changes eviction)\n\
         planner-demo  (load artifacts, run the planner once, print the decision)\n\
         version"
    );
}

fn cmd_serve(args: &Args) -> Result<i32> {
    let engine_name = args.get_str("engine", "fleec");
    let port: u16 = args.get_or("port", 11211u16);
    let shards: usize = args.get_or("shards", 1usize).max(1).next_power_of_two();
    let config = cache_config(args);
    let mut cache = build_sharded(engine_name, shards, config)?;

    // Multi-tenant plane: wrap the engine *before* the coordinator and
    // the server see it, so maintenance ticks arbitrate and every
    // connection gets tenant state.
    let tenants_on = args.has_flag("tenants") || args.options.contains_key("tenants");
    let mut plane = None;
    if tenants_on {
        use crate::cache::tenant::{PlaneConfig, TenantCache, TenantPlane};
        let p = TenantPlane::new(
            cache.as_ref(),
            PlaneConfig {
                arbiter: !args.has_flag("no-arbiter"),
            },
        );
        cache = Arc::new(TenantCache::new(cache, Arc::clone(&p)));
        plane = Some(p);
    }

    // Planner is best-effort: a serving cache must not require artifacts.
    let planner_dir = if args.has_flag("no-planner") {
        None
    } else {
        Some(artifacts_dir())
    };
    let _coordinator = Coordinator::start(
        Arc::clone(&cache),
        planner_dir,
        CoordinatorConfig::default(),
    );

    let model = server_model(args)?;
    let metrics_addr = match args.options.get("metrics-addr") {
        Some(s) => Some(s.parse()?),
        None => None,
    };
    let idle_secs: u64 = args.get_or("conn-idle-timeout", 0u64);
    let mut server = Server::start(
        ServerConfig {
            addr: format!("127.0.0.1:{port}").parse()?,
            model,
            drain_sample: args.get_or("latency-sample", 64u32),
            metrics_addr,
            max_conns: args.get_or("max-conns", 0usize),
            idle_timeout: (idle_secs > 0).then(|| Duration::from_secs(idle_secs)),
            tenants: plane,
            ..ServerConfig::default()
        },
        Arc::clone(&cache),
    )?;
    if let Some(m) = server.metrics_addr() {
        eprintln!("fleec metrics on http://{m}/metrics");
    }
    let model_desc = match model {
        ServerModel::Thread => "thread-per-connection".to_string(),
        ServerModel::Reactor { io_threads } => format!(
            "reactor x{} io-threads",
            crate::server::resolve_io_threads(io_threads)
        ),
    };
    eprintln!(
        "fleec serving engine={} on {} (mem limit {} MiB, {model_desc}{})",
        cache.engine_name(),
        server.addr(),
        cache.mem_limit() >> 20,
        if tenants_on {
            if args.has_flag("no-arbiter") {
                ", multi-tenant static"
            } else {
                ", multi-tenant arbiter"
            }
        } else {
            ""
        }
    );
    // Serve until SIGTERM/SIGINT, then drain gracefully: stop accepting,
    // flush buffered replies, close connections as they empty, hard-stop
    // at the deadline.
    sig::install();
    while !sig::termination_requested() {
        std::thread::sleep(Duration::from_millis(100));
    }
    eprintln!("fleec draining (deadline {}s)...", DRAIN_DEADLINE.as_secs());
    let clean = server.drain(DRAIN_DEADLINE);
    eprintln!(
        "fleec stopped ({})",
        if clean { "drained clean" } else { "drain deadline hit" }
    );
    Ok(0)
}

/// How long `fleec serve` waits for connections to drain after SIGTERM
/// before hard-stopping. Kubernetes-style supervisors default to 30s
/// grace; finishing well inside it avoids the SIGKILL race.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// Minimal Unix signal handling (the offline crate set has no signal
/// crate, and std exposes none): a `signal(2)` shim installing a handler
/// that records the request in an atomic the serve loop polls.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        /// `signal(2)`. Not `sigaction` — no struct layout to mirror, and
        /// one-shot semantics are irrelevant here (any delivery latches
        /// the flag forever).
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    /// Signal handler: must stay async-signal-safe — one atomic store,
    /// nothing else (no allocation, no locks, no stderr).
    extern "C" fn on_term(_signum: i32) {
        // ord: relaxed-ok — a monotonic latch polled by the serve loop;
        // it orders no other data, and the poll loop's 100ms cadence
        // dwarfs any propagation delay.
        TERM.store(true, Ordering::Relaxed);
    }

    /// Install the SIGTERM/SIGINT handlers (idempotent).
    pub fn install() {
        // SAFETY: `signal` is the C library's own prototype; `on_term`
        // is a valid `extern "C" fn(i32)` for the life of the process
        // (static item), and the handler body is async-signal-safe (one
        // relaxed atomic store). Failure (SIG_ERR) just leaves default
        // disposition — acceptable for a best-effort graceful path.
        unsafe {
            signal(SIGTERM, on_term);
            signal(SIGINT, on_term);
        }
    }

    /// Whether a termination signal has been delivered.
    pub fn termination_requested() -> bool {
        // ord: relaxed-ok — see the store side; a latch, nothing ordered.
        TERM.load(Ordering::Relaxed)
    }
}

/// Non-Unix stub: no signal shim; `fleec serve` runs until killed.
#[cfg(not(unix))]
mod sig {
    pub fn install() {}
    pub fn termination_requested() -> bool {
        false
    }
}

fn cmd_bench(args: &Args) -> Result<i32> {
    if args.get_or("conns", 0usize) > 0 {
        return cmd_bench_wire(args);
    }
    if args.get_or("tenants", 0usize) > 0 {
        return cmd_bench_tenants(args);
    }
    let spec = WorkloadSpec {
        catalog: args.get_or("catalog", 100_000u64),
        alpha: args.get_or("alpha", 0.99f64),
        read_ratio: args.get_or("read-ratio", 0.99f64),
        value_size: ValueSize::Fixed(args.get_or("value-bytes", 64usize)),
        seed: args.get_or("seed", 0xF1EE_C0DEu64),
    };
    let opts = DriverOptions {
        threads: args.get_or("threads", 8usize),
        stop: StopRule::OpsPerThread(args.get_or("ops", 200_000u64)),
        prefill: true,
        sample_every: args.get_or("sample-every", 4u64),
        validate: args.has_flag("validate"),
        batch: args.get_or("batch", 1usize),
    };
    let engine_sel = args.get_str("engine", "all");
    // Round the way the router does, so the printed topology is the one
    // that actually runs.
    let shards: usize = args.get_or("shards", 1usize).max(1).next_power_of_two();
    let engines: Vec<&str> = if engine_sel == "all" {
        ENGINES.to_vec()
    } else {
        vec![engine_sel]
    };
    println!(
        "# workload: alpha={} reads={} catalog={} value={:?} threads={} ops/thread={:?} batch={} shards={}",
        spec.alpha, spec.read_ratio, spec.catalog, spec.value_size, opts.threads, opts.stop,
        opts.batch, shards
    );
    let mut base_tput = None;
    for name in engines {
        let cache = build_sharded(name, shards, cache_config(args))?;
        let report = run_driver(&cache, &spec, &opts);
        let speedup = base_tput
            .map(|b: f64| report.throughput() / b)
            .unwrap_or(1.0);
        if base_tput.is_none() {
            base_tput = Some(report.throughput());
        }
        println!("{}  speedup={speedup:.2}x", report.row());
        if report.validation_failures > 0 {
            eprintln!("!! {} validation failures", report.validation_failures);
            return Ok(1);
        }
    }
    Ok(0)
}

/// `fleec bench --conns N`: serve the engine in-process (with the chosen
/// `--model` front-end) and drive it over loopback with N simultaneous
/// pipelined connections — the connection-scaling experiment.
fn cmd_bench_wire(args: &Args) -> Result<i32> {
    let spec = WorkloadSpec {
        catalog: args.get_or("catalog", 16_384u64),
        alpha: args.get_or("alpha", 0.99f64),
        read_ratio: args.get_or("read-ratio", 0.95f64),
        value_size: ValueSize::Fixed(args.get_or("value-bytes", 64usize)),
        seed: args.get_or("seed", 0xF1EE_C0DEu64),
    };
    let timeout_ms: u64 = args.get_or("read-timeout-ms", 0u64);
    let opts = WireOptions {
        conns: args.get_or("conns", 64usize),
        depth: args.get_or("batch", 16usize),
        ops_per_conn: args.get_or("ops", 10_000u64),
        workers: args.get_or("workers", 0usize),
        prefill: true,
        read_timeout: (timeout_ms > 0).then(|| Duration::from_millis(timeout_ms)),
    };
    let model = server_model(args)?;
    let shards: usize = args.get_or("shards", 1usize).max(1).next_power_of_two();
    let engine_sel = args.get_str("engine", "fleec");
    let engines: Vec<&str> = if engine_sel == "all" {
        ENGINES.to_vec()
    } else {
        vec![engine_sel]
    };
    println!(
        "# wire workload: conns={} depth={} ops/conn={} model={:?} shards={} alpha={} reads={}",
        opts.conns, opts.depth, opts.ops_per_conn, model, shards, spec.alpha, spec.read_ratio
    );
    for name in engines {
        let cache = build_sharded(name, shards, cache_config(args))?;
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse()?,
                model,
                ..ServerConfig::default()
            },
            Arc::clone(&cache),
        )?;
        let report = run_wire(server.addr(), &spec, &opts)?;
        println!("{:>10}  {}", cache.engine_name(), report.row());
    }
    Ok(0)
}

/// `fleec bench --tenants N [--tenant-skew S]`: the multi-tenant
/// arbiter sweep. Runs the identical deterministic workload twice —
/// static equal partition (arbiter off) vs. the Memshare-style arbiter —
/// prints both, and writes the machine-readable comparison to
/// `--out` (default `BENCH_tenants.json`, the CI artifact).
fn cmd_bench_tenants(args: &Args) -> Result<i32> {
    let spec = TenantBenchSpec {
        tenants: args.get_or("tenants", 4usize).clamp(2, 15),
        skew: args.get_or("tenant-skew", 1.0f64),
        catalog: args.get_or("catalog", 200_000u64),
        alpha: args.get_or("alpha", 0.99f64),
        read_ratio: args.get_or("read-ratio", 0.95f64),
        value_bytes: args.get_or("value-bytes", 256usize),
        ops: args.get_or("ops", 2_000_000u64),
        maintenance_every: args.get_or("maintenance-every", 4096u64),
        seed: args.get_or("seed", 0xF1EE_C0DEu64),
    };
    let engine_name = args.get_str("engine", "fleec");
    let engine_name = if engine_name == "all" { "fleec" } else { engine_name };
    let shards: usize = args.get_or("shards", 1usize).max(1).next_power_of_two();
    println!(
        "# tenant bench: engine={engine_name} shards={shards} tenants={} skew={} catalog={} alpha={} reads={} value={}B ops={}",
        spec.tenants, spec.skew, spec.catalog, spec.alpha, spec.read_ratio, spec.value_bytes,
        spec.ops
    );
    println!("# footprints (keys/tenant): {:?}", footprints(&spec));
    let mut reports = Vec::new();
    for arbiter in [false, true] {
        let cache = build_sharded(engine_name, shards, cache_config(args))?;
        let report = run_tenant_bench(&cache, &spec, arbiter);
        println!(
            "arbiter={:<5} aggregate_hit_ratio={:.4} moved_bytes={}",
            arbiter,
            report.hit_ratio(),
            report.moved_bytes
        );
        for row in &report.rows {
            let s = &row.snapshot;
            let ratio = if s.gets == 0 {
                0.0
            } else {
                s.hits as f64 / s.gets as f64
            };
            println!(
                "  {:<8} catalog={:<8} hit_ratio={ratio:.4} shadow_hits={:<8} live={}KiB budget={}KiB",
                s.name,
                row.catalog,
                s.shadow_hits,
                s.live_bytes >> 10,
                s.budget_bytes >> 10
            );
        }
        reports.push(report);
    }
    let json = render_tenant_json(engine_name, shards, &spec, &reports);
    let out_path = args.get_str("out", "BENCH_tenants.json").to_string();
    std::fs::write(&out_path, json)?;
    eprintln!("wrote {out_path}");
    Ok(0)
}

/// Hand-rolled JSON for the tenant sweep (offline crate set: no serde).
/// Every number is either an integer or a finite float, every string a
/// controlled identifier — no escaping needed.
fn render_tenant_json(
    engine: &str,
    shards: usize,
    spec: &TenantBenchSpec,
    reports: &[TenantBenchReport],
) -> String {
    use std::fmt::Write;
    let mut j = String::with_capacity(4096);
    let _ = write!(
        j,
        "{{\n  \"engine\": \"{engine}\",\n  \"shards\": {shards},\n  \"tenants\": {},\n  \"tenant_skew\": {},\n  \"catalog\": {},\n  \"alpha\": {},\n  \"read_ratio\": {},\n  \"value_bytes\": {},\n  \"ops\": {},\n  \"seed\": {},\n  \"runs\": [",
        spec.tenants,
        spec.skew,
        spec.catalog,
        spec.alpha,
        spec.read_ratio,
        spec.value_bytes,
        spec.ops,
        spec.seed
    );
    for (ri, r) in reports.iter().enumerate() {
        let _ = write!(
            j,
            "{}\n    {{\n      \"arbiter\": {},\n      \"aggregate_hit_ratio\": {:.6},\n      \"gets\": {},\n      \"hits\": {},\n      \"moved_bytes\": {},\n      \"per_tenant\": [",
            if ri == 0 { "" } else { "," },
            r.arbiter,
            r.hit_ratio(),
            r.gets,
            r.hits,
            r.moved_bytes
        );
        for (ti, row) in r.rows.iter().enumerate() {
            let s = &row.snapshot;
            let ratio = if s.gets == 0 {
                0.0
            } else {
                s.hits as f64 / s.gets as f64
            };
            let _ = write!(
                j,
                "{}\n        {{\"name\": \"{}\", \"catalog\": {}, \"gets\": {}, \"hits\": {}, \"hit_ratio\": {ratio:.6}, \"sets\": {}, \"shadow_hits\": {}, \"live_bytes\": {}, \"budget_bytes\": {}}}",
                if ti == 0 { "" } else { "," },
                s.name,
                row.catalog,
                s.gets,
                s.hits,
                s.sets,
                s.shadow_hits,
                s.live_bytes,
                s.budget_bytes
            );
        }
        let _ = write!(j, "\n      ]\n    }}");
    }
    j.push_str("\n  ]\n}\n");
    j
}

fn cmd_hit_ratio(args: &Args) -> Result<i32> {
    use crate::workload::Trace;
    let spec = WorkloadSpec {
        catalog: args.get_or("catalog", 100_000u64),
        alpha: args.get_or("alpha", 0.99f64),
        read_ratio: 0.99,
        value_size: ValueSize::Fixed(args.get_or("value-bytes", 64usize)),
        seed: args.get_or("seed", 7u64),
    };
    let trace_len = args.get_or("trace-len", 400_000usize);
    let trace = Trace::generate(&spec, trace_len);
    let shards: usize = args.get_or("shards", 1usize).max(1).next_power_of_two();
    println!(
        "# hit-ratio: alpha={} catalog={} mem-mb={} shards={}",
        spec.alpha,
        spec.catalog,
        args.get_or("mem-mb", 4usize),
        shards
    );
    for name in ENGINES {
        let cache = build_sharded(name, shards, cache_config(args))?;
        let report = crate::workload::driver::replay_trace(cache.as_ref(), &trace);
        println!(
            "{name:>10}: hit_ratio={:.4} (hits={} gets={})",
            report.0, report.1, report.2
        );
    }
    // Model column when artifacts exist.
    if let Ok(rt) = Runtime::new() {
        if let Ok(model) = HitRatioModule::load(&rt, &artifacts_dir()) {
            let items_fit = (args.get_or("mem-mb", 4usize) << 20) / (64 + 88);
            if let Ok(est) = model.run(spec.alpha as f32, items_fit as f32) {
                println!(
                    "     model: lru={:.4} fifo/clock={:.4} (capacity≈{items_fit} items)",
                    est.lru, est.fifo
                );
            }
        }
    }
    Ok(0)
}

fn cmd_planner_demo() -> Result<i32> {
    let rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());
    let planner = PlannerModule::load(&rt, &artifacts_dir())?;
    // Simulated warm table, moderate pressure.
    let mut clocks = [0i32; crate::runtime::PLANNER_SNAPSHOT];
    for (i, c) in clocks.iter_mut().enumerate() {
        *c = (i % 4) as i32;
    }
    let decision = planner.run(&clocks, 0.4)?;
    println!("planner decision: {decision:?}");
    let model = HitRatioModule::load(&rt, &artifacts_dir())?;
    for alpha in [0.5f32, 0.9, 0.99, 1.2] {
        let est = model.run(alpha, 10_000.0)?;
        println!("hit-ratio model alpha={alpha}: lru={:.4} fifo={:.4}", est.lru, est.fifo);
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args_of(s: &[&str]) -> Args {
        parse_args(&s.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = args_of(&["--engine", "fleec", "--validate", "pos1", "--ops", "5"]);
        assert_eq!(a.get_str("engine", "x"), "fleec");
        assert!(a.has_flag("validate"));
        assert_eq!(a.get_or("ops", 0u64), 5);
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get_or("missing", 9u32), 9);
    }

    #[test]
    fn cache_config_from_args() {
        let a = args_of(&["--mem-mb", "8", "--clock-max", "7"]);
        let c = cache_config(&a);
        assert_eq!(c.mem_limit, 8 << 20);
        assert_eq!(c.clock_max, 7);
        assert_eq!(c.load_factor, 1.5);
    }

    #[test]
    fn unknown_subcommand_exits_2() {
        assert_eq!(run(vec!["nope".into()]).unwrap(), 2);
    }
}
