//! Per-tenant slab accounting and page-budget words — the substrate the
//! Memshare-style arbiter ([`crate::cache::tenant`]) steers.
//!
//! The multi-tenant plane needs three things from the allocator, none of
//! which may slow the single-tenant fast path:
//!
//! 1. **Attribution**: how many live bytes (and per-size-class chunks)
//!    each tenant holds. Allocation attributes to the *calling thread's*
//!    current tenant (a thread-local set by the server's drain loop
//!    around batch execution); frees attribute via the tenant byte the
//!    item header carries, because EBR reclamation runs on whichever
//!    thread happens to flush the deferral queue, long after the
//!    allocating connection moved on.
//! 2. **Budget words**: one soft page-budget per tenant that the arbiter
//!    moves between tenants. A budget of `0` means *unlimited* — the
//!    default tenant starts there, so a tenant-less server (or one where
//!    the arbiter never ran) is budget-transparent.
//! 3. **A gate**: with tenancy disabled (every slab built by a plain
//!    `serve`), the only cost on the alloc/free path is one relaxed
//!    load and a predictable branch.
//!
//! Everything here is stats-grade relaxed atomics: the counters steer
//! eviction and arbitration heuristics, they are not synchronization
//! edges. Chunk ownership itself still publishes through the free lists'
//! and item words' orderings (see `rust/docs/concurrency.md`).

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Hard cap on concurrently registered tenants per process. Small and
/// fixed so every accounting structure is a flat array of atomics —
/// no resizing, no locks on the data plane.
pub const MAX_TENANTS: usize = 16;

/// Tenant id of connections that never issued `tenant <name>`.
pub const DEFAULT_TENANT: u8 = 0;

thread_local! {
    /// The tenant the calling thread is currently executing for.
    /// Set by the server's drain loop around batch execution; read by
    /// `Item::alloc` to stamp and attribute fresh items.
    static CURRENT: Cell<u8> = const { Cell::new(DEFAULT_TENANT) };
}

/// Set the calling thread's current tenant (see [`CURRENT`]).
#[inline]
pub fn set_current(tenant: u8) {
    CURRENT.with(|c| c.set(tenant));
}

/// The calling thread's current tenant id.
#[inline]
pub fn current() -> u8 {
    CURRENT.with(|c| c.get())
}

/// One tenant's accounting snapshot (stats / arbiter input).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantUsage {
    /// Item bytes currently attributed to the tenant (footprint of the
    /// chunks it holds, at chunk granularity).
    pub live_bytes: usize,
    /// Soft page budget (0 = unlimited / unenforced).
    pub budget_bytes: usize,
    /// Chunks ever handed to the tenant (monotonic).
    pub handed_chunks: u64,
    /// Chunks the tenant returned (monotonic).
    pub freed_chunks: u64,
}

/// One tenant's per-size-class row, riding [`super::SizeClassStats`]'
/// shape: `live = handed - freed`, in chunks of `chunk_size`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantClassStats {
    pub chunk_size: usize,
    pub handed_chunks: u64,
    pub freed_chunks: u64,
    pub live_chunks: u64,
}

/// The per-slab tenant accounting table. All flat atomics; the `enabled`
/// gate keeps the disabled path at one relaxed load.
pub(super) struct TenantTable {
    enabled: AtomicBool,
    /// Soft byte budgets, `0` = unlimited.
    budgets: [AtomicUsize; MAX_TENANTS],
    /// Live chunk bytes attributed per tenant.
    live_bytes: [AtomicUsize; MAX_TENANTS],
    /// Monotonic handed/freed chunk counters, `tenant * n_classes +
    /// class` — the per-tenant mirror of `SizeClass::handed`.
    handed: Box<[AtomicU64]>,
    freed: Box<[AtomicU64]>,
    n_classes: usize,
}

impl TenantTable {
    pub(super) fn new(n_classes: usize) -> Self {
        let cells = MAX_TENANTS * n_classes;
        TenantTable {
            enabled: AtomicBool::new(false),
            budgets: std::array::from_fn(|_| AtomicUsize::new(0)),
            live_bytes: std::array::from_fn(|_| AtomicUsize::new(0)),
            handed: (0..cells).map(|_| AtomicU64::new(0)).collect(),
            freed: (0..cells).map(|_| AtomicU64::new(0)).collect(),
            n_classes,
        }
    }

    #[inline]
    pub(super) fn enable(&self) {
        // ord: relaxed-ok — a pure on/off gate for stats-grade counters;
        // callers that race the flip merely miss a few early notes.
        self.enabled.store(true, Ordering::Relaxed);
    }

    #[inline]
    pub(super) fn enabled(&self) -> bool {
        // ord: relaxed-ok — see enable(); the disabled fast path is one
        // relaxed load + branch by design.
        self.enabled.load(Ordering::Relaxed)
    }

    #[inline]
    pub(super) fn note_alloc(&self, tenant: u8, class: u8, chunk_bytes: usize) {
        let t = tenant as usize % MAX_TENANTS;
        // ord: relaxed-ok — stats-grade attribution counters; ownership
        // of the chunk publishes through the allocator, not these.
        self.live_bytes[t].fetch_add(chunk_bytes, Ordering::Relaxed);
        // ord: relaxed-ok — monotonic stats counter, same as above.
        self.handed[t * self.n_classes + class as usize].fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub(super) fn note_free(&self, tenant: u8, class: u8, chunk_bytes: usize) {
        let t = tenant as usize % MAX_TENANTS;
        // ord: relaxed-ok — see note_alloc; saturation below guards the
        // (startup-race) case of a free noted without its alloc.
        let mut live = self.live_bytes[t].load(Ordering::Relaxed);
        loop {
            let next = live.saturating_sub(chunk_bytes);
            // ord: relaxed-ok — stats-grade CAS, no payload published.
            match self.live_bytes[t].compare_exchange_weak(
                live,
                next,
                Ordering::Relaxed, // ord: relaxed-ok — stats-grade CAS
                Ordering::Relaxed, // ord: relaxed-ok — failure re-load, same
            ) {
                Ok(_) => break,
                Err(cur) => live = cur,
            }
        }
        // ord: relaxed-ok — monotonic stats counter.
        self.freed[t * self.n_classes + class as usize].fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub(super) fn budget(&self, tenant: u8) -> usize {
        // ord: relaxed-ok — soft-limit heuristic read.
        self.budgets[tenant as usize % MAX_TENANTS].load(Ordering::Relaxed)
    }

    #[inline]
    pub(super) fn live(&self, tenant: u8) -> usize {
        // ord: relaxed-ok — stats snapshot.
        self.live_bytes[tenant as usize % MAX_TENANTS].load(Ordering::Relaxed)
    }

    pub(super) fn set_budget(&self, tenant: u8, bytes: usize) {
        // ord: relaxed-ok — soft limit consumed by heuristic reads.
        self.budgets[tenant as usize % MAX_TENANTS].store(bytes, Ordering::Relaxed);
    }

    /// Move up to `bytes` of budget from `from` to `to`, never shrinking
    /// the donor below `floor`. Returns the bytes actually moved. A
    /// donor at `0` (unlimited) donates nothing — unlimited is not a
    /// balance to draw down.
    pub(super) fn move_budget(&self, from: u8, to: u8, bytes: usize, floor: usize) -> usize {
        let f = from as usize % MAX_TENANTS;
        let t = to as usize % MAX_TENANTS;
        if f == t {
            return 0;
        }
        // ord: relaxed-ok — budget words are advisory soft limits; the
        // CAS only needs atomicity (no torn donation), not ordering.
        let mut cur = self.budgets[f].load(Ordering::Relaxed);
        let moved = loop {
            if cur == 0 || cur <= floor {
                return 0;
            }
            let new = cur.saturating_sub(bytes).max(floor);
            let moved = cur - new;
            // ord: relaxed-ok — see the load above.
            match self.budgets[f].compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed, // ord: relaxed-ok — advisory budget CAS
                Ordering::Relaxed, // ord: relaxed-ok — failure re-load, same
            ) {
                Ok(_) => break moved,
                Err(now) => cur = now,
            }
        };
        // ord: relaxed-ok — advisory credit; pairs with nothing.
        self.budgets[t].fetch_add(moved, Ordering::Relaxed);
        moved
    }

    pub(super) fn usage(&self, tenant: u8) -> TenantUsage {
        let t = tenant as usize % MAX_TENANTS;
        let base = t * self.n_classes;
        let mut handed = 0u64;
        let mut freed = 0u64;
        for c in 0..self.n_classes {
            // ord: relaxed-ok — stats snapshot, tolerates skew between
            // cells read at different instants.
            handed += self.handed[base + c].load(Ordering::Relaxed);
            // ord: relaxed-ok — same stats snapshot.
            freed += self.freed[base + c].load(Ordering::Relaxed);
        }
        TenantUsage {
            live_bytes: self.live(tenant),
            budget_bytes: self.budget(tenant),
            handed_chunks: handed,
            freed_chunks: freed,
        }
    }

    pub(super) fn class_row(&self, tenant: u8, class: usize, chunk_size: usize) -> TenantClassStats {
        let base = (tenant as usize % MAX_TENANTS) * self.n_classes;
        // ord: relaxed-ok — stats snapshot; see usage().
        let handed = self.handed[base + class].load(Ordering::Relaxed);
        // ord: relaxed-ok — same stats snapshot.
        let freed = self.freed[base + class].load(Ordering::Relaxed);
        TenantClassStats {
            chunk_size,
            handed_chunks: handed,
            freed_chunks: freed,
            live_chunks: handed.saturating_sub(freed),
        }
    }
}
