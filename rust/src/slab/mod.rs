//! Slab allocator — Memcached's third core structure, with a privatized
//! fast path.
//!
//! Items are allocated from size classes whose chunk sizes grow by a
//! ×1.25 factor (Memcached's default `-f 1.25`), carved out of 1 MiB
//! pages. The total page budget is fixed up front (`-m` in Memcached);
//! when it is exhausted and a class' free list is empty, [`Slab::alloc`]
//! returns `None` — that is the *memory pressure* signal that drives both
//! the EBR collector ([`crate::ebr::Collector::request_reclaim`]) and the
//! CLOCK eviction hand.
//!
//! Concurrency, in three tiers:
//!
//! 1. **Per-thread magazines** ([`magazine`]) — steady-state `alloc` and
//!    `free` touch only a thread-local array of up to [`MAG_CAP`] chunk
//!    pointers: zero shared atomics, zero contention.
//! 2. **Segment free lists** ([`SizeClass`]) — magazines refill/flush in
//!    whole segments, one version-tagged Treiber CAS per ~[`MAG_CAP`]
//!    chunks; bump allocation batch-claims with one CAS.
//! 3. **Page refill** (once per MiB of growth) takes a mutex, matching
//!    the paper's scope: FLeeC re-designs the hash table, eviction and
//!    reclamation; the slab keeps Memcached's design with lock-free (now
//!    mostly *lock-free-free*) fast paths.
//!
//! Accounting stays truthful with chunks parked privately:
//! [`Slab::class_stats`]/[`Slab::utilization`] count magazine residents
//! as free (each registration publishes its magazine lengths into a slot
//! table), and [`Slab::exhausted`] flushes the calling thread's magazines
//! before reporting pressure so parked chunks become globally reusable
//! right when it matters.
//!
//! Pressure also reaches *other* threads' magazines: allocation failure
//! raises a flush-request epoch ([`Slab::request_magazine_flush`]) that
//! every registered thread checks on its next magazine op and honors by
//! flushing everything it parked. This closes the privatization blind
//! spot where chunks parked by threads with no traffic of their own
//! stayed invisible to a thread starving under pressure.
//!
//! [`Slab::new`] returns `Arc<Slab>`: thread registrations hold a
//! `Weak<Slab>` so a departing thread can flush its magazines iff the
//! slab still exists (and never dangles if it doesn't).

mod class;
mod magazine;
pub mod tenant;

pub use class::{SizeClass, SizeClassStats};
pub use magazine::MAG_CAP;
pub use tenant::{TenantClassStats, TenantUsage, DEFAULT_TENANT, MAX_TENANTS};

use std::alloc::{alloc, dealloc, Layout};
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::metrics::ShardedCounter;

/// Slab tuning; defaults mirror Memcached's.
#[derive(Debug, Clone)]
pub struct SlabConfig {
    /// Total memory budget in bytes (Memcached `-m`, default 64 MiB).
    pub mem_limit: usize,
    /// Page size carved into chunks (Memcached: 1 MiB).
    pub page_size: usize,
    /// Smallest chunk size.
    pub base_chunk: usize,
    /// Geometric growth factor between classes (Memcached `-f`).
    pub growth: f64,
    /// Largest item size the slab will serve.
    pub max_chunk: usize,
}

impl Default for SlabConfig {
    fn default() -> Self {
        SlabConfig {
            mem_limit: 64 << 20,
            page_size: 1 << 20,
            base_chunk: 64,
            growth: 1.25,
            max_chunk: 1 << 20,
        }
    }
}

impl SlabConfig {
    /// A small-budget config used across tests.
    pub fn small(mem_limit: usize) -> Self {
        SlabConfig {
            mem_limit,
            page_size: 64 << 10,
            ..Self::default()
        }
    }
}

/// One allocated page (so Drop can return it to the OS).
struct Page {
    ptr: *mut u8,
    layout: Layout,
}

// SAFETY: a Page is just an owned allocation handle (ptr + layout); the
// chunks inside are handed out under the slab's own synchronization.
unsafe impl Send for Page {}

/// The slab allocator.
pub struct Slab {
    classes: Box<[SizeClass]>,
    config: SlabConfig,
    /// Bytes of page budget not yet claimed.
    budget_left: AtomicUsize,
    /// All pages ever allocated (freed on drop). Cold path.
    pages: Mutex<Vec<Page>>,
    /// Published per-thread magazine lengths (stats truthfulness).
    depot: magazine::SlotTable,
    /// Pressure-raised flush-request epoch (see module docs). Registered
    /// threads compare it against their last-seen value on every magazine
    /// op and flush their parked chunks when it moved.
    flush_epoch: AtomicU32,
    /// Observability: allocations served straight from the calling
    /// thread's magazine (the zero-shared-CAS fast path). Stats-grade
    /// striped relaxed counter.
    magazine_hits: ShardedCounter,
    /// Observability: allocations that fell through to the shared
    /// structures (magazine refill or slot-less direct alloc).
    shared_refills: ShardedCounter,
    /// Observability: flush-request epochs honored by registered threads
    /// (each count is one thread publishing its parked chunks).
    flushes_honored: ShardedCounter,
    /// Per-tenant accounting + budget words (multi-tenant plane); a
    /// single gated relaxed load when tenancy is off.
    tenants: tenant::TenantTable,
    /// Own-`Arc` handle for magazine registrations (see module docs).
    self_weak: Weak<Slab>,
}

// SAFETY: all shared state is atomics, lock-free structures, or behind
// the pages mutex; the raw page pointers are only dereferenced through
// the size classes' synchronized hand-out paths.
unsafe impl Send for Slab {}
// SAFETY: see Send above — every &self entry point is either lock-free
// (classes, depot) or takes the pages mutex.
unsafe impl Sync for Slab {}

impl Slab {
    /// Build the class table for `config`.
    pub fn new(config: SlabConfig) -> Arc<Self> {
        assert!(config.base_chunk >= 16 && config.base_chunk % 8 == 0);
        assert!(config.growth > 1.0);
        assert!(config.page_size >= config.base_chunk);
        let mut sizes = Vec::new();
        let mut size = config.base_chunk;
        while size <= config.max_chunk.min(config.page_size) {
            sizes.push(size);
            let next = ((size as f64 * config.growth) as usize + 7) & !7;
            size = next.max(size + 8);
        }
        let classes = sizes
            .into_iter()
            .map(SizeClass::new)
            .collect::<Vec<_>>()
            .into_boxed_slice();
        let depot = magazine::SlotTable::new(classes.len());
        let tenants = tenant::TenantTable::new(classes.len());
        Arc::new_cyclic(|self_weak| Slab {
            budget_left: AtomicUsize::new(config.mem_limit),
            tenants,
            classes,
            config,
            pages: Mutex::new(Vec::new()),
            depot,
            flush_epoch: AtomicU32::new(0),
            magazine_hits: ShardedCounter::new(),
            shared_refills: ShardedCounter::new(),
            flushes_honored: ShardedCounter::new(),
            self_weak: self_weak.clone(),
        })
    }

    /// Number of size classes.
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// The class whose chunks fit `size`, or `None` if the item is too big.
    pub fn class_for(&self, size: usize) -> Option<u8> {
        // Classes are sorted; linear scan is fine (≤ ~50 classes) but a
        // partition point is cheaper on the hot path.
        let idx = self.classes.partition_point(|c| c.chunk_size() < size);
        if idx < self.classes.len() {
            Some(idx as u8)
        } else {
            None
        }
    }

    /// Chunk size of a class.
    pub fn chunk_size(&self, class: u8) -> usize {
        self.classes[class as usize].chunk_size()
    }

    /// Allocate a chunk that fits `size`. Returns `(ptr, class)` or `None`
    /// under memory pressure (caller should reclaim/evict and retry).
    ///
    /// Fast path: the calling thread's magazine — no shared atomics at
    /// all. On a magazine miss, one segment pop refills up to [`MAG_CAP`]
    /// chunks; only page growth takes a lock.
    // audit:allow(guard) hands out an exclusively-owned free chunk, not
    // guard-lent memory — byte stability is the *caller's* story (items
    // become guard-stable only once published, see cache/fleec/node.rs).
    pub fn alloc(&self, size: usize) -> Option<(*mut u8, u8)> {
        // Failpoint `slab.alloc` (chaos tests): an injected failure is
        // indistinguishable from real exhaustion — it raises the
        // flush-request epoch and returns `None`, driving callers down
        // their reclamation/eviction/OOM paths.
        if crate::faults::fail("slab.alloc") {
            self.request_magazine_flush();
            return None;
        }
        let class = self.class_for(size)?;
        let sc = &self.classes[class as usize];
        if let Some(local) = magazine::local(self) {
            if local.active() {
                if let Some(ptr) = local.pop(self, class) {
                    self.magazine_hits.inc();
                    return Some((ptr, class));
                }
                loop {
                    if let Some(ptr) = local.refill_and_pop(self, class) {
                        self.shared_refills.inc();
                        return Some((ptr, class));
                    }
                    // Shared structures empty: try to claim a fresh page.
                    if !self.grow_class(sc) {
                        self.request_magazine_flush();
                        return None;
                    }
                }
            }
        }
        // No magazine (slot table full / thread teardown): shared path.
        loop {
            if let Some(ptr) = sc.try_alloc() {
                self.shared_refills.inc();
                return Some((ptr, class));
            }
            if !self.grow_class(sc) {
                self.request_magazine_flush();
                return None;
            }
        }
    }

    /// Return a chunk to its class (magazine-first; shared segment push on
    /// overflow).
    ///
    /// # Safety
    /// `ptr` must have come from [`Slab::alloc`] with the same `class` and
    /// not be referenced anywhere (a grace period must have elapsed).
    pub unsafe fn free(&self, ptr: *mut u8, class: u8) {
        if let Some(local) = magazine::local(self) {
            if local.active() {
                local.push(self, class, ptr);
                return;
            }
        }
        self.classes[class as usize].free(ptr);
    }

    /// Claim one page of budget for `sc`. Returns false when the budget is
    /// exhausted (= memory pressure).
    fn grow_class(&self, sc: &SizeClass) -> bool {
        // Reserve budget first (lock-free).
        let page = self.config.page_size;
        // ord: relaxed-ok — optimistic read; the CAS below revalidates.
        let mut left = self.budget_left.load(Ordering::Relaxed);
        loop {
            if left < page {
                return false;
            }
            match self.budget_left.compare_exchange_weak(
                left,
                left - page,
                // ord: AcqRel budget claim — Acquire sees a failed
                // claimer's Release refund below; Release publishes the
                // debit to other claimers' Acquire loads/CAS.
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(cur) => left = cur,
            }
        }
        // Allocate and install the page (cold path, mutex inside malloc
        // anyway). 64-byte alignment so chunks never straddle cache lines
        // at smaller-than-line sizes.
        let layout = Layout::from_size_align(page, 64).expect("page layout");
        // SAFETY: `layout` has non-zero size (page_size ≥ base_chunk ≥ 16)
        // and valid 64-byte alignment; null is handled below.
        let ptr = unsafe { alloc(layout) };
        if ptr.is_null() {
            // ord: Release refund; Acquire counterpart: the claim CAS
            // above in other threads.
            self.budget_left.fetch_add(page, Ordering::Release);
            return false;
        }
        self.pages.lock().unwrap().push(Page { ptr, layout });
        sc.install_page(ptr, page);
        true
    }

    /// Total byte budget.
    pub fn mem_limit(&self) -> usize {
        self.config.mem_limit
    }

    /// Page size — the budget-claim granule, the tenant-budget floor,
    /// and the arbiter's move quantum.
    pub fn page_size(&self) -> usize {
        self.config.page_size
    }

    /// Bytes of page budget already claimed by pages. Page-granular, so
    /// magazines (chunk-granular) cannot distort it.
    pub fn claimed_bytes(&self) -> usize {
        // ord: relaxed-ok — stats snapshot; page installs it races with
        // are already only eventually visible to callers.
        self.config.mem_limit - self.budget_left.load(Ordering::Relaxed)
    }

    /// Whether the page budget is fully claimed (chunk-level reuse only).
    ///
    /// Before reporting exhaustion, the calling thread's magazines are
    /// flushed to the shared free lists: chunks parked privately are
    /// *free* memory, and publishing them right at the pressure boundary
    /// keeps the signal honest — pressure handlers (reclaim, eviction)
    /// only run when chunk-level reuse genuinely cannot be served from
    /// what this thread already has.
    pub fn exhausted(&self) -> bool {
        // ord: relaxed-ok — pressure heuristic; a stale read only delays
        // or hastens a reclaim round, never breaks safety.
        if self.budget_left.load(Ordering::Relaxed) >= self.config.page_size {
            return false;
        }
        self.flush_local_magazines();
        self.request_magazine_flush();
        true
    }

    /// Ask every registered thread to flush its magazines at its next
    /// opportunity (its next alloc/free against this slab).
    ///
    /// Magazines are thread-local, so a starving thread cannot drain them
    /// directly; raising the epoch makes every *active* thread publish its
    /// parked chunks promptly. Truly idle threads still hold theirs until
    /// they run again or exit (bounded by [`MAG_CAP`] chunks per class
    /// per idle thread). Called automatically whenever [`Slab::alloc`]
    /// fails or [`Slab::exhausted`] reports pressure; pressure handlers
    /// (eviction, EBR reclaim drivers) may also call it directly.
    pub fn request_magazine_flush(&self) {
        // ord: relaxed-ok — advisory counter; the flushes it triggers
        // publish through the free lists' Release CASes.
        self.flush_epoch.fetch_add(1, Ordering::Relaxed);
    }

    /// Return every chunk parked in the *calling thread's* magazines to
    /// the shared free lists (no-op for threads that never allocated).
    pub fn flush_local_magazines(&self) {
        if let Some(local) = magazine::local_existing(self) {
            local.flush_all(self);
        }
    }

    /// Live-chunk utilization estimate in [0,1] over the claimed budget.
    /// Magazine-resident chunks count as free.
    pub fn utilization(&self) -> f64 {
        let claimed = self.claimed_bytes();
        if claimed == 0 {
            return 0.0;
        }
        let live: usize = self
            .class_stats()
            .iter()
            .map(|c| c.live_chunks * c.chunk_size)
            .sum();
        live as f64 / claimed as f64
    }

    /// Per-class statistics snapshot: `live_chunks` excludes (and
    /// `cached_chunks` reports) chunks parked in thread magazines.
    pub fn class_stats(&self) -> Vec<SizeClassStats> {
        self.classes
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let mut s = c.stats();
                let cached = self.depot.cached(i);
                s.cached_chunks = cached;
                // Saturating: `handed` and the published lengths are
                // updated non-atomically with respect to each other, so a
                // racy snapshot may transiently observe the flush before
                // the length update.
                s.live_chunks = s.live_chunks.saturating_sub(cached);
                s
            })
            .collect()
    }

    /// Allocations served straight from a thread magazine (stats).
    pub fn magazine_hits(&self) -> u64 {
        self.magazine_hits.get()
    }

    /// Allocations that went through the shared structures (stats).
    pub fn shared_refills(&self) -> u64 {
        self.shared_refills.get()
    }

    /// Flush-request epochs honored by registered threads (stats).
    pub fn flushes_honored(&self) -> u64 {
        self.flushes_honored.get()
    }

    /// Shared-structure transfer count for the class serving `size`
    /// (debug builds; 0 in release). Test hook for the zero-shared-CAS
    /// steady-state property.
    pub fn shared_ops_for(&self, size: usize) -> usize {
        self.class_for(size)
            .map(|c| self.classes[c as usize].shared_ops())
            .unwrap_or(0)
    }

    // ---------------------------------------------------------------
    // Multi-tenant plane (see [`tenant`] module docs). All of these are
    // stats-grade relaxed accounting plus soft budget words; chunk
    // ownership still flows through the allocator's own orderings.
    // ---------------------------------------------------------------

    /// Turn on per-tenant accounting. Until this is called every tenant
    /// hook below is a no-op costing one relaxed load.
    pub fn enable_tenancy(&self) {
        self.tenants.enable();
    }

    /// Whether per-tenant accounting is on.
    #[inline]
    pub fn tenancy_enabled(&self) -> bool {
        self.tenants.enabled()
    }

    /// Attribute a freshly handed chunk of `class` to `tenant`. Called
    /// by the item layer right after a successful [`Slab::alloc`].
    #[inline]
    pub fn note_tenant_alloc(&self, tenant: u8, class: u8) {
        if self.tenants.enabled() {
            self.tenants
                .note_alloc(tenant, class, self.chunk_size(class));
        }
    }

    /// Unwind [`Slab::note_tenant_alloc`] when the chunk returns. Called
    /// by the item layer right before [`Slab::free`], with the tenant
    /// byte read back from the item header (frees run on whichever
    /// thread EBR reclamation lands on).
    #[inline]
    pub fn note_tenant_free(&self, tenant: u8, class: u8) {
        if self.tenants.enabled() {
            self.tenants
                .note_free(tenant, class, self.chunk_size(class));
        }
    }

    /// Set a tenant's soft byte budget (`0` = unlimited).
    pub fn set_tenant_budget(&self, tenant: u8, bytes: usize) {
        self.tenants.set_budget(tenant, bytes);
    }

    /// A tenant's soft byte budget (`0` = unlimited).
    pub fn tenant_budget(&self, tenant: u8) -> usize {
        self.tenants.budget(tenant)
    }

    /// Live chunk bytes currently attributed to a tenant.
    pub fn tenant_live_bytes(&self, tenant: u8) -> usize {
        self.tenants.live(tenant)
    }

    /// Whether storing `add` more bytes would put `tenant` over its soft
    /// budget — the eviction-steering signal: an over-budget tenant must
    /// evict from itself before drawing on the shared pool, and a tenant
    /// at its floor with nothing of its own left to evict is the one that
    /// sees per-tenant OOM while other tenants keep storing.
    #[inline]
    pub fn tenant_must_yield(&self, tenant: u8, add: usize) -> bool {
        if !self.tenants.enabled() {
            return false;
        }
        let budget = self.tenants.budget(tenant);
        budget != 0 && self.tenants.live(tenant).saturating_add(add) > budget
    }

    /// Arbiter hook: move up to `bytes` of soft budget from `from` to
    /// `to` (donor floor: one page), then raise the flush-request epoch
    /// so chunks the shrinking tenant's traffic parked in *other*
    /// threads' magazines are published immediately — the taker should
    /// be able to use the surrendered memory on its next allocation, not
    /// after the donor's next natural pressure event. Returns the bytes
    /// actually moved.
    pub fn move_tenant_budget(&self, from: u8, to: u8, bytes: usize) -> usize {
        let moved = self
            .tenants
            .move_budget(from, to, bytes, self.config.page_size);
        if moved > 0 {
            self.request_magazine_flush();
        }
        moved
    }

    /// Accounting snapshot for one tenant.
    pub fn tenant_usage(&self, tenant: u8) -> TenantUsage {
        self.tenants.usage(tenant)
    }

    /// Per-size-class rows for one tenant (the per-tenant mirror of
    /// [`Slab::class_stats`]); classes the tenant never touched are
    /// omitted.
    pub fn tenant_class_stats(&self, tenant: u8) -> Vec<TenantClassStats> {
        (0..self.classes.len())
            .map(|c| {
                self.tenants
                    .class_row(tenant, c, self.classes[c].chunk_size())
            })
            .filter(|row| row.handed_chunks > 0)
            .collect()
    }
}

impl Drop for Slab {
    fn drop(&mut self) {
        // Debug-build chunk conservation: every chunk ever carved from a
        // page is either outside the shared structures (user-live or
        // magazine-parked — `handed`) or still reachable from the free
        // lists / bump region. Draining the shared side and comparing
        // against the carve counter catches lost chunks, double frees and
        // accounting drift *semantically*, where a sanitizer would only
        // see the byte-level symptom (if any).
        #[cfg(debug_assertions)]
        for (i, sc) in self.classes.iter().enumerate() {
            let outside = sc.stats().live_chunks;
            let mut drained: Vec<*mut u8> = Vec::new();
            loop {
                // SAFETY: `&mut self` in drop — no other thread can touch
                // the free lists; drained chunks are owned until the page
                // dealloc below.
                let got = unsafe { sc.alloc_batch(&mut drained, 1024) };
                if got == 0 {
                    break;
                }
            }
            // Draining the bump region carves fresh chunks (bumping the
            // counters), so read `total` after the drain.
            let total = sc.stats().total_chunks;
            assert_eq!(
                outside + drained.len(),
                total,
                "size class {i}: chunk conservation violated \
                 (handed-out {outside} + shared-free {} != carved {total})",
                drained.len()
            );
        }
        for page in self.pages.get_mut().unwrap().drain(..) {
            // SAFETY: `ptr`/`layout` came from `alloc` in grow_class and
            // each page is deallocated exactly once (drain).
            unsafe { dealloc(page.ptr, page.layout) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn class_table_matches_growth_factor() {
        let slab = Slab::new(SlabConfig::default());
        let stats = slab.class_stats();
        assert!(stats.len() > 10);
        assert_eq!(stats[0].chunk_size, 64);
        for w in stats.windows(2) {
            assert!(w[1].chunk_size > w[0].chunk_size);
            // 1.25 nominal + 8-byte alignment rounding on small classes.
            let ratio = w[1].chunk_size as f64 / w[0].chunk_size as f64;
            assert!(ratio <= 1.35, "growth ratio {ratio} too large");
        }
    }

    #[test]
    fn class_for_picks_smallest_fitting() {
        let slab = Slab::new(SlabConfig::default());
        let c = slab.class_for(64).unwrap();
        assert_eq!(slab.chunk_size(c), 64);
        let c = slab.class_for(65).unwrap();
        assert!(slab.chunk_size(c) >= 65);
        assert!(slab.class_for(usize::MAX).is_none());
    }

    #[test]
    fn alloc_free_reuses_chunks() {
        let slab = Slab::new(SlabConfig::small(256 << 10));
        let (p1, c1) = slab.alloc(100).unwrap();
        unsafe { slab.free(p1, c1) };
        let (p2, c2) = slab.alloc(100).unwrap();
        assert_eq!(c1, c2);
        assert_eq!(p1, p2, "freed chunk must be reused (LIFO)");
    }

    #[test]
    fn budget_exhaustion_returns_none_until_free() {
        let slab = Slab::new(SlabConfig {
            mem_limit: 64 << 10,
            page_size: 64 << 10,
            base_chunk: 1024,
            growth: 1.25,
            max_chunk: 8192,
        });
        let mut held = Vec::new();
        while let Some(got) = slab.alloc(1024) {
            held.push(got);
        }
        assert!(!held.is_empty());
        assert!(slab.exhausted());
        assert!(slab.alloc(1024).is_none(), "budget gone, free list empty");
        let (p, c) = held.pop().unwrap();
        unsafe { slab.free(p, c) };
        assert!(slab.alloc(1024).is_some(), "freeing re-enables allocation");
    }

    #[test]
    fn distinct_allocations_do_not_overlap() {
        let slab = Slab::new(SlabConfig::small(512 << 10));
        let mut seen = HashSet::new();
        let mut held = Vec::new();
        for _ in 0..1000 {
            let (p, c) = slab.alloc(48).unwrap();
            let sz = slab.chunk_size(c);
            assert!(seen.insert(p as usize), "duplicate chunk");
            // Touch the whole chunk to catch overlap under ASAN-ish logic.
            unsafe { std::ptr::write_bytes(p, 0xAB, sz) };
            held.push((p, c));
        }
        for (p, c) in held {
            unsafe { slab.free(p, c) };
        }
    }

    #[test]
    fn concurrent_alloc_free_storm_is_consistent() {
        let slab = Slab::new(SlabConfig::small(1 << 20));
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let slab = Arc::clone(&slab);
                std::thread::spawn(move || {
                    let mut rng = crate::sync::Xoshiro256::seeded(t);
                    let mut held: Vec<(usize, u8)> = Vec::new();
                    for _ in 0..5_000 {
                        if held.len() < 32 && rng.chance(0.6) {
                            if let Some((p, c)) = slab.alloc(1 + rng.next_below(200) as usize) {
                                // Stamp ownership; verify on free.
                                unsafe { (p as *mut u64).write(t ^ p as u64) };
                                held.push((p as usize, c));
                            }
                        } else if let Some((p, c)) = held.pop() {
                            unsafe {
                                assert_eq!((p as *mut u64).read(), t ^ p as u64, "chunk stomped");
                                slab.free(p as *mut u8, c);
                            }
                        }
                    }
                    for (p, c) in held {
                        unsafe { slab.free(p as *mut u8, c) };
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn utilization_tracks_live_chunks_excluding_magazines() {
        let slab = Slab::new(SlabConfig::small(256 << 10));
        assert_eq!(slab.utilization(), 0.0);
        let mut held = Vec::new();
        for _ in 0..100 {
            held.push(slab.alloc(512).unwrap());
        }
        let class = held[0].1;
        let stats = slab.class_stats();
        assert_eq!(
            stats[class as usize].live_chunks, 100,
            "magazine leftovers from the refill batches must not count live"
        );
        let u_full = slab.utilization();
        assert!(u_full > 0.0);
        for (p, c) in held.drain(..) {
            unsafe { slab.free(p, c) };
        }
        let stats = slab.class_stats();
        assert_eq!(stats[class as usize].live_chunks, 0);
        assert!(
            stats[class as usize].cached_chunks >= 1,
            "freed chunks park in the magazine"
        );
        assert!(slab.utilization() < u_full);
    }

    #[test]
    fn steady_state_magazine_serves_without_shared_cas() {
        if !cfg!(debug_assertions) {
            eprintln!("SKIP: shared-op counter is a debug_assertions hook");
            return;
        }
        let slab = Slab::new(SlabConfig::small(256 << 10));
        // Warm the magazine: one refill, then park a few frees.
        let mut held = Vec::new();
        for _ in 0..8 {
            held.push(slab.alloc(100).unwrap());
        }
        for (p, c) in held.drain(..) {
            unsafe { slab.free(p, c) };
        }
        let before = slab.shared_ops_for(100);
        // Steady state: every alloc/free stays inside the magazine.
        for _ in 0..1_000 {
            for _ in 0..4 {
                held.push(slab.alloc(100).unwrap());
            }
            for (p, c) in held.drain(..) {
                unsafe { slab.free(p, c) };
            }
        }
        let after = slab.shared_ops_for(100);
        assert_eq!(
            after - before,
            0,
            "magazine-served steady state must not touch the shared free list"
        );
    }

    #[test]
    fn cross_thread_churn_reuses_chunks_without_leaking() {
        // Alloc on thread A, free on thread B, repeatedly: chunks must
        // flow B-magazine → shared segment → A-refill, not leak.
        let slab = Slab::new(SlabConfig::small(512 << 10));
        // Rendezvous-ish bound so the allocator can't outrun the freer by
        // more than ~2 batches (the budget only covers reuse, not a
        // backlog).
        let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<(usize, u8)>>(1);
        let freer = {
            let slab = Arc::clone(&slab);
            std::thread::spawn(move || {
                for batch in rx {
                    for (p, c) in batch {
                        unsafe { slab.free(p as *mut u8, c) };
                    }
                }
                // Exit flushes this thread's magazines back to shared.
            })
        };
        for _round in 0..50 {
            let batch: Vec<(usize, u8)> = (0..64)
                .map(|_| {
                    let (p, c) = slab.alloc(200).expect("reuse must prevent exhaustion");
                    (p as usize, c)
                })
                .collect();
            tx.send(batch).unwrap();
        }
        drop(tx);
        freer.join().unwrap();
        // 50 rounds × 64 × 224B-class chunks ≈ 700 KiB of traffic through
        // a 512 KiB budget: only reuse makes that possible. After the
        // freer exited (exit-flush) and this thread flushed its own
        // refill leftovers, nothing may remain parked anywhere.
        slab.flush_local_magazines();
        let stats = slab.class_stats();
        let total_cached: usize = stats.iter().map(|s| s.cached_chunks).sum();
        let total_live: usize = stats.iter().map(|s| s.live_chunks).sum();
        assert_eq!(total_cached, 0, "freer thread exit must flush magazines");
        assert_eq!(total_live, 0, "every chunk was freed");
        // And everything is genuinely allocatable again without growth.
        let claimed = slab.claimed_bytes();
        let mut held = Vec::new();
        for _ in 0..64 {
            held.push(slab.alloc(200).unwrap());
        }
        assert_eq!(slab.claimed_bytes(), claimed, "reuse, not new pages");
    }

    #[test]
    fn thread_exit_flushes_magazines() {
        let slab = Slab::new(SlabConfig::small(256 << 10));
        let worker = {
            let slab = Arc::clone(&slab);
            std::thread::spawn(move || {
                let mut held = Vec::new();
                for _ in 0..8 {
                    held.push(slab.alloc(100).unwrap());
                }
                let first = held[0];
                for (p, c) in held {
                    unsafe { slab.free(p, c) };
                }
                // Parked in this thread's magazine until exit.
                first
            })
        };
        let (first_ptr, first_class) = worker.join().unwrap();
        let stats = slab.class_stats();
        assert_eq!(stats[first_class as usize].cached_chunks, 0);
        assert_eq!(stats[first_class as usize].live_chunks, 0);
        // The worker's chunks are reachable from this thread via shared
        // segments — no page growth needed.
        let claimed = slab.claimed_bytes();
        let mut got = Vec::new();
        for _ in 0..8 {
            got.push(slab.alloc(100).unwrap().0 as usize);
        }
        assert_eq!(slab.claimed_bytes(), claimed);
        assert!(
            got.contains(&(first_ptr as usize)),
            "worker's flushed chunks must be reused"
        );
    }

    #[test]
    fn pressure_flush_request_publishes_idle_magazines() {
        // The privatization blind spot: chunks parked in an *idle*
        // thread's magazine used to stay invisible to a thread starving
        // under pressure until the owner happened to alloc/free again
        // with a full/empty magazine. The flush-request epoch closes it:
        // a failed alloc raises the epoch, and the owner's very next
        // magazine op (here: one free) publishes everything it parked.
        let slab = Slab::new(SlabConfig {
            mem_limit: 64 << 10,
            page_size: 64 << 10,
            base_chunk: 1024,
            growth: 1.25,
            max_chunk: 8192,
        });
        let (to_victim, victim_rx) = std::sync::mpsc::channel::<()>();
        let (to_main, main_rx) = std::sync::mpsc::channel::<()>();
        let victim = {
            let slab = Arc::clone(&slab);
            std::thread::spawn(move || {
                // Alloc 8, free 7: the refill batch plus the frees leave
                // well over half the magazine parked privately.
                let mut held = Vec::new();
                for _ in 0..8 {
                    held.push(slab.alloc(1024).unwrap());
                }
                let keep = held.pop().unwrap();
                for (p, c) in held {
                    unsafe { slab.free(p, c) };
                }
                to_main.send(()).unwrap();
                // Sit idle until main has hit the pressure wall.
                victim_rx.recv().unwrap();
                // One magazine op honors the raised epoch and flushes.
                unsafe { slab.free(keep.0, keep.1) };
                to_main.send(()).unwrap();
                // Keep this thread (and its magazines) alive until the
                // assertions ran, so exit-flush can't mask the epoch path.
                victim_rx.recv().unwrap();
            })
        };
        main_rx.recv().unwrap();
        // Drain the budget from this thread until allocation fails — each
        // failure raises the flush-request epoch.
        let mut held = Vec::new();
        while let Some(got) = slab.alloc(1024) {
            held.push(got);
        }
        assert!(
            slab.alloc(1024).is_none(),
            "victim's parked chunks must not be reachable while it idles"
        );
        // Wake the victim; its single free must publish its magazine.
        to_victim.send(()).unwrap();
        main_rx.recv().unwrap();
        assert!(
            slab.alloc(1024).is_some(),
            "epoch-honoring flush must publish the idle thread's magazine"
        );
        to_victim.send(()).unwrap();
        victim.join().unwrap();
        for (p, c) in held {
            unsafe { slab.free(p, c) };
        }
    }

    #[test]
    fn arbiter_budget_move_raises_flush_epoch() {
        // Satellite of the multi-tenant plane: when the arbiter shrinks
        // a tenant's budget, chunks parked in an *idle* thread's
        // magazine must become publishable immediately —
        // `move_tenant_budget` raises the flush-request epoch (PR 7)
        // itself instead of waiting for the donor's next natural
        // pressure event. Unlike
        // `pressure_flush_request_publishes_idle_magazines`, nothing
        // here ever hits the pressure wall, so the budget move is the
        // ONLY epoch raiser the victim can observe.
        let slab = Slab::new(SlabConfig::small(256 << 10));
        slab.enable_tenancy();
        slab.set_tenant_budget(1, 192 << 10);
        slab.set_tenant_budget(2, 64 << 10);
        let (to_victim, victim_rx) = std::sync::mpsc::channel::<()>();
        let (to_main, main_rx) = std::sync::mpsc::channel::<()>();
        let victim = {
            let slab = Arc::clone(&slab);
            std::thread::spawn(move || {
                // Alloc 8, free 7, keep 1: refill batch + frees leave a
                // well-stocked magazine parked privately.
                let mut held = Vec::new();
                for _ in 0..8 {
                    held.push(slab.alloc(1024).unwrap());
                }
                let keep = held.pop().unwrap();
                for (p, c) in held {
                    unsafe { slab.free(p, c) };
                }
                to_main.send(()).unwrap();
                // Idle while main runs the arbiter.
                victim_rx.recv().unwrap();
                // One magazine op honors the raised epoch and flushes.
                unsafe { slab.free(keep.0, keep.1) };
                to_main.send(()).unwrap();
                // Stay alive until the assertions ran, so exit-flush
                // cannot mask the epoch path.
                victim_rx.recv().unwrap();
            })
        };
        main_rx.recv().unwrap();
        let class = slab.class_for(1024).unwrap() as usize;
        assert!(
            slab.class_stats()[class].cached_chunks > 0,
            "victim parked chunks privately"
        );
        let honored_before = slab.flushes_honored();
        let moved = slab.move_tenant_budget(1, 2, 64 << 10);
        assert_eq!(moved, 64 << 10, "donor above floor surrenders in full");
        assert_eq!(slab.tenant_budget(1), 128 << 10);
        assert_eq!(slab.tenant_budget(2), 128 << 10);
        // Wake the victim; its single free must publish its magazine.
        to_victim.send(()).unwrap();
        main_rx.recv().unwrap();
        // The push honors the epoch (flushing everything parked) before
        // parking the newly freed chunk, so exactly one chunk remains.
        assert_eq!(
            slab.class_stats()[class].cached_chunks,
            1,
            "budget move must make the idle thread publish its magazine"
        );
        assert!(
            slab.flushes_honored() > honored_before,
            "the flush must be epoch-honoring, not incidental"
        );
        to_victim.send(()).unwrap();
        victim.join().unwrap();
        // Donor floor: budget never shrinks below one page (64 KiB in
        // the small test config), and an unlimited (0) tenant donates
        // nothing.
        assert_eq!(slab.move_tenant_budget(1, 2, usize::MAX), 64 << 10);
        assert_eq!(slab.tenant_budget(1), 64 << 10);
        assert_eq!(slab.move_tenant_budget(1, 2, 4 << 10), 0, "donor at floor");
        assert_eq!(slab.move_tenant_budget(0, 2, 4 << 10), 0, "unlimited donor");
    }

    #[test]
    fn tenant_accounting_attributes_allocs_and_frees() {
        let slab = Slab::new(SlabConfig::small(256 << 10));
        // Disabled: hooks are no-ops.
        slab.note_tenant_alloc(3, 0);
        assert_eq!(slab.tenant_usage(3), TenantUsage::default());
        slab.enable_tenancy();
        let (p, c) = slab.alloc(100).unwrap();
        slab.note_tenant_alloc(3, c);
        let chunk = slab.chunk_size(c);
        assert_eq!(slab.tenant_live_bytes(3), chunk);
        let u = slab.tenant_usage(3);
        assert_eq!((u.handed_chunks, u.freed_chunks), (1, 0));
        let rows = slab.tenant_class_stats(3);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].chunk_size, chunk);
        assert_eq!(rows[0].live_chunks, 1);
        // Budget enforcement signal: over-budget only when live + add
        // exceeds a non-zero budget.
        assert!(!slab.tenant_must_yield(3, chunk), "no budget set");
        slab.set_tenant_budget(3, chunk + chunk / 2);
        assert!(!slab.tenant_must_yield(3, chunk / 4));
        assert!(slab.tenant_must_yield(3, chunk));
        // Free attributes back via the explicit tenant (header byte in
        // real use) even though nothing about the calling thread says 3.
        slab.note_tenant_free(3, c);
        unsafe { slab.free(p, c) };
        assert_eq!(slab.tenant_live_bytes(3), 0);
        let u = slab.tenant_usage(3);
        assert_eq!((u.handed_chunks, u.freed_chunks), (1, 1));
        assert!(!slab.tenant_must_yield(3, chunk));
        // Thread-local plumbing used by the item layer.
        assert_eq!(tenant::current(), DEFAULT_TENANT);
        tenant::set_current(3);
        assert_eq!(tenant::current(), 3);
        tenant::set_current(DEFAULT_TENANT);
    }

    #[test]
    fn exhausted_flushes_local_magazines() {
        let slab = Slab::new(SlabConfig {
            mem_limit: 64 << 10,
            page_size: 64 << 10,
            base_chunk: 1024,
            growth: 1.25,
            max_chunk: 8192,
        });
        let mut held = Vec::new();
        while let Some(got) = slab.alloc(1024) {
            held.push(got);
        }
        // Park some frees privately.
        for (p, c) in held.drain(..).take(8) {
            unsafe { slab.free(p, c) };
        }
        let class = slab.class_for(1024).unwrap() as usize;
        assert!(slab.class_stats()[class].cached_chunks > 0);
        assert!(slab.exhausted(), "budget is fully claimed");
        assert_eq!(
            slab.class_stats()[class].cached_chunks,
            0,
            "exhausted() must publish parked chunks before reporting pressure"
        );
    }
}
