//! Per-thread chunk magazines — the privatized fast path over the shared
//! size-class structures.
//!
//! Every thread keeps, per (slab, size class), a small *magazine* of free
//! chunks. Steady-state `alloc`/`free` pop/push the magazine only — no
//! shared CAS, no contended cache line. The magazine exchanges chunks
//! with the shared [`super::SizeClass`] in batches: an empty magazine
//! refills with one segment pop (up to [`MAG_CAP`] chunks, one CAS), a
//! full one flushes its whole contents as one segment push (one CAS).
//! This is the commutative-update privatization argument: alloc/free of
//! *distinct* chunks commute, so nothing about their order needs to be
//! globally visible until a batch boundary.
//!
//! ## Truthful accounting
//!
//! Magazine-resident chunks are *free*, not live. Each registration owns
//! a slot in the slab's [`SlotTable`] and publishes its per-class
//! magazine length with plain relaxed stores to its own cache line;
//! [`super::Slab::class_stats`] subtracts the summed slot lengths from
//! the classes' `handed` counters, so `utilization`/`mem_used` stay exact
//! (up to the usual racy-snapshot caveat) with chunks parked privately.
//!
//! ## Pressure cooperation
//!
//! Privatized chunks are invisible to *other* threads until a batch
//! boundary — under memory pressure that is a starvation hazard (thread A
//! fails to allocate while thread B's magazine parks plenty). The slab's
//! flush-request epoch ([`super::Slab::request_magazine_flush`]) closes
//! it: every `pop`/`push` first compares the epoch against the value this
//! registration last honored and, if it moved, flushes all magazines back
//! to the shared lists before proceeding.
//!
//! ## Lifetime
//!
//! The registry is a thread-local keyed by slab address. Each entry holds
//! a `Weak<Slab>` (cloned from the slab's own handle): at thread exit the
//! entry upgrades it and — if the slab is still alive — flushes every
//! magazine back to the shared lists and releases its slot, so chunks are
//! never stranded by a departing thread. If the slab died first, the
//! chunks died with its pages and the entry simply evaporates. A live
//! `Weak` also pins the slab's allocation, so a registry key can never
//! alias a *different* live slab.

use std::cell::{Cell, RefCell, UnsafeCell};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::Weak;

use crossbeam_utils::CachePadded;

use super::Slab;

/// Magazine capacity per (thread, size class): the batch size of shared
/// free-list interactions.
pub const MAG_CAP: usize = 16;

/// Registration slots per slab (matches [`crate::ebr::MAX_THREADS`]).
pub(super) const MAG_SLOTS: usize = 128;

/// One thread's published magazine lengths (owner-written, stats-read).
pub(super) struct Slot {
    owned: AtomicBool,
    lens: Box<[AtomicU32]>,
}

/// The slab-resident side of the magazine layer: per-thread slots whose
/// published lengths make magazine-parked chunks visible to stats.
pub(super) struct SlotTable {
    slots: Box<[CachePadded<Slot>]>,
}

impl SlotTable {
    pub(super) fn new(classes: usize) -> Self {
        let slots = (0..MAG_SLOTS)
            .map(|_| {
                CachePadded::new(Slot {
                    owned: AtomicBool::new(false),
                    lens: (0..classes).map(|_| AtomicU32::new(0)).collect(),
                })
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        SlotTable { slots }
    }

    /// Claim a free slot; `None` when all are taken (magazines disabled
    /// for that thread — it falls back to the shared path).
    fn claim(&self) -> Option<usize> {
        self.slots.iter().position(|s| {
            // ord: relaxed-ok — optimistic pre-check only; ownership is
            // decided by the CAS below.
            !s.owned.load(Ordering::Relaxed)
                && s.owned
                    // ord: AcqRel claim — Acquire sees the previous
                    // owner's Release in LocalMags::drop; Release pairs
                    // with cached()'s Acquire owned.load.
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
        })
    }

    /// Chunks of `class` currently parked across every thread's magazine.
    pub(super) fn cached(&self, class: usize) -> usize {
        self.slots
            .iter()
            .filter(|s| s.owned.load(Ordering::Acquire))
            // ord: relaxed-ok — published length is a racy stats snapshot
            // by design (see "Truthful accounting" above).
            .map(|s| s.lens[class].load(Ordering::Relaxed) as usize)
            .sum()
    }
}

/// How many slot-less lookups to wait between re-attempts at claiming a
/// stats slot (a claim scans the whole table, so don't do it per op).
const CLAIM_RETRY_EVERY: u32 = 1024;

/// One thread's magazines for one slab.
pub(super) struct LocalMags {
    slab_key: usize,
    weak: Weak<Slab>,
    /// Claimed stats slot. `None` when the table was full at registration
    /// — re-attempted every [`CLAIM_RETRY_EVERY`] lookups so a transient
    /// thread spike doesn't cost this thread its fast path forever.
    slot: Cell<Option<usize>>,
    claim_countdown: Cell<u32>,
    /// Last flush-request epoch honored (see
    /// [`super::Slab::request_magazine_flush`]).
    seen_flush: Cell<u32>,
    /// Chunk pointers, owner-thread only. `RefCell` (not a lock): the
    /// registry is thread-local and nothing below re-enters it.
    mags: RefCell<Box<[Vec<*mut u8>]>>,
}

impl LocalMags {
    /// Whether this registration can actually park chunks (it claimed a
    /// stats slot). Without a slot, parking would make stats untruthful,
    /// so the slab falls back to the shared path instead.
    pub(super) fn active(&self) -> bool {
        self.slot.get().is_some()
    }

    /// Periodic re-attempt to claim a slot after a full-table miss.
    fn maybe_reclaim_slot(&self, slab: &Slab) {
        if self.slot.get().is_some() {
            return;
        }
        let left = self.claim_countdown.get();
        if left > 0 {
            self.claim_countdown.set(left - 1);
            return;
        }
        self.claim_countdown.set(CLAIM_RETRY_EVERY);
        self.slot.set(slab.depot.claim());
    }

    #[inline]
    fn publish_len(&self, slab: &Slab, class: usize, len: usize) {
        if let Some(s) = self.slot.get() {
            // ord: relaxed-ok — owner-written stats line; readers accept a
            // racy snapshot (class_stats clamps).
            slab.depot.slots[s].lens[class].store(len as u32, Ordering::Relaxed);
        }
    }

    /// Flush everything we parked if the slab raised its flush-request
    /// epoch since we last looked — the cooperative half of
    /// [`super::Slab::request_magazine_flush`]. Must run before the
    /// magazine borrow in the caller ([`Self::flush_all`] re-borrows).
    fn honor_flush_request(&self, slab: &Slab) {
        // ord: relaxed-ok — advisory flush request; the flush itself
        // publishes through the free lists' Release CASes, and a missed
        // epoch is honored on the next op.
        let e = slab.flush_epoch.load(Ordering::Relaxed);
        if e != self.seen_flush.get() {
            self.seen_flush.set(e);
            self.flush_all(slab);
            slab.flushes_honored.inc();
        }
    }

    /// Magazine-only pop: `None` means empty (caller refills).
    // audit:allow(guard) hands out an exclusively-owned free chunk, not
    // guard-lent memory — no byte-stability contract applies.
    pub(super) fn pop(&self, slab: &Slab, class: u8) -> Option<*mut u8> {
        self.honor_flush_request(slab);
        let mut mags = self.mags.borrow_mut();
        let m = &mut mags[class as usize];
        let p = m.pop();
        if p.is_some() {
            self.publish_len(slab, class as usize, m.len());
        }
        p
    }

    /// Park a freed chunk; a full magazine first flushes its entire
    /// contents to the shared list as one segment.
    ///
    /// # Safety
    /// `ptr` must be an unreferenced chunk of `class` from `slab`.
    pub(super) unsafe fn push(&self, slab: &Slab, class: u8, ptr: *mut u8) {
        self.honor_flush_request(slab);
        let mut mags = self.mags.borrow_mut();
        let m = &mut mags[class as usize];
        if m.len() >= MAG_CAP {
            slab.classes[class as usize].free_batch(m.as_slice());
            m.clear();
        }
        m.push(ptr);
        self.publish_len(slab, class as usize, m.len());
    }

    /// Refill an empty magazine from the shared structures and hand one
    /// chunk out. `None` = the shared side is empty too (caller grows the
    /// class or reports pressure).
    // audit:allow(guard) hands out an exclusively-owned free chunk, not
    // guard-lent memory — no byte-stability contract applies.
    pub(super) fn refill_and_pop(&self, slab: &Slab, class: u8) -> Option<*mut u8> {
        let mut mags = self.mags.borrow_mut();
        let m = &mut mags[class as usize];
        debug_assert!(m.is_empty(), "refill on a non-empty magazine");
        // SAFETY: `class` indexes `slab.classes` (this magazine was built
        // with one Vec per class), and the batch lands in this thread's
        // own magazine.
        let got = unsafe { slab.classes[class as usize].alloc_batch(m, MAG_CAP) };
        if got == 0 {
            return None;
        }
        let p = m.pop();
        self.publish_len(slab, class as usize, m.len());
        p
    }

    /// Return every parked chunk to the shared lists (one segment per
    /// non-empty class).
    pub(super) fn flush_all(&self, slab: &Slab) {
        let mut mags = self.mags.borrow_mut();
        for (class, m) in mags.iter_mut().enumerate() {
            if !m.is_empty() {
                // SAFETY: every pointer parked in magazine `class` came in
                // through `push`, whose caller guaranteed an unreferenced
                // chunk of that class from this slab.
                unsafe { slab.classes[class].free_batch(m.as_slice()) };
                m.clear();
                self.publish_len(slab, class, 0);
            }
        }
    }
}

impl Drop for LocalMags {
    fn drop(&mut self) {
        // Thread exit (or registry GC): if the slab is still alive, give
        // the chunks back and release the slot. If not, its pages are
        // gone and so are the chunks — nothing to do (and nothing is
        // dereferenced).
        if let Some(slab) = self.weak.upgrade() {
            self.flush_all(&slab);
            if let Some(s) = self.slot.get() {
                // ord: Release hands the slot back after the flush above;
                // Acquire counterpart: claim()'s CAS and cached()'s
                // owned.load.
                slab.depot.slots[s].owned.store(false, Ordering::Release);
            }
        }
    }
}

thread_local! {
    /// This thread's magazine registrations (one per slab ever touched;
    /// linear scan — a thread talks to very few slabs).
    static MAGS: UnsafeCell<Vec<Rc<LocalMags>>> = const { UnsafeCell::new(Vec::new()) };
}

/// Find (or create) this thread's magazines for `slab`. Returns `None`
/// only during thread teardown (the registry TLS is already destroyed);
/// callers then use the shared path directly.
pub(super) fn local(slab: &Slab) -> Option<Rc<LocalMags>> {
    let key = slab as *const Slab as usize;
    MAGS.try_with(|cell| {
        // SAFETY: single-threaded access (thread_local), no re-entrancy:
        // nothing below calls back into MAGS.
        let mags = unsafe { &mut *cell.get() };
        if let Some(l) = mags.iter().find(|l| l.slab_key == key) {
            l.maybe_reclaim_slot(slab);
            return Rc::clone(l);
        }
        let classes = slab.classes.len();
        let local = Rc::new(LocalMags {
            slab_key: key,
            weak: slab.self_weak.clone(),
            slot: Cell::new(slab.depot.claim()),
            claim_countdown: Cell::new(CLAIM_RETRY_EVERY),
            // ord: relaxed-ok — start at the current epoch: a fresh
            // registration has nothing parked, so pending requests are
            // vacuously honored.
            seen_flush: Cell::new(slab.flush_epoch.load(Ordering::Relaxed)),
            mags: RefCell::new(
                (0..classes)
                    .map(|_| Vec::with_capacity(MAG_CAP))
                    .collect(),
            ),
        });
        mags.push(Rc::clone(&local));
        // GC registrations whose slab died (their Drop is a no-op).
        mags.retain(|l| l.weak.strong_count() > 0);
        local
    })
    .ok()
}

/// This thread's existing registration for `slab`, if any — used by
/// flush-only paths that should not register just to flush nothing.
pub(super) fn local_existing(slab: &Slab) -> Option<Rc<LocalMags>> {
    let key = slab as *const Slab as usize;
    MAGS.try_with(|cell| {
        // SAFETY: single-threaded access (thread_local), no re-entrancy:
        // nothing below calls back into MAGS.
        let mags = unsafe { &*cell.get() };
        mags.iter().find(|l| l.slab_key == key).map(Rc::clone)
    })
    .ok()
    .flatten()
}
