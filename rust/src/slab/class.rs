//! One slab size class: a lock-free free list plus a CAS bump region.
//!
//! The bump region is a single packed word `(addr48 << 16) | count16` so
//! page installation and chunk claiming are both single CASes — two
//! separate `bump`/`end` words could be read torn across an install and
//! hand out memory past a page boundary.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::lockfree::TaggedStack;

const COUNT_BITS: u32 = 16;
const COUNT_MASK: usize = (1 << COUNT_BITS) - 1;

#[inline]
fn pack(addr: usize, count: usize) -> usize {
    debug_assert!(addr < (1usize << 48), "address exceeds 48 bits");
    debug_assert!(count <= COUNT_MASK, "chunk count exceeds 16 bits");
    (addr << COUNT_BITS) | count
}

#[inline]
fn unpack(word: usize) -> (usize, usize) {
    (word >> COUNT_BITS, word & COUNT_MASK)
}

/// Statistics for one size class.
#[derive(Debug, Clone, Copy)]
pub struct SizeClassStats {
    pub chunk_size: usize,
    /// Chunks handed out and not yet freed.
    pub live_chunks: usize,
    /// Total chunks ever carved from pages.
    pub total_chunks: usize,
}

/// A size class. `region` is the packed (next-chunk address, chunks left)
/// of the most recently installed page; exhausted pages live on only
/// through the free list.
pub struct SizeClass {
    chunk_size: usize,
    free: TaggedStack,
    region: AtomicUsize,
    live: AtomicUsize,
    total: AtomicUsize,
}

impl SizeClass {
    pub fn new(chunk_size: usize) -> Self {
        SizeClass {
            chunk_size,
            free: TaggedStack::new(),
            region: AtomicUsize::new(0),
            live: AtomicUsize::new(0),
            total: AtomicUsize::new(0),
        }
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// Try to allocate from the free list, then the bump region. `None`
    /// means the caller must install a new page (or report pressure).
    pub fn try_alloc(&self) -> Option<*mut u8> {
        // Free list first: reuse keeps the working set dense.
        if let Some(ptr) = unsafe { self.free.pop() } {
            self.live.fetch_add(1, Ordering::Relaxed);
            return Some(ptr);
        }
        let mut word = self.region.load(Ordering::Acquire);
        loop {
            let (addr, count) = unpack(word);
            if count == 0 {
                return None;
            }
            match self.region.compare_exchange_weak(
                word,
                pack(addr + self.chunk_size, count - 1),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    self.live.fetch_add(1, Ordering::Relaxed);
                    self.total.fetch_add(1, Ordering::Relaxed);
                    return Some(addr as *mut u8);
                }
                Err(cur) => word = cur,
            }
        }
    }

    /// Install a fresh page as the bump region (single atomic publish).
    /// The remainder of any previous region (< one chunk) is abandoned —
    /// the same slack Memcached accepts. Callers serialize installs (the
    /// slab's page mutex), so no region is ever overwritten while nonempty.
    pub fn install_page(&self, page: *mut u8, page_size: usize) {
        // Clamp to the packed width (loses at most one chunk of a
        // pathological 16-byte/1-MiB configuration).
        let count = (page_size / self.chunk_size).min(COUNT_MASK);
        self.region
            .store(pack(page as usize, count), Ordering::Release);
    }

    /// Return a chunk to the free list.
    ///
    /// # Safety
    /// `ptr` must be an unreferenced chunk of this class.
    pub unsafe fn free(&self, ptr: *mut u8) {
        self.live.fetch_sub(1, Ordering::Relaxed);
        self.free.push(ptr);
    }

    pub fn stats(&self) -> SizeClassStats {
        SizeClassStats {
            chunk_size: self.chunk_size,
            live_chunks: self.live.load(Ordering::Relaxed),
            total_chunks: self.total.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_allocates_sequential_chunks() {
        let sc = SizeClass::new(64);
        assert!(sc.try_alloc().is_none(), "no page installed yet");
        let mut page = vec![0u8; 4096];
        sc.install_page(page.as_mut_ptr(), 4096);
        let a = sc.try_alloc().unwrap() as usize;
        let b = sc.try_alloc().unwrap() as usize;
        assert_eq!(b - a, 64);
        let stats = sc.stats();
        assert_eq!(stats.live_chunks, 2);
        assert_eq!(stats.total_chunks, 2);
    }

    #[test]
    fn page_exhaustion_is_reported() {
        let sc = SizeClass::new(1024);
        let mut page = vec![0u8; 2048];
        sc.install_page(page.as_mut_ptr(), 2048);
        assert!(sc.try_alloc().is_some());
        assert!(sc.try_alloc().is_some());
        assert!(sc.try_alloc().is_none());
    }

    #[test]
    fn free_list_has_priority_over_bump() {
        let sc = SizeClass::new(128);
        let mut page = vec![0u8; 1024];
        sc.install_page(page.as_mut_ptr(), 1024);
        let a = sc.try_alloc().unwrap();
        unsafe { sc.free(a) };
        assert_eq!(sc.stats().live_chunks, 0);
        let b = sc.try_alloc().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn concurrent_bump_claims_are_disjoint() {
        use std::collections::HashSet;
        use std::sync::Arc;
        let sc = Arc::new(SizeClass::new(64));
        let mut page = vec![0u8; 64 * 1024];
        sc.install_page(page.as_mut_ptr(), 64 * 1024);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let sc = Arc::clone(&sc);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(p) = sc.try_alloc() {
                        got.push(p as usize);
                    }
                    got
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len(), 1024);
        assert_eq!(all.iter().collect::<HashSet<_>>().len(), 1024);
    }
}
