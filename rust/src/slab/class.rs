//! One slab size class: a lock-free free list plus a CAS bump region.
//!
//! The bump region is a single packed word `(addr48 << 16) | count16` so
//! page installation and chunk claiming are both single CASes — two
//! separate `bump`/`end` words could be read torn across an install and
//! hand out memory past a page boundary.
//!
//! ## Segment free list
//!
//! The free list stores **segments**: short chains of free chunks linked
//! through each chunk's *second* word (the first word belongs to the
//! Treiber stack itself). One push/pop of the shared stack therefore
//! transfers a whole batch of chunks, which is what lets the per-thread
//! magazine layer ([`crate::slab`]) refill and flush with one shared CAS
//! per ~[`crate::slab::MAG_CAP`] operations instead of one per chunk.
//! Walking a segment's intra-links is only ever done *after* the pop —
//! on memory the walker exclusively owns — so the stack's ABA/version
//! reasoning is untouched (the stack still only reads the first word of
//! its top node).
//!
//! ## Accounting
//!
//! `handed` counts chunks currently *outside* the shared structures:
//! handed to callers **or** parked in a thread magazine. The slab layer
//! subtracts the magazine population (tracked per registration slot) to
//! report user-live chunks, so `utilization`/`mem_used` treat magazine
//! residents as free.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::lockfree::TaggedStack;

const COUNT_BITS: u32 = 16;
const COUNT_MASK: usize = (1 << COUNT_BITS) - 1;

#[inline]
fn pack(addr: usize, count: usize) -> usize {
    debug_assert!(addr < (1usize << 48), "address exceeds 48 bits");
    debug_assert!(count <= COUNT_MASK, "chunk count exceeds 16 bits");
    (addr << COUNT_BITS) | count
}

#[inline]
fn unpack(word: usize) -> (usize, usize) {
    (word >> COUNT_BITS, word & COUNT_MASK)
}

/// Read a chunk's intra-segment link (second word).
///
/// # Safety
/// `p` must be a chunk the caller exclusively owns (freshly popped
/// segment or a chain being assembled), with `chunk_size >= 16`.
#[inline]
unsafe fn seg_next(p: *mut u8) -> *mut u8 {
    (p.add(8) as *const u64).read() as *mut u8
}

/// Write a chunk's intra-segment link (second word).
///
/// # Safety
/// Same ownership contract as [`seg_next`].
#[inline]
unsafe fn set_seg_next(p: *mut u8, next: *mut u8) {
    (p.add(8) as *mut u64).write(next as u64);
}

/// Statistics for one size class.
#[derive(Debug, Clone, Copy)]
pub struct SizeClassStats {
    pub chunk_size: usize,
    /// Chunks handed out to users and not yet freed. At the class level
    /// this includes magazine-parked chunks; [`crate::slab::Slab`]
    /// subtracts those into `cached_chunks` before reporting.
    pub live_chunks: usize,
    /// Chunks parked in per-thread magazines (free, but privatized).
    /// Always 0 in a class-level snapshot; filled in by the slab.
    pub cached_chunks: usize,
    /// Total chunks ever carved from pages.
    pub total_chunks: usize,
}

/// A size class. `region` is the packed (next-chunk address, chunks left)
/// of the most recently installed page; exhausted pages live on only
/// through the free list.
pub struct SizeClass {
    chunk_size: usize,
    free: TaggedStack,
    region: AtomicUsize,
    /// Chunks outside the shared structures (user-live + magazine).
    handed: AtomicUsize,
    total: AtomicUsize,
    /// Debug-build hook: successful shared CAS transfers (free-list
    /// push/pop, bump claims). The magazine tests assert this stays flat
    /// across magazine-served steady state. Compiled out of release.
    #[cfg(debug_assertions)]
    shared_ops: AtomicUsize,
}

impl SizeClass {
    pub fn new(chunk_size: usize) -> Self {
        assert!(chunk_size >= 16, "segment links need two words per chunk");
        SizeClass {
            chunk_size,
            free: TaggedStack::new(),
            region: AtomicUsize::new(0),
            handed: AtomicUsize::new(0),
            total: AtomicUsize::new(0),
            #[cfg(debug_assertions)]
            shared_ops: AtomicUsize::new(0),
        }
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    #[inline]
    fn note_shared_op(&self) {
        #[cfg(debug_assertions)]
        // ord: relaxed-ok — debug-only event counter; tests read it from
        // the same thread or after a join.
        self.shared_ops.fetch_add(1, Ordering::Relaxed);
    }

    /// Successful shared free-list/bump transfers so far (debug builds;
    /// always 0 in release).
    pub fn shared_ops(&self) -> usize {
        #[cfg(debug_assertions)]
        {
            // ord: relaxed-ok — debug counter snapshot (see note_shared_op).
            self.shared_ops.load(Ordering::Relaxed)
        }
        #[cfg(not(debug_assertions))]
        {
            0
        }
    }

    /// Try to allocate one chunk from the free list, then the bump
    /// region. `None` means the caller must install a new page (or report
    /// pressure).
    // audit:allow(guard) hands out an exclusively-owned free chunk, not
    // guard-lent memory — no byte-stability contract applies.
    pub fn try_alloc(&self) -> Option<*mut u8> {
        // Free list first: reuse keeps the working set dense. The popped
        // node is a whole segment; keep its head and return the rest.
        // SAFETY: every node pushed onto `free` is a chunk of this class
        // (see `free`/`free_batch` contracts), so popping yields a chunk
        // we now exclusively own.
        if let Some(seg) = unsafe { self.free.pop() } {
            self.note_shared_op();
            // SAFETY: `seg` is exclusively ours after the pop and
            // chunk_size ≥ 16 (asserted in `new`).
            let rest = unsafe { seg_next(seg) };
            if !rest.is_null() {
                // `rest` is still a well-formed (intra-linked,
                // null-terminated) segment; push it back as one node.
                self.note_shared_op();
                // SAFETY: `rest` chains chunks of this class we own; its
                // first word is free for the stack's use.
                unsafe { self.free.push(rest) };
            }
            // ord: relaxed-ok — accounting counter; stats tolerate racy
            // snapshots (slab::class_stats clamps).
            self.handed.fetch_add(1, Ordering::Relaxed);
            return Some(seg);
        }
        let mut word = self.region.load(Ordering::Acquire);
        loop {
            let (addr, count) = unpack(word);
            if count == 0 {
                return None;
            }
            match self.region.compare_exchange_weak(
                word,
                pack(addr + self.chunk_size, count - 1),
                // ord: AcqRel bump claim — Acquire pairs with
                // install_page's Release store so the claimed address is
                // backed by a visible page; Release orders claims.
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    self.note_shared_op();
                    // ord: relaxed-ok — accounting counters; stats
                    // tolerate racy snapshots.
                    self.handed.fetch_add(1, Ordering::Relaxed);
                    // ord: relaxed-ok — same accounting story as `handed`.
                    self.total.fetch_add(1, Ordering::Relaxed);
                    return Some(addr as *mut u8);
                }
                Err(cur) => word = cur,
            }
        }
    }

    /// Pop up to `want` chunks into `out` (one shared segment pop, then
    /// one batched bump claim). Returns how many were appended.
    ///
    /// # Safety
    /// Same contract as [`SizeClass::try_alloc`]: returned chunks are
    /// exclusively the caller's.
    pub unsafe fn alloc_batch(&self, out: &mut Vec<*mut u8>, want: usize) -> usize {
        let mut got = 0usize;
        if want == 0 {
            return 0;
        }
        if let Some(seg) = self.free.pop() {
            self.note_shared_op();
            let mut cur = seg;
            while !cur.is_null() && got < want {
                let next = seg_next(cur);
                out.push(cur);
                got += 1;
                cur = next;
            }
            if !cur.is_null() {
                // Oversized segment (shouldn't happen with magazine-sized
                // flushes, but singles can chain): return the tail.
                self.note_shared_op();
                self.free.push(cur);
            }
        }
        if got < want {
            let mut word = self.region.load(Ordering::Acquire);
            loop {
                let (addr, count) = unpack(word);
                let take = count.min(want - got);
                if take == 0 {
                    break;
                }
                match self.region.compare_exchange_weak(
                    word,
                    pack(addr + take * self.chunk_size, count - take),
                    // ord: AcqRel batched bump claim — same pairing as
                    // try_alloc: Acquire vs install_page's Release.
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        self.note_shared_op();
                        for i in 0..take {
                            out.push((addr + i * self.chunk_size) as *mut u8);
                        }
                        // ord: relaxed-ok — accounting counter (racy
                        // stats snapshots are fine).
                        self.total.fetch_add(take, Ordering::Relaxed);
                        got += take;
                        break;
                    }
                    Err(cur) => word = cur,
                }
            }
        }
        if got > 0 {
            // ord: relaxed-ok — accounting counter (racy stats are fine).
            self.handed.fetch_add(got, Ordering::Relaxed);
        }
        got
    }

    /// Install a fresh page as the bump region (single atomic publish).
    /// The remainder of any previous region (< one chunk) is abandoned —
    /// the same slack Memcached accepts. Callers serialize installs (the
    /// slab's page mutex), so no region is ever overwritten while nonempty.
    pub fn install_page(&self, page: *mut u8, page_size: usize) {
        // Clamp to the packed width (loses at most one chunk of a
        // pathological 16-byte/1-MiB configuration).
        let count = (page_size / self.chunk_size).min(COUNT_MASK);
        // ord: Release publishes the (zero-initialized-enough) page
        // behind the packed word; Acquire counterpart: the region loads
        // and claim CAS in try_alloc/alloc_batch.
        self.region.store(pack(page as usize, count), Ordering::Release);
    }

    /// Return one chunk to the free list (a singleton segment).
    ///
    /// # Safety
    /// `ptr` must be an unreferenced chunk of this class.
    pub unsafe fn free(&self, ptr: *mut u8) {
        set_seg_next(ptr, std::ptr::null_mut());
        // ord: relaxed-ok — accounting counter (racy stats are fine).
        self.handed.fetch_sub(1, Ordering::Relaxed);
        self.note_shared_op();
        self.free.push(ptr);
    }

    /// Return a batch of chunks as one segment (one shared CAS).
    ///
    /// # Safety
    /// Every chunk must be an unreferenced chunk of this class, owned by
    /// the caller.
    pub unsafe fn free_batch(&self, chunks: &[*mut u8]) {
        if chunks.is_empty() {
            return;
        }
        for w in chunks.windows(2) {
            set_seg_next(w[0], w[1]);
        }
        set_seg_next(*chunks.last().unwrap(), std::ptr::null_mut());
        // ord: relaxed-ok — accounting counter (racy stats are fine).
        self.handed.fetch_sub(chunks.len(), Ordering::Relaxed);
        self.note_shared_op();
        self.free.push(chunks[0]);
    }

    pub fn stats(&self) -> SizeClassStats {
        SizeClassStats {
            chunk_size: self.chunk_size,
            // ord: relaxed-ok — stats snapshot; both counters are racy by
            // design and the slab layer clamps inconsistencies.
            live_chunks: self.handed.load(Ordering::Relaxed),
            cached_chunks: 0,
            // ord: relaxed-ok — same snapshot story as live_chunks.
            total_chunks: self.total.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_allocates_sequential_chunks() {
        let sc = SizeClass::new(64);
        assert!(sc.try_alloc().is_none(), "no page installed yet");
        let mut page = vec![0u8; 4096];
        sc.install_page(page.as_mut_ptr(), 4096);
        let a = sc.try_alloc().unwrap() as usize;
        let b = sc.try_alloc().unwrap() as usize;
        assert_eq!(b - a, 64);
        let stats = sc.stats();
        assert_eq!(stats.live_chunks, 2);
        assert_eq!(stats.total_chunks, 2);
    }

    #[test]
    fn page_exhaustion_is_reported() {
        let sc = SizeClass::new(1024);
        let mut page = vec![0u8; 2048];
        sc.install_page(page.as_mut_ptr(), 2048);
        assert!(sc.try_alloc().is_some());
        assert!(sc.try_alloc().is_some());
        assert!(sc.try_alloc().is_none());
    }

    #[test]
    fn free_list_has_priority_over_bump() {
        let sc = SizeClass::new(128);
        let mut page = vec![0u8; 1024];
        sc.install_page(page.as_mut_ptr(), 1024);
        let a = sc.try_alloc().unwrap();
        unsafe { sc.free(a) };
        assert_eq!(sc.stats().live_chunks, 0);
        let b = sc.try_alloc().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn batch_roundtrip_preserves_chunks_and_counts() {
        let sc = SizeClass::new(64);
        let mut page = vec![0u8; 4096]; // 64 chunks
        sc.install_page(page.as_mut_ptr(), 4096);
        let mut batch = Vec::new();
        let got = unsafe { sc.alloc_batch(&mut batch, 16) };
        assert_eq!(got, 16);
        assert_eq!(sc.stats().live_chunks, 16);
        unsafe { sc.free_batch(&batch) };
        assert_eq!(sc.stats().live_chunks, 0);
        // The whole 16-chunk segment comes back in one pop.
        let mut again = Vec::new();
        let got = unsafe { sc.alloc_batch(&mut again, 16) };
        assert_eq!(got, 16);
        use std::collections::HashSet;
        let a: HashSet<usize> = batch.iter().map(|p| *p as usize).collect();
        let b: HashSet<usize> = again.iter().map(|p| *p as usize).collect();
        assert_eq!(a, b, "segment reuse must hand back the same chunks");
    }

    #[test]
    fn alloc_batch_splits_oversized_segments() {
        let sc = SizeClass::new(64);
        let mut page = vec![0u8; 4096];
        sc.install_page(page.as_mut_ptr(), 4096);
        let mut batch = Vec::new();
        unsafe { sc.alloc_batch(&mut batch, 12) };
        unsafe { sc.free_batch(&batch) }; // one 12-chunk segment
        let mut small = Vec::new();
        let got = unsafe { sc.alloc_batch(&mut small, 5) };
        assert_eq!(got, 5, "takes only what was asked");
        // The 7-chunk tail went back; singles still pop.
        let mut seen = std::collections::HashSet::new();
        for _ in 0..7 {
            let p = sc.try_alloc().unwrap();
            assert!(
                batch.iter().any(|&b| b == p),
                "tail chunk must come from the returned segment"
            );
            assert!(seen.insert(p as usize));
        }
        assert_eq!(sc.stats().live_chunks, 12);
    }

    #[test]
    fn concurrent_bump_claims_are_disjoint() {
        use std::collections::HashSet;
        use std::sync::Arc;
        let sc = Arc::new(SizeClass::new(64));
        let mut page = vec![0u8; 64 * 1024];
        sc.install_page(page.as_mut_ptr(), 64 * 1024);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let sc = Arc::clone(&sc);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(p) = sc.try_alloc() {
                        got.push(p as usize);
                    }
                    got
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len(), 1024);
        assert_eq!(all.iter().collect::<HashSet<_>>().len(), 1024);
    }

    #[test]
    fn concurrent_batch_transfers_conserve_chunks() {
        use std::collections::HashSet;
        use std::sync::Arc;
        let sc = Arc::new(SizeClass::new(64));
        let mut page = vec![0u8; 64 * 1024]; // 1024 chunks
        sc.install_page(page.as_mut_ptr(), 64 * 1024);
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let sc = Arc::clone(&sc);
                std::thread::spawn(move || {
                    let mut rng = crate::sync::Xoshiro256::seeded(t);
                    let mut held: Vec<*mut u8> = Vec::new();
                    for _ in 0..2_000 {
                        if rng.chance(0.5) {
                            let want = 1 + rng.next_below(16) as usize;
                            unsafe { sc.alloc_batch(&mut held, want) };
                        } else if !held.is_empty() {
                            let n = 1 + rng.next_below(held.len() as u64) as usize;
                            let tail: Vec<*mut u8> =
                                held.drain(held.len() - n..).collect();
                            unsafe { sc.free_batch(&tail) };
                        }
                    }
                    held.iter().map(|p| *p as usize).collect::<Vec<_>>()
                })
            })
            .collect();
        let mut live: Vec<usize> = Vec::new();
        for h in handles {
            live.extend(h.join().unwrap());
        }
        // Drain everything left in shared structures.
        let mut rest = Vec::new();
        loop {
            let got = unsafe { sc.alloc_batch(&mut rest, 64) };
            if got == 0 {
                break;
            }
        }
        let all: Vec<usize> = live
            .iter()
            .copied()
            .chain(rest.iter().map(|p| *p as usize))
            .collect();
        assert_eq!(all.len(), 1024, "no chunk lost or duplicated");
        assert_eq!(all.iter().collect::<HashSet<_>>().len(), 1024);
    }
}
