//! Multi-tenant bench: skewed per-tenant footprints under equal budget
//! splits, with the Memshare-style arbiter on or off.
//!
//! The scenario the arbiter exists for: N tenants share one cache, each
//! gets an equal slice of the byte budget at registration, but their
//! working sets differ (footprints follow a power-law across tenants,
//! `--tenant-skew`). A static partition strands memory at the small
//! tenants while the large ones evict their own hot keys; the arbiter
//! reads the shadow-hit signal and moves page budget toward the pain.
//! Running the same deterministic workload with the arbiter off and on
//! (`fleec bench --tenants N`) quantifies the difference as aggregate
//! and per-tenant hit ratios — the repo's `BENCH_tenants.json` artifact.
//!
//! The loop drives the engine the way the server does — thread-local
//! tenant stamp around every crossing, namespaced execution keys, the
//! same hit/shadow accounting [`crate::cache::tenant::TenantSink`]
//! performs — just without a socket in the middle.

use std::sync::Arc;

use crate::cache::tenant::{PlaneConfig, TenantPlane, TenantSnapshot};
use crate::cache::{hash_key, Cache};
use crate::sync::Xoshiro256;
use crate::workload::{encode_key, fill_value, Zipf, KEY_LEN};

/// One multi-tenant bench configuration.
#[derive(Debug, Clone)]
pub struct TenantBenchSpec {
    /// Named tenants (≥ 2; each gets `mem_limit / tenants` at
    /// registration).
    pub tenants: usize,
    /// Footprint skew across tenants: tenant `i`'s share of the key
    /// catalog is proportional to `(i + 1)^skew`. 0 = identical
    /// footprints (the arbiter has nothing to win).
    pub skew: f64,
    /// Total distinct keys across all tenants.
    pub catalog: u64,
    /// Per-tenant zipfian access skew.
    pub alpha: f64,
    /// Fraction of each tenant's ops that are reads (misses re-cache,
    /// the standard cache-miss protocol).
    pub read_ratio: f64,
    /// Value bytes per item.
    pub value_bytes: usize,
    /// Total operations (round-robined across tenants).
    pub ops: u64,
    /// Run a maintenance tick (CLOCK decay + arbitration) every this
    /// many operations.
    pub maintenance_every: u64,
    /// RNG seed; per-tenant streams derive from it.
    pub seed: u64,
}

impl Default for TenantBenchSpec {
    fn default() -> Self {
        TenantBenchSpec {
            tenants: 4,
            skew: 1.0,
            catalog: 200_000,
            alpha: 0.99,
            read_ratio: 0.95,
            value_bytes: 256,
            ops: 2_000_000,
            maintenance_every: 4096,
            seed: 0xF1EE_C0DE,
        }
    }
}

/// Per-tenant outcome row (plane snapshot plus the bench's own
/// footprint fact).
#[derive(Debug, Clone)]
pub struct TenantBenchRow {
    pub snapshot: TenantSnapshot,
    /// Distinct keys this tenant cycled through.
    pub catalog: u64,
}

/// One full run's outcome.
#[derive(Debug, Clone)]
pub struct TenantBenchReport {
    pub arbiter: bool,
    pub rows: Vec<TenantBenchRow>,
    /// Aggregate gets across named tenants.
    pub gets: u64,
    /// Aggregate hits across named tenants.
    pub hits: u64,
    /// Lifetime bytes the arbiter moved (0 with it off).
    pub moved_bytes: u64,
}

impl TenantBenchReport {
    pub fn hit_ratio(&self) -> f64 {
        if self.gets == 0 {
            0.0
        } else {
            self.hits as f64 / self.gets as f64
        }
    }
}

/// Split `spec.catalog` across tenants by the power-law weights.
/// Public so the CLI can print the footprints it is about to run.
pub fn footprints(spec: &TenantBenchSpec) -> Vec<u64> {
    let weights: Vec<f64> = (0..spec.tenants)
        .map(|i| ((i + 1) as f64).powf(spec.skew))
        .collect();
    let total: f64 = weights.iter().sum();
    weights
        .iter()
        .map(|w| ((spec.catalog as f64) * w / total).max(64.0) as u64)
        .collect()
}

/// Run the workload against a fresh `cache` and report per-tenant hit
/// ratios. Deterministic for a given `(spec, arbiter)` pair, so the
/// off/on comparison isolates the arbiter.
pub fn run_tenant_bench(
    cache: &Arc<dyn Cache>,
    spec: &TenantBenchSpec,
    arbiter: bool,
) -> TenantBenchReport {
    assert!(spec.tenants >= 2, "need at least two tenants to arbitrate");
    let plane = TenantPlane::new(cache.as_ref(), PlaneConfig { arbiter });
    let mut tenants = Vec::with_capacity(spec.tenants);
    for (i, catalog) in footprints(spec).into_iter().enumerate() {
        let name = format!("t{i}");
        let id = plane
            .register(name.as_bytes())
            .expect("bench tenant registration");
        tenants.push(TenantLoop {
            id,
            prefix: plane.prefix_of(id),
            catalog,
            zipf: Zipf::new(catalog, spec.alpha),
            rng: Xoshiro256::seeded(spec.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1)),
        });
    }

    let mut key = [0u8; KEY_LEN];
    let mut ns_key = Vec::with_capacity(KEY_LEN + 66);
    let mut value = vec![0u8; spec.value_bytes];
    for op in 0..spec.ops {
        let t = &mut tenants[(op % spec.tenants as u64) as usize];
        let id = t.zipf.sample(&mut t.rng);
        let read = (t.rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64 <= spec.read_ratio;
        ns_key.clear();
        ns_key.extend_from_slice(&t.prefix);
        ns_key.extend_from_slice(encode_key(&mut key, id));
        // Same attribution bracket the server's flush puts around an
        // engine crossing: allocations inside it land on this tenant.
        crate::slab::tenant::set_current(t.id);
        if read && cache.get(&ns_key).is_some() {
            plane.note_get(t.id, true, || 0);
        } else {
            if read {
                plane.note_get(t.id, false, || hash_key(&ns_key));
            }
            // Miss (or write): fetch-and-cache.
            fill_value(id, &mut value);
            let _ = cache.set(&ns_key, &value, 0, 0);
            plane.note_set(t.id, hash_key(&ns_key));
        }
        crate::slab::tenant::set_current(crate::slab::DEFAULT_TENANT);
        if (op + 1) % spec.maintenance_every == 0 {
            cache.maintenance();
            plane.arbitrate();
        }
    }

    let snaps = plane.snapshot();
    let mut rows = Vec::with_capacity(tenants.len());
    let (mut gets, mut hits) = (0u64, 0u64);
    for t in &tenants {
        let snapshot = snaps[t.id as usize].clone();
        gets += snapshot.gets;
        hits += snapshot.hits;
        rows.push(TenantBenchRow {
            snapshot,
            catalog: t.catalog,
        });
    }
    TenantBenchReport {
        arbiter,
        rows,
        gets,
        hits,
        moved_bytes: plane.moved_bytes(),
    }
}

/// One tenant's generator state.
struct TenantLoop {
    id: u8,
    prefix: Vec<u8>,
    catalog: u64,
    zipf: Zipf,
    rng: Xoshiro256,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};

    fn tiny_spec() -> TenantBenchSpec {
        TenantBenchSpec {
            tenants: 3,
            skew: 1.0,
            catalog: 3_000,
            alpha: 1.01,
            read_ratio: 0.9,
            value_bytes: 128,
            ops: 30_000,
            maintenance_every: 512,
            seed: 7,
        }
    }

    #[test]
    fn footprints_follow_the_skew() {
        let spec = tiny_spec();
        let f = footprints(&spec);
        assert_eq!(f.len(), 3);
        assert!(f[0] < f[1] && f[1] < f[2], "{f:?}");
        let flat = footprints(&TenantBenchSpec { skew: 0.0, ..spec });
        assert_eq!(flat[0], flat[2]);
    }

    #[test]
    fn bench_runs_and_reports_per_tenant_rows() {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let spec = tiny_spec();
        let report = run_tenant_bench(&cache, &spec, false);
        assert_eq!(report.rows.len(), 3);
        assert!(report.gets > 0);
        assert!(report.hits > 0, "steady-state reads must hit");
        assert_eq!(report.moved_bytes, 0, "arbiter off must never move budget");
        for row in &report.rows {
            assert!(row.snapshot.gets > 0, "{}", row.snapshot.name);
            assert!(row.snapshot.sets > 0, "{}", row.snapshot.name);
        }
    }
}
