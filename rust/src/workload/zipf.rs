//! Zipfian sampling by rejection-inversion (Hörmann & Derflinger 1996),
//! the same algorithm behind Apache Commons' `RejectionInversionZipfSampler`
//! and `rand_distr::Zipf`. O(1) per sample with no per-rank tables, so
//! catalogs of hundreds of millions of keys cost nothing to set up —
//! exactly what the α-sweep benches need.
//!
//! Ranks are 1-based: rank 1 is the most popular key. `alpha = 0`
//! degenerates to the uniform distribution.

use crate::sync::Xoshiro256;

/// Rejection-inversion zipfian sampler over `{1, …, n}` with exponent α.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    h_x1: f64,
    h_n: f64,
    s: f64,
}

/// `(exp(t) - 1) / t` with a series fallback near 0.
#[inline]
fn helper2(t: f64) -> f64 {
    if t.abs() > 1e-8 {
        t.exp_m1() / t
    } else {
        1.0 + t / 2.0 + t * t / 6.0
    }
}

/// `ln(1 + t) / t` with a series fallback near 0.
#[inline]
fn helper1(t: f64) -> f64 {
    if t.abs() > 1e-8 {
        t.ln_1p() / t
    } else {
        1.0 - t / 2.0 + t * t / 3.0
    }
}

impl Zipf {
    /// Sampler for `n ≥ 1` elements with exponent `alpha ≥ 0`.
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n >= 1, "catalog must be non-empty");
        assert!(alpha >= 0.0 && alpha.is_finite(), "alpha must be ≥ 0");
        let h_x1 = Self::h_integral_static(1.5, alpha) - 1.0;
        let h_n = Self::h_integral_static(n as f64 + 0.5, alpha);
        let s = 2.0
            - Self::h_integral_inverse_static(
                Self::h_integral_static(2.5, alpha) - Self::h_static(2.0, alpha),
                alpha,
            );
        Zipf {
            n,
            alpha,
            h_x1,
            h_n,
            s,
        }
    }

    /// H(x) = ∫ x^{-α} dx, shifted form used by rejection-inversion.
    fn h_integral_static(x: f64, alpha: f64) -> f64 {
        let log_x = x.ln();
        helper2((1.0 - alpha) * log_x) * log_x
    }

    /// h(x) = x^{-α}.
    fn h_static(x: f64, alpha: f64) -> f64 {
        (-alpha * x.ln()).exp()
    }

    /// H^{-1}(x).
    fn h_integral_inverse_static(x: f64, alpha: f64) -> f64 {
        let mut t = x * (1.0 - alpha);
        if t < -1.0 {
            t = -1.0; // numerical guard per the reference implementation
        }
        (helper1(t) * x).exp()
    }

    /// Draw one rank in `[1, n]`.
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        loop {
            let u = self.h_n + rng.next_f64() * (self.h_x1 - self.h_n);
            let x = Self::h_integral_inverse_static(u, self.alpha);
            // Candidate rank, clamped into range.
            let k64 = (x + 0.5) as u64;
            let k = k64.clamp(1, self.n);
            let kf = k as f64;
            if kf - x <= self.s
                || u >= Self::h_integral_static(kf + 0.5, self.alpha) - Self::h_static(kf, self.alpha)
            {
                return k;
            }
        }
    }

    /// Number of elements.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// The exponent.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Exact probability mass of each rank (O(n); analytics/tests only).
    pub fn pmf(n: u64, alpha: f64) -> Vec<f64> {
        let weights: Vec<f64> = (1..=n).map(|k| (k as f64).powf(-alpha)).collect();
        let total: f64 = weights.iter().sum();
        weights.into_iter().map(|w| w / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn histogram(n: u64, alpha: f64, samples: usize, seed: u64) -> Vec<u64> {
        let z = Zipf::new(n, alpha);
        let mut rng = Xoshiro256::seeded(seed);
        let mut h = vec![0u64; n as usize];
        for _ in 0..samples {
            let k = z.sample(&mut rng);
            assert!((1..=n).contains(&k));
            h[(k - 1) as usize] += 1;
        }
        h
    }

    #[test]
    fn alpha_zero_is_uniform() {
        let h = histogram(100, 0.0, 200_000, 1);
        let expect = 200_000.0 / 100.0;
        for (i, &c) in h.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.15, "rank {} count {} deviates {:.2}", i + 1, c, dev);
        }
    }

    #[test]
    fn empirical_frequencies_match_pmf() {
        for &alpha in &[0.5, 0.99, 1.0, 1.3] {
            let n = 1000;
            let samples = 300_000;
            let h = histogram(n, alpha, samples, 42);
            let pmf = Zipf::pmf(n, alpha);
            // Check the head (top-10 ranks hold most mass).
            for k in 0..10 {
                let emp = h[k] as f64 / samples as f64;
                let dev = (emp - pmf[k]).abs() / pmf[k];
                assert!(
                    dev < 0.08,
                    "alpha {alpha} rank {} empirical {emp:.5} vs pmf {:.5}",
                    k + 1,
                    pmf[k]
                );
            }
        }
    }

    #[test]
    fn higher_alpha_is_more_skewed() {
        let top_share = |alpha: f64| -> f64 {
            let h = histogram(10_000, alpha, 100_000, 7);
            let top: u64 = h[..10].iter().sum();
            top as f64 / 100_000.0
        };
        let s05 = top_share(0.5);
        let s099 = top_share(0.99);
        let s13 = top_share(1.3);
        assert!(s05 < s099 && s099 < s13, "skew ordering: {s05} {s099} {s13}");
        assert!(s13 > 0.5, "alpha=1.3 must concentrate >50% on top-10: {s13}");
    }

    #[test]
    fn single_element_catalog() {
        let z = Zipf::new(1, 0.99);
        let mut rng = Xoshiro256::seeded(3);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 1);
        }
    }

    #[test]
    fn pmf_sums_to_one() {
        for &alpha in &[0.0, 0.7, 1.0, 1.5] {
            let total: f64 = Zipf::pmf(500, alpha).iter().sum();
            assert!((total - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_empty_catalog() {
        let _ = Zipf::new(0, 1.0);
    }
}
