//! Workload generation — the paper's evaluation harness substrate.
//!
//! The evaluation varies three contention levers: access skew (zipfian
//! α), item size, and read ratio (Fig. 1 uses 99 % reads with small
//! items). [`WorkloadSpec`] captures one configuration; [`OpStream`]
//! turns it into an infinite operation stream; [`driver`] runs closed-loop
//! worker threads against any [`crate::cache::Cache`]; [`Trace`] freezes a
//! finite sequence so hit-ratio comparisons feed *identical* accesses to
//! every engine.

pub mod driver;
pub mod tenants;
pub mod zipf;

pub use driver::{run_driver, run_wire, DriverOptions, DriverReport, WireOptions, WireReport};
pub use tenants::{run_tenant_bench, TenantBenchReport, TenantBenchSpec};
pub use zipf::Zipf;

use crate::sync::{SplitMix64, Xoshiro256};

/// Value sizing policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ValueSize {
    /// Every value is exactly this many bytes.
    Fixed(usize),
    /// Deterministic per key in `[min, max)` — repeatable across engines
    /// and runs, so validation can recompute expected bytes.
    PerKey { min: usize, max: usize },
}

impl ValueSize {
    /// Size of the value for `key_id`.
    pub fn for_key(&self, key_id: u64) -> usize {
        match *self {
            ValueSize::Fixed(n) => n,
            ValueSize::PerKey { min, max } => {
                debug_assert!(max > min);
                let h = SplitMix64::new(key_id ^ 0x5151_5151).next_u64();
                min + (h % (max - min) as u64) as usize
            }
        }
    }
}

/// One workload configuration (one point in the paper's sweeps).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Number of distinct keys.
    pub catalog: u64,
    /// Zipfian skew (0 = uniform; Fig. 1 sweeps ~0.5 … 1.3).
    pub alpha: f64,
    /// Fraction of operations that are reads (Fig. 1: 0.99).
    pub read_ratio: f64,
    /// Value sizing.
    pub value_size: ValueSize,
    /// RNG seed; streams for different threads derive from it.
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            catalog: 100_000,
            alpha: 0.99,
            read_ratio: 0.99,
            value_size: ValueSize::Fixed(64),
            seed: 0xF1EE_C0DE,
        }
    }
}

/// Fixed-width key encoding: `k` + 15 decimal digits (16 bytes).
pub const KEY_LEN: usize = 16;

/// Write the canonical key for `id` into `buf`, returning the key slice.
pub fn encode_key(buf: &mut [u8; KEY_LEN], id: u64) -> &[u8] {
    buf[0] = b'k';
    let mut v = id;
    for i in (1..KEY_LEN).rev() {
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
    }
    &buf[..]
}

/// Parse a canonical key back to its id (tests / validation).
pub fn decode_key(key: &[u8]) -> Option<u64> {
    if key.len() != KEY_LEN || key[0] != b'k' {
        return None;
    }
    let mut v = 0u64;
    for &b in &key[1..] {
        if !b.is_ascii_digit() {
            return None;
        }
        v = v * 10 + (b - b'0') as u64;
    }
    Some(v)
}

/// Deterministic value bytes for `key_id` (validation can recompute them).
pub fn fill_value(key_id: u64, out: &mut [u8]) {
    let mut g = SplitMix64::new(key_id.wrapping_mul(0x9E37_79B9));
    let mut i = 0;
    while i < out.len() {
        let w = g.next_u64().to_le_bytes();
        let n = (out.len() - i).min(8);
        out[i..i + n].copy_from_slice(&w[..n]);
        i += n;
    }
}

/// Verify `data` matches the deterministic pattern for `key_id`.
pub fn check_value(key_id: u64, data: &[u8]) -> bool {
    let mut expect = vec![0u8; data.len()];
    fill_value(key_id, &mut expect);
    expect == data
}

/// One generated operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Read the key with this id.
    Get(u64),
    /// Write the key with this id (size comes from the spec).
    Set(u64),
}

/// Infinite operation stream for one worker thread.
pub struct OpStream {
    spec: WorkloadSpec,
    rng: Xoshiro256,
    zipf: Zipf,
}

impl OpStream {
    /// Stream `stream_id` (one per thread) of the spec.
    pub fn new(spec: &WorkloadSpec, stream_id: u64) -> Self {
        OpStream {
            rng: Xoshiro256::seeded(spec.seed ^ stream_id.wrapping_mul(0xA076_1D64_78BD_642F)),
            zipf: Zipf::new(spec.catalog, spec.alpha),
            spec: spec.clone(),
        }
    }

    /// Next operation. Zipf ranks are 1-based; key ids are 0-based.
    #[inline]
    pub fn next_op(&mut self) -> Op {
        let id = self.zipf.sample(&mut self.rng) - 1;
        if self.rng.chance(self.spec.read_ratio) {
            Op::Get(id)
        } else {
            Op::Set(id)
        }
    }

    /// The spec this stream follows.
    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }
}

/// A frozen operation sequence, identical for every engine — used by the
/// hit-ratio experiment (E1) where fairness requires replaying the same
/// accesses.
#[derive(Debug, Clone)]
pub struct Trace {
    pub ops: Vec<Op>,
    pub spec: WorkloadSpec,
}

impl Trace {
    /// Generate `len` operations from the spec's seed.
    pub fn generate(spec: &WorkloadSpec, len: usize) -> Self {
        let mut stream = OpStream::new(spec, 0);
        Trace {
            ops: (0..len).map(|_| stream.next_op()).collect(),
            spec: spec.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_encoding_roundtrips() {
        let mut buf = [0u8; KEY_LEN];
        for id in [0u64, 1, 99, 123_456_789, u32::MAX as u64] {
            let k = encode_key(&mut buf, id);
            assert_eq!(k.len(), KEY_LEN);
            assert_eq!(decode_key(k), Some(id));
        }
        assert_eq!(decode_key(b"xnothex"), None);
        assert_eq!(decode_key(b"kaaaaaaaaaaaaaaa"), None);
    }

    #[test]
    fn value_fill_is_deterministic_and_checkable() {
        let mut a = vec![0u8; 100];
        let mut b = vec![0u8; 100];
        fill_value(7, &mut a);
        fill_value(7, &mut b);
        assert_eq!(a, b);
        assert!(check_value(7, &a));
        a[3] ^= 1;
        assert!(!check_value(7, &a));
        fill_value(8, &mut b);
        assert!(!check_value(7, &b));
    }

    #[test]
    fn per_key_sizes_are_stable_and_bounded() {
        let vs = ValueSize::PerKey { min: 10, max: 50 };
        for id in 0..1000 {
            let s = vs.for_key(id);
            assert!((10..50).contains(&s));
            assert_eq!(s, vs.for_key(id));
        }
        assert_eq!(ValueSize::Fixed(64).for_key(3), 64);
    }

    #[test]
    fn read_ratio_is_respected() {
        let spec = WorkloadSpec {
            read_ratio: 0.99,
            ..Default::default()
        };
        let mut s = OpStream::new(&spec, 1);
        let n = 50_000;
        let reads = (0..n)
            .filter(|_| matches!(s.next_op(), Op::Get(_)))
            .count();
        let ratio = reads as f64 / n as f64;
        assert!((ratio - 0.99).abs() < 0.01, "read ratio {ratio}");
    }

    #[test]
    fn streams_differ_per_thread_but_replay_per_seed() {
        let spec = WorkloadSpec::default();
        let seq = |sid: u64| -> Vec<Op> {
            let mut s = OpStream::new(&spec, sid);
            (0..64).map(|_| s.next_op()).collect()
        };
        assert_eq!(seq(1), seq(1));
        assert_ne!(seq(1), seq(2));
    }

    #[test]
    fn trace_is_reproducible() {
        let spec = WorkloadSpec::default();
        let a = Trace::generate(&spec, 1000);
        let b = Trace::generate(&spec, 1000);
        assert_eq!(a.ops, b.ops);
    }
}
