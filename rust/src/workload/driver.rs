//! Closed-loop load driver: N worker threads issue operations from
//! per-thread [`OpStream`]s against one engine and report throughput,
//! hit-ratio and latency percentiles — the measurement core behind every
//! figure-regenerating bench.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cache::{Cache, Op as CacheOp, OpResult};
use crate::client::{Client, PipelineReply, PreparedPipeline};
use crate::metrics::{HistogramSummary, LatencyHistogram};
use crate::workload::{check_value, encode_key, fill_value, Op, OpStream, WorkloadSpec, KEY_LEN};

/// When the run stops.
#[derive(Debug, Clone, Copy)]
pub enum StopRule {
    /// Each thread performs exactly this many operations.
    OpsPerThread(u64),
    /// All threads run until the deadline.
    Duration(Duration),
}

/// Driver configuration.
#[derive(Debug, Clone)]
pub struct DriverOptions {
    pub threads: usize,
    pub stop: StopRule,
    /// Pre-insert the whole catalog before measuring (bounded by memory:
    /// the engine evicts as needed, leaving it warm).
    pub prefill: bool,
    /// Measure latency on every k-th operation (1 = all).
    pub sample_every: u64,
    /// Verify the bytes of every sampled hit against the deterministic
    /// per-key pattern (corruption canary for concurrency tests).
    pub validate: bool,
    /// Ops issued per engine crossing. 1 = the single-key convenience
    /// methods; >1 = pipelined batches through
    /// [`crate::cache::Cache::execute_batch`] (the serving plane's shape:
    /// one EBR pin / one dispatch per batch on engines that support it).
    /// In batch mode latency is sampled per *batch* and recorded as the
    /// amortized per-op time.
    pub batch: usize,
}

impl Default for DriverOptions {
    fn default() -> Self {
        DriverOptions {
            threads: 4,
            stop: StopRule::OpsPerThread(100_000),
            prefill: true,
            sample_every: 4,
            validate: false,
            batch: 1,
        }
    }
}

/// Aggregated result of one driver run.
#[derive(Debug, Clone)]
pub struct DriverReport {
    pub engine: &'static str,
    pub threads: usize,
    pub elapsed: Duration,
    pub total_ops: u64,
    pub gets: u64,
    pub hits: u64,
    pub sets: u64,
    pub store_failures: u64,
    pub validation_failures: u64,
    pub latency: HistogramSummary,
    pub get_latency: HistogramSummary,
    pub set_latency: HistogramSummary,
}

impl DriverReport {
    /// Operations per second over the whole run.
    pub fn throughput(&self) -> f64 {
        self.total_ops as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Hit ratio over the measured window.
    pub fn hit_ratio(&self) -> f64 {
        if self.gets == 0 {
            0.0
        } else {
            self.hits as f64 / self.gets as f64
        }
    }

    /// One-line summary used by benches.
    pub fn row(&self) -> String {
        format!(
            "{:>10} thr={:2} ops={:>9} tput={:>10.0}/s hit={:.4} p50={:>7}ns p99={:>8}ns",
            self.engine,
            self.threads,
            self.total_ops,
            self.throughput(),
            self.hit_ratio(),
            self.latency.p50_ns,
            self.latency.p99_ns
        )
    }
}

/// Sets issued per engine crossing during prefill. Batching the fill
/// rides the same fast path the serving plane uses (one EBR pin per
/// chunk on FLeeC, one router partition per chunk on sharded engines),
/// which matters when benches prefill 10⁵⁺ keys per configuration.
const PREFILL_CHUNK: usize = 64;

/// Pre-insert the catalog (ascending popularity ids last so the hottest
/// keys are freshest when memory is tight).
pub fn prefill(cache: &dyn Cache, spec: &WorkloadSpec) {
    let mut keys = vec![[0u8; KEY_LEN]; PREFILL_CHUNK];
    let mut values: Vec<Vec<u8>> = vec![Vec::new(); PREFILL_CHUNK];
    let mut pending = 0usize;
    let flush = |cache: &dyn Cache, keys: &[[u8; KEY_LEN]], values: &[Vec<u8>], n: usize| {
        let ops: Vec<CacheOp<'_>> = (0..n)
            .map(|i| CacheOp::Set {
                key: &keys[i],
                value: &values[i],
                flags: 0,
                exptime: 0,
            })
            .collect();
        let _ = cache.execute_batch(&ops);
    };
    // Insert cold→hot: ids descending, so the popular head survives any
    // eviction that happens during the fill.
    for id in (0..spec.catalog).rev() {
        let len = spec.value_size.for_key(id);
        values[pending].resize(len, 0);
        fill_value(id, &mut values[pending]);
        encode_key(&mut keys[pending], id);
        pending += 1;
        if pending == PREFILL_CHUNK {
            flush(cache, &keys, &values, pending);
            pending = 0;
        }
    }
    if pending > 0 {
        flush(cache, &keys, &values, pending);
    }
}

/// Replay a frozen [`crate::workload::Trace`] single-threaded against an
/// engine and return `(hit_ratio, hits, gets)`. Used by the hit-ratio
/// experiment (E1), where every engine must see *identical* accesses.
pub fn replay_trace(cache: &dyn Cache, trace: &crate::workload::Trace) -> (f64, u64, u64) {
    let mut key = [0u8; KEY_LEN];
    let mut value = vec![0u8; 4096];
    let (mut hits, mut gets) = (0u64, 0u64);
    for op in &trace.ops {
        match *op {
            Op::Get(id) => {
                gets += 1;
                let k = encode_key(&mut key, id);
                if cache.get(k).is_some() {
                    hits += 1;
                } else {
                    // Cache-miss protocol: the application fetches from the
                    // backing store and re-caches — required for hit-ratio
                    // experiments to reach steady state.
                    let len = trace.spec.value_size.for_key(id);
                    if value.len() < len {
                        value.resize(len, 0);
                    }
                    fill_value(id, &mut value[..len]);
                    let _ = cache.set(k, &value[..len], 0, 0);
                }
            }
            Op::Set(id) => {
                let len = trace.spec.value_size.for_key(id);
                if value.len() < len {
                    value.resize(len, 0);
                }
                fill_value(id, &mut value[..len]);
                let k = encode_key(&mut key, id);
                let _ = cache.set(k, &value[..len], 0, 0);
            }
        }
    }
    let ratio = if gets == 0 { 0.0 } else { hits as f64 / gets as f64 };
    (ratio, hits, gets)
}

/// Run the workload; returns the aggregated report.
pub fn run_driver(cache: &Arc<dyn Cache>, spec: &WorkloadSpec, opts: &DriverOptions) -> DriverReport {
    if opts.prefill {
        prefill(cache.as_ref(), spec);
    }

    let stop_flag = Arc::new(AtomicBool::new(false));
    let total_ops = Arc::new(AtomicU64::new(0));
    let gets = Arc::new(AtomicU64::new(0));
    let hits = Arc::new(AtomicU64::new(0));
    let sets = Arc::new(AtomicU64::new(0));
    let store_failures = Arc::new(AtomicU64::new(0));
    let validation_failures = Arc::new(AtomicU64::new(0));
    let latency = Arc::new(LatencyHistogram::new());
    let get_latency = Arc::new(LatencyHistogram::new());
    let set_latency = Arc::new(LatencyHistogram::new());

    let start = Instant::now();
    let deadline = match opts.stop {
        StopRule::Duration(d) => Some(start + d),
        StopRule::OpsPerThread(_) => None,
    };
    let ops_budget = match opts.stop {
        StopRule::OpsPerThread(n) => n,
        StopRule::Duration(_) => u64::MAX,
    };

    let workers: Vec<_> = (0..opts.threads)
        .map(|t| {
            let cache = Arc::clone(cache);
            let spec = spec.clone();
            let opts = opts.clone();
            let stop_flag = Arc::clone(&stop_flag);
            let total_ops = Arc::clone(&total_ops);
            let gets = Arc::clone(&gets);
            let hits = Arc::clone(&hits);
            let sets = Arc::clone(&sets);
            let store_failures = Arc::clone(&store_failures);
            let validation_failures = Arc::clone(&validation_failures);
            let latency = Arc::clone(&latency);
            let get_latency = Arc::clone(&get_latency);
            let set_latency = Arc::clone(&set_latency);
            std::thread::spawn(move || {
                let mut stream = OpStream::new(&spec, t as u64 + 1);
                let mut key = [0u8; KEY_LEN];
                let mut value = vec![0u8; 4096];
                let (mut l_ops, mut l_gets, mut l_hits, mut l_sets) = (0u64, 0u64, 0u64, 0u64);
                let (mut l_sfail, mut l_vfail) = (0u64, 0u64);
                let mut n = 0u64;
                let batch = opts.batch.max(1);
                if batch > 1 {
                    // Batched mode: fill per-slot scratch buffers, build a
                    // borrowed CacheOp batch, and cross the engine once.
                    let mut keys = vec![[0u8; KEY_LEN]; batch];
                    let mut values: Vec<Vec<u8>> = vec![Vec::new(); batch];
                    let mut pending: Vec<Op> = Vec::with_capacity(batch);
                    let mut batches = 0u64;
                    while n < ops_budget {
                        if stop_flag.load(Ordering::Relaxed) {
                            break;
                        }
                        let take = (batch as u64).min(ops_budget - n) as usize;
                        pending.clear();
                        for i in 0..take {
                            let op = stream.next_op();
                            match op {
                                Op::Get(id) => {
                                    encode_key(&mut keys[i], id);
                                }
                                Op::Set(id) => {
                                    encode_key(&mut keys[i], id);
                                    let len = spec.value_size.for_key(id);
                                    values[i].resize(len, 0);
                                    fill_value(id, &mut values[i]);
                                }
                            }
                            pending.push(op);
                        }
                        let batch_ops: Vec<CacheOp<'_>> = pending
                            .iter()
                            .enumerate()
                            .map(|(i, op)| match *op {
                                Op::Get(_) => CacheOp::Get { key: &keys[i] },
                                Op::Set(_) => CacheOp::Set {
                                    key: &keys[i],
                                    value: &values[i],
                                    flags: 0,
                                    exptime: 0,
                                },
                            })
                            .collect();
                        batches += 1;
                        let sampled = batches % opts.sample_every == 0;
                        let t0 = if sampled { Some(Instant::now()) } else { None };
                        let results = cache.execute_batch(&batch_ops);
                        if let Some(t0) = t0 {
                            // Amortized per-op cost of the whole crossing.
                            let ns = t0.elapsed().as_nanos() as u64 / take.max(1) as u64;
                            latency.record(ns);
                        }
                        for (op, r) in pending.iter().zip(&results) {
                            match op {
                                Op::Get(id) => {
                                    l_gets += 1;
                                    if let OpResult::Value(Some(v)) = r {
                                        l_hits += 1;
                                        if opts.validate && sampled {
                                            let expect_len = spec.value_size.for_key(*id);
                                            if v.data.len() != expect_len
                                                || !check_value(*id, &v.data)
                                            {
                                                l_vfail += 1;
                                            }
                                        }
                                    }
                                }
                                Op::Set(_) => {
                                    l_sets += 1;
                                    if *r != OpResult::Store(crate::cache::StoreOutcome::Stored) {
                                        l_sfail += 1;
                                    }
                                }
                            }
                        }
                        n += take as u64;
                        l_ops += take as u64;
                    }
                    total_ops.fetch_add(l_ops, Ordering::Relaxed);
                    gets.fetch_add(l_gets, Ordering::Relaxed);
                    hits.fetch_add(l_hits, Ordering::Relaxed);
                    sets.fetch_add(l_sets, Ordering::Relaxed);
                    store_failures.fetch_add(l_sfail, Ordering::Relaxed);
                    validation_failures.fetch_add(l_vfail, Ordering::Relaxed);
                    return;
                }
                while n < ops_budget {
                    // Deadline check amortized over 256 ops.
                    if n % 256 == 0 && stop_flag.load(Ordering::Relaxed) {
                        break;
                    }
                    n += 1;
                    let op = stream.next_op();
                    let sampled = n % opts.sample_every == 0;
                    let t0 = if sampled { Some(Instant::now()) } else { None };
                    match op {
                        Op::Get(id) => {
                            let k = encode_key(&mut key, id);
                            let res = cache.get(k);
                            l_gets += 1;
                            if let Some(r) = res {
                                l_hits += 1;
                                if opts.validate && sampled {
                                    let expect_len = spec.value_size.for_key(id);
                                    if r.data.len() != expect_len || !check_value(id, &r.data) {
                                        l_vfail += 1;
                                    }
                                }
                            }
                            if let Some(t0) = t0 {
                                let ns = t0.elapsed().as_nanos() as u64;
                                latency.record(ns);
                                get_latency.record(ns);
                            }
                        }
                        Op::Set(id) => {
                            let len = spec.value_size.for_key(id);
                            if value.len() < len {
                                value.resize(len, 0);
                            }
                            fill_value(id, &mut value[..len]);
                            let k = encode_key(&mut key, id);
                            let out = cache.set(k, &value[..len], 0, 0);
                            l_sets += 1;
                            if out != crate::cache::StoreOutcome::Stored {
                                l_sfail += 1;
                            }
                            if let Some(t0) = t0 {
                                let ns = t0.elapsed().as_nanos() as u64;
                                latency.record(ns);
                                set_latency.record(ns);
                            }
                        }
                    }
                    l_ops += 1;
                }
                total_ops.fetch_add(l_ops, Ordering::Relaxed);
                gets.fetch_add(l_gets, Ordering::Relaxed);
                hits.fetch_add(l_hits, Ordering::Relaxed);
                sets.fetch_add(l_sets, Ordering::Relaxed);
                store_failures.fetch_add(l_sfail, Ordering::Relaxed);
                validation_failures.fetch_add(l_vfail, Ordering::Relaxed);
            })
        })
        .collect();

    if let Some(deadline) = deadline {
        let now = Instant::now();
        if deadline > now {
            std::thread::sleep(deadline - now);
        }
        stop_flag.store(true, Ordering::Relaxed);
    }
    for w in workers {
        w.join().expect("worker panicked");
    }
    let elapsed = start.elapsed();

    DriverReport {
        engine: cache.engine_name(),
        threads: opts.threads,
        elapsed,
        total_ops: total_ops.load(Ordering::Relaxed),
        gets: gets.load(Ordering::Relaxed),
        hits: hits.load(Ordering::Relaxed),
        sets: sets.load(Ordering::Relaxed),
        store_failures: store_failures.load(Ordering::Relaxed),
        validation_failures: validation_failures.load(Ordering::Relaxed),
        latency: latency.summary(),
        get_latency: get_latency.summary(),
        set_latency: set_latency.summary(),
    }
}

/// Options for the over-the-wire **connection-scaling** driver
/// ([`run_wire`]): `conns` open TCP connections multiplexed by a bounded
/// worker pool, each connection issuing pipelined gets/sets. This is the
/// load shape that exercises the server *front-end* (thread-per-connection
/// vs. reactor) rather than the engine — `fleec bench --conns N` and the
/// `benches/batch_pipeline.rs` conns sweep drive it.
#[derive(Debug, Clone)]
pub struct WireOptions {
    /// Simultaneously-open client connections.
    pub conns: usize,
    /// Ops per pipeline (one write / one reply burst per round).
    pub depth: usize,
    /// Ops each connection issues over the whole run.
    pub ops_per_conn: u64,
    /// Worker threads multiplexing the connections (0 = `min(conns, 16)`).
    /// Workers write **all** their connections' pipelines before
    /// collecting replies, so every connection keeps a request in flight
    /// regardless of the worker count.
    pub workers: usize,
    /// Pre-insert the catalog through one pipelined connection first.
    pub prefill: bool,
    /// Per-reply client read timeout (`None` = wait forever). A timed-out
    /// connection is abandoned and counted in [`WireReport::timeouts`] —
    /// its reply stream position is unknown, so it cannot be reused — but
    /// the run continues on the surviving connections. This is what lets
    /// the chaos harness drive a fault-injected server without one stalled
    /// connection hanging the whole bench.
    pub read_timeout: Option<Duration>,
}

impl Default for WireOptions {
    fn default() -> Self {
        WireOptions {
            conns: 1,
            depth: 16,
            ops_per_conn: 10_000,
            workers: 0,
            prefill: true,
            read_timeout: None,
        }
    }
}

/// Aggregated result of one [`run_wire`] run.
#[derive(Debug, Clone)]
pub struct WireReport {
    pub conns: usize,
    pub total_ops: u64,
    pub gets: u64,
    pub hits: u64,
    /// Connections abandoned because a reply read exceeded
    /// [`WireOptions::read_timeout`].
    pub timeouts: u64,
    pub elapsed: Duration,
}

impl WireReport {
    /// Operations per second over the whole run.
    pub fn throughput(&self) -> f64 {
        self.total_ops as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Hit ratio over the measured window.
    pub fn hit_ratio(&self) -> f64 {
        if self.gets == 0 {
            0.0
        } else {
            self.hits as f64 / self.gets as f64
        }
    }

    /// One-line summary used by benches. Timeouts only appear when they
    /// happened — the healthy-run row format stays stable.
    pub fn row(&self) -> String {
        let mut row = format!(
            "conns={:>4} ops={:>9} tput={:>10.0}/s hit={:.4}",
            self.conns,
            self.total_ops,
            self.throughput(),
            self.hit_ratio()
        );
        if self.timeouts > 0 {
            row.push_str(&format!(" timeouts={}", self.timeouts));
        }
        row
    }
}

/// Pre-insert the catalog over the wire (cold → hot, matching
/// [`prefill`]) through one pipelined connection.
fn wire_prefill(addr: SocketAddr, spec: &WorkloadSpec) -> crate::Result<()> {
    const CHUNK: u64 = 128;
    let mut c = Client::connect(addr)?;
    let mut key = [0u8; KEY_LEN];
    let mut val = vec![0u8; 4096];
    let mut id = spec.catalog;
    while id > 0 {
        let take = CHUNK.min(id);
        let mut p = c.pipeline();
        for _ in 0..take {
            id -= 1;
            let len = spec.value_size.for_key(id);
            if val.len() < len {
                val.resize(len, 0);
            }
            fill_value(id, &mut val[..len]);
            p.set(encode_key(&mut key, id), &val[..len], 0, 0);
        }
        p.run()?;
    }
    Ok(())
}

/// Run the connection-scaling workload against a served address; returns
/// the aggregated report. Connections are distributed round-robin over
/// the worker pool; each worker runs split-phase pipelining (send to all
/// its connections, then receive from all) so the server juggles `conns`
/// active sockets at once.
pub fn run_wire(
    addr: SocketAddr,
    spec: &WorkloadSpec,
    opts: &WireOptions,
) -> crate::Result<WireReport> {
    let conns = opts.conns.max(1);
    let depth = opts.depth.max(1);
    let workers = if opts.workers > 0 {
        opts.workers.min(conns)
    } else {
        conns.min(16)
    };
    if opts.prefill {
        wire_prefill(addr, spec)?;
    }
    let rounds = (opts.ops_per_conn + depth as u64 - 1) / depth as u64;
    let read_timeout = opts.read_timeout;
    let t0 = Instant::now();
    let mut totals = (0u64, 0u64, 0u64, 0u64); // (ops, gets, hits, timeouts)
    let mut first_err: Option<anyhow::Error> = None;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            handles.push(s.spawn(move || -> crate::Result<(u64, u64, u64, u64)> {
                let my: Vec<usize> = (w..conns).step_by(workers).collect();
                let mut clients = Vec::with_capacity(my.len());
                for _ in &my {
                    clients.push(Client::connect_with(addr, read_timeout)?);
                }
                let mut streams: Vec<OpStream> = my
                    .iter()
                    .map(|&c| OpStream::new(spec, c as u64 + 1))
                    .collect();
                let mut pending: Vec<Option<PreparedPipeline>> =
                    (0..clients.len()).map(|_| None).collect();
                // Connections abandoned after a reply read timed out: the
                // stream position is unknown, so they are never reused.
                let mut dead: Vec<bool> = vec![false; clients.len()];
                let mut key = [0u8; KEY_LEN];
                let mut val = vec![0u8; 4096];
                let (mut ops_n, mut gets, mut hits, mut timeouts) = (0u64, 0u64, 0u64, 0u64);
                for _round in 0..rounds {
                    if dead.iter().all(|&d| d) {
                        break;
                    }
                    for i in 0..clients.len() {
                        if dead[i] {
                            continue;
                        }
                        let prep = {
                            let mut p = clients[i].pipeline();
                            for _ in 0..depth {
                                match streams[i].next_op() {
                                    Op::Get(id) => {
                                        p.get(encode_key(&mut key, id));
                                    }
                                    Op::Set(id) => {
                                        let len = spec.value_size.for_key(id);
                                        if val.len() < len {
                                            val.resize(len, 0);
                                        }
                                        fill_value(id, &mut val[..len]);
                                        p.set(encode_key(&mut key, id), &val[..len], 0, 0);
                                    }
                                }
                            }
                            p.prepare()
                        };
                        clients[i].send_prepared(&prep)?;
                        pending[i] = Some(prep);
                    }
                    for i in 0..clients.len() {
                        let Some(prep) = pending[i].take() else {
                            continue; // dead before send this round
                        };
                        match clients[i].recv_prepared(prep) {
                            Ok(replies) => {
                                for reply in replies {
                                    if let PipelineReply::Values(v) = reply {
                                        gets += 1;
                                        if !v.is_empty() {
                                            hits += 1;
                                        }
                                    }
                                }
                                ops_n += depth as u64;
                            }
                            Err(e) if crate::client::is_timeout(&e) => {
                                timeouts += 1;
                                dead[i] = true;
                            }
                            Err(e) => return Err(e),
                        }
                    }
                }
                Ok((ops_n, gets, hits, timeouts))
            }));
        }
        for h in handles {
            match h.join().expect("wire worker panicked") {
                Ok((o, g, hi, t)) => {
                    totals.0 += o;
                    totals.1 += g;
                    totals.2 += hi;
                    totals.3 += t;
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
    });
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(WireReport {
        conns,
        total_ops: totals.0,
        gets: totals.1,
        hits: totals.2,
        timeouts: totals.3,
        elapsed: t0.elapsed(),
    })
}
