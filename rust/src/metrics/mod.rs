//! Cache metrics: sharded counters and a log-bucketed latency histogram.
//!
//! Everything on the request path must be wait-free and contention-light:
//! counters are striped across cache lines ([`ShardedCounter`]) and the
//! histogram uses one relaxed `fetch_add` per sample. Snapshots fold the
//! shards — slightly stale, which is fine for `stats` output and benches.

mod histogram;

pub use histogram::{HistogramSummary, LatencyHistogram};

use std::sync::atomic::{AtomicU64, Ordering};

use crossbeam_utils::CachePadded;

/// Number of stripes; a small power of two keyed by thread id.
const SHARDS: usize = 16;

thread_local! {
    /// Per-thread stripe index, derived once from the thread's address.
    static SHARD: usize = {
        let x = &0u8 as *const u8 as usize;
        // SplitMix-style mix so stack-allocated cookies spread.
        let mut z = x as u64;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        (z as usize >> 8) & (SHARDS - 1)
    };
}

/// A counter striped over [`SHARDS`] cache lines.
#[derive(Default)]
pub struct ShardedCounter {
    shards: [CachePadded<AtomicU64>; SHARDS],
}

impl ShardedCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` on this thread's stripe (relaxed; stats-grade).
    #[inline]
    pub fn add(&self, n: u64) {
        SHARD.with(|&s| {
            self.shards[s].fetch_add(n, Ordering::Relaxed);
        });
    }

    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Fold all stripes.
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }
}

/// All request-path counters an engine maintains.
#[derive(Default)]
pub struct EngineMetrics {
    pub gets: ShardedCounter,
    pub hits: ShardedCounter,
    pub misses: ShardedCounter,
    pub sets: ShardedCounter,
    pub deletes: ShardedCounter,
    pub evictions: ShardedCounter,
    pub expired: ShardedCounter,
    pub expansions: ShardedCounter,
    pub oom_stalls: ShardedCounter,
}

/// Plain snapshot of [`EngineMetrics`] (serialized into `stats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub gets: u64,
    pub hits: u64,
    pub misses: u64,
    pub sets: u64,
    pub deletes: u64,
    pub evictions: u64,
    pub expired: u64,
    pub expansions: u64,
    pub oom_stalls: u64,
}

impl EngineMetrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            gets: self.gets.get(),
            hits: self.hits.get(),
            misses: self.misses.get(),
            sets: self.sets.get(),
            deletes: self.deletes.get(),
            evictions: self.evictions.get(),
            expired: self.expired.get(),
            expansions: self.expansions.get(),
            oom_stalls: self.oom_stalls.get(),
        }
    }
}

impl MetricsSnapshot {
    /// Fold another snapshot into this one (every counter sums) — the
    /// merge step behind sharded-engine `stats`.
    pub fn absorb(&mut self, other: &MetricsSnapshot) {
        self.gets += other.gets;
        self.hits += other.hits;
        self.misses += other.misses;
        self.sets += other.sets;
        self.deletes += other.deletes;
        self.evictions += other.evictions;
        self.expired += other.expired;
        self.expansions += other.expansions;
        self.oom_stalls += other.oom_stalls;
    }

    /// Hit ratio over gets; 0 when no gets happened.
    pub fn hit_ratio(&self) -> f64 {
        if self.gets == 0 {
            0.0
        } else {
            self.hits as f64 / self.gets as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sharded_counter_sums_across_threads() {
        let c = Arc::new(ShardedCounter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn snapshot_and_hit_ratio() {
        let m = EngineMetrics::default();
        for _ in 0..3 {
            m.gets.inc();
        }
        m.hits.add(2);
        m.misses.inc();
        let s = m.snapshot();
        assert_eq!(s.gets, 3);
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(MetricsSnapshot::default().hit_ratio(), 0.0);
    }
}
