//! Cache metrics: sharded counters and a log-bucketed latency histogram.
//!
//! Everything on the request path must be wait-free and contention-light:
//! counters are striped across cache lines ([`ShardedCounter`]) and the
//! histogram uses one relaxed `fetch_add` per sample. Snapshots fold the
//! shards — slightly stale, which is fine for `stats` output and benches.

mod histogram;

pub use histogram::{HistogramSnapshot, HistogramSummary, LatencyHistogram};

use std::sync::atomic::{AtomicU64, Ordering};

use crossbeam_utils::CachePadded;

/// Number of stripes; a small power of two keyed by thread id.
const SHARDS: usize = 16;

thread_local! {
    /// Per-thread stripe index, derived once from the thread's address.
    static SHARD: usize = {
        let x = &0u8 as *const u8 as usize;
        // SplitMix-style mix so stack-allocated cookies spread.
        let mut z = x as u64;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        (z as usize >> 8) & (SHARDS - 1)
    };
}

/// A counter striped over [`SHARDS`] cache lines.
#[derive(Default)]
pub struct ShardedCounter {
    shards: [CachePadded<AtomicU64>; SHARDS],
}

impl ShardedCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` on this thread's stripe (relaxed; stats-grade).
    #[inline]
    pub fn add(&self, n: u64) {
        SHARD.with(|&s| {
            self.shards[s].fetch_add(n, Ordering::Relaxed);
        });
    }

    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Fold all stripes.
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }
}

/// Latency classes the observability plane distinguishes. Coarser than
/// [`crate::cache::Op`] on purpose: four histograms cover the shapes
/// that differ mechanically (lookup, install, read-modify-write,
/// unlink) without a per-variant footprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Get = 0,
    Store = 1,
    Rmw = 2,
    Delete = 3,
}

impl OpClass {
    pub const ALL: [OpClass; 4] = [OpClass::Get, OpClass::Store, OpClass::Rmw, OpClass::Delete];

    /// Stable lowercase name used in `stats latency` / Prometheus keys.
    pub fn name(self) -> &'static str {
        match self {
            OpClass::Get => "get",
            OpClass::Store => "store",
            OpClass::Rmw => "rmw",
            OpClass::Delete => "delete",
        }
    }
}

/// Per-op-class latency histograms plus the batch sampling tick.
///
/// Engines call [`sample_batch`](Self::sample_batch) once per batch: a
/// single relaxed `fetch_add` decides whether this batch reads the
/// clock at all, so at `--latency-sample N` the steady-state cost on
/// the other N−1 batches is one increment and one predictable branch —
/// no `Instant::now()`, no allocation.
#[derive(Default)]
pub struct LatencyMetrics {
    classes: [LatencyHistogram; 4],
    tick: AtomicU64,
}

impl LatencyMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Decide whether this batch is a sampled one. `every == 0` turns
    /// sampling off entirely; otherwise batch 0, N, 2N… are sampled
    /// (the *first* batch always is, so short runs still see data).
    #[inline]
    pub fn sample_batch(&self, every: u32) -> bool {
        if every == 0 {
            return false;
        }
        // ord: relaxed-ok — private sampling tick; counts batches only,
        // orders nothing, and an occasional torn stride is harmless.
        let t = self.tick.fetch_add(1, Ordering::Relaxed);
        t % u64::from(every) == 0
    }

    /// Record one sampled op latency.
    #[inline]
    pub fn record(&self, class: OpClass, nanos: u64) {
        self.classes[class as usize].record(nanos);
    }

    /// The live histogram for one class (bench reporting).
    pub fn class(&self, class: OpClass) -> &LatencyHistogram {
        &self.classes[class as usize]
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        LatencySnapshot {
            get: self.classes[OpClass::Get as usize].snapshot(),
            store: self.classes[OpClass::Store as usize].snapshot(),
            rmw: self.classes[OpClass::Rmw as usize].snapshot(),
            delete: self.classes[OpClass::Delete as usize].snapshot(),
        }
    }
}

/// Plain snapshot of [`LatencyMetrics`] (serialized into `stats
/// latency`, merged across shards like [`MetricsSnapshot`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LatencySnapshot {
    pub get: HistogramSnapshot,
    pub store: HistogramSnapshot,
    pub rmw: HistogramSnapshot,
    pub delete: HistogramSnapshot,
}

impl LatencySnapshot {
    /// Fold another snapshot into this one, class by class.
    pub fn absorb(&mut self, other: &LatencySnapshot) {
        self.get.absorb(&other.get);
        self.store.absorb(&other.store);
        self.rmw.absorb(&other.rmw);
        self.delete.absorb(&other.delete);
    }

    pub fn class(&self, class: OpClass) -> &HistogramSnapshot {
        match class {
            OpClass::Get => &self.get,
            OpClass::Store => &self.store,
            OpClass::Rmw => &self.rmw,
            OpClass::Delete => &self.delete,
        }
    }
}

/// All request-path counters an engine maintains.
#[derive(Default)]
pub struct EngineMetrics {
    pub gets: ShardedCounter,
    pub hits: ShardedCounter,
    pub misses: ShardedCounter,
    pub sets: ShardedCounter,
    pub deletes: ShardedCounter,
    pub evictions: ShardedCounter,
    pub expired: ShardedCounter,
    pub expansions: ShardedCounter,
    pub oom_stalls: ShardedCounter,
}

/// Plain snapshot of [`EngineMetrics`] (serialized into `stats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub gets: u64,
    pub hits: u64,
    pub misses: u64,
    pub sets: u64,
    pub deletes: u64,
    pub evictions: u64,
    pub expired: u64,
    pub expansions: u64,
    pub oom_stalls: u64,
}

impl EngineMetrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            gets: self.gets.get(),
            hits: self.hits.get(),
            misses: self.misses.get(),
            sets: self.sets.get(),
            deletes: self.deletes.get(),
            evictions: self.evictions.get(),
            expired: self.expired.get(),
            expansions: self.expansions.get(),
            oom_stalls: self.oom_stalls.get(),
        }
    }
}

impl MetricsSnapshot {
    /// Fold another snapshot into this one (every counter sums) — the
    /// merge step behind sharded-engine `stats`.
    pub fn absorb(&mut self, other: &MetricsSnapshot) {
        self.gets += other.gets;
        self.hits += other.hits;
        self.misses += other.misses;
        self.sets += other.sets;
        self.deletes += other.deletes;
        self.evictions += other.evictions;
        self.expired += other.expired;
        self.expansions += other.expansions;
        self.oom_stalls += other.oom_stalls;
    }

    /// Hit ratio over gets; 0 when no gets happened.
    pub fn hit_ratio(&self) -> f64 {
        if self.gets == 0 {
            0.0
        } else {
            self.hits as f64 / self.gets as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sharded_counter_sums_across_threads() {
        let c = Arc::new(ShardedCounter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn snapshot_and_hit_ratio() {
        let m = EngineMetrics::default();
        for _ in 0..3 {
            m.gets.inc();
        }
        m.hits.add(2);
        m.misses.inc();
        let s = m.snapshot();
        assert_eq!(s.gets, 3);
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(MetricsSnapshot::default().hit_ratio(), 0.0);
    }
}
