//! Log-bucketed latency histogram (HdrHistogram-style, fixed footprint).
//!
//! Values (nanoseconds) are bucketed by octave with 8 sub-buckets per
//! octave — ≤ 12.5 % relative error, 512 buckets ≈ 4 KiB, one relaxed
//! `fetch_add` per record. Percentile queries interpolate inside the
//! winning bucket: the returned value is the bucket's lower bound plus
//! the target rank's linear fraction of the bucket width (rank-based
//! linear interpolation), clamped to the observed maximum — so the
//! relative error stays within the bucket resolution (≤ 12.5 %) instead
//! of snapping to midpoints.
//!
//! [`HistogramSnapshot`] is the plain (non-atomic) image used by
//! `stats` replies and sharded merging: `snapshot()` freezes a live
//! histogram, `absorb()` folds snapshots bucket-wise the same way
//! `MetricsSnapshot` folds counters.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-buckets per power of two.
const SUB_BITS: u32 = 3;
const SUB: usize = 1 << SUB_BITS;
/// Octaves covered: 2^0 .. 2^63 ns (584 years; plenty).
const OCTAVES: usize = 64;
const BUCKETS: usize = OCTAVES * SUB;

/// Inclusive lower / exclusive upper value bounds of bucket `i`.
///
/// For octaves below `SUB_BITS` each representable value gets its own
/// bucket (the sub index *is* the value), so the bounds are exact.
fn bucket_bounds(i: usize) -> (u64, u64) {
    let exp = (i / SUB) as u32;
    let sub = (i % SUB) as u64;
    if exp >= SUB_BITS {
        let base = 1u64 << exp;
        let step = 1u64 << (exp - SUB_BITS);
        let lo = base + sub * step;
        (lo, lo + step)
    } else {
        let v = sub.max(1);
        (v, v + 1)
    }
}

/// Rank-based linear interpolation over a bucket array: find the bucket
/// holding the `p`-quantile's rank, then interpolate the rank's fraction
/// through that bucket's value bounds. Shared by the live histogram and
/// the snapshot so both answer identically.
fn rank_percentile(mut load: impl FnMut(usize) -> u64, n: u64, max: u64, p: f64) -> u64 {
    if n == 0 {
        return 0;
    }
    let target = ((p.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
    let mut seen = 0u64;
    for i in 0..BUCKETS {
        let c = load(i);
        if c == 0 {
            continue;
        }
        if seen + c >= target {
            let (lo, hi) = bucket_bounds(i);
            let frac = (target - seen) as f64 / c as f64;
            let v = lo as f64 + frac * (hi - lo) as f64;
            return (v as u64).min(max);
        }
        seen += c;
    }
    // Racy under-count (concurrent recorders): the max is the best
    // stats-grade answer.
    max
}

/// Concurrent fixed-size latency histogram.
pub struct LatencyHistogram {
    buckets: Box<[AtomicU64; BUCKETS]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // Box<[AtomicU64; N]> without a large stack temporary.
        let v: Vec<AtomicU64> = (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        let boxed: Box<[AtomicU64; BUCKETS]> = v.into_boxed_slice().try_into().map_err(|_| ()).unwrap();
        LatencyHistogram {
            buckets: boxed,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    fn index(nanos: u64) -> usize {
        let v = nanos.max(1);
        let exp = 63 - v.leading_zeros(); // floor(log2 v)
        let sub = if exp >= SUB_BITS {
            ((v >> (exp - SUB_BITS)) as usize) & (SUB - 1)
        } else {
            // Tiny values: place by low bits.
            (v as usize) & (SUB - 1)
        };
        (exp as usize) * SUB + sub
    }

    /// Record one sample (nanoseconds).
    #[inline]
    pub fn record(&self, nanos: u64) {
        self.buckets[Self::index(nanos)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(nanos, Ordering::Relaxed);
        self.max.fetch_max(nanos, Ordering::Relaxed);
    }

    /// Record a `Duration`.
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    /// Total samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean sample in nanoseconds (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Largest recorded sample.
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate `p`-quantile (0 < p ≤ 1) in nanoseconds, linearly
    /// interpolated within the winning bucket.
    pub fn percentile(&self, p: f64) -> u64 {
        rank_percentile(
            |i| self.buckets[i].load(Ordering::Relaxed),
            self.count(),
            self.max(),
            p,
        )
    }

    /// Fold another live histogram into this one (relaxed adds; the
    /// sharded-merge primitive for long-lived aggregation — `stats`
    /// replies merge [`HistogramSnapshot`]s instead).
    pub fn absorb(&self, other: &LatencyHistogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            let c = src.load(Ordering::Relaxed);
            if c != 0 {
                dst.fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Freeze a plain, mergeable image of the current state. Slightly
    /// torn under concurrent recording; stats-grade by design.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count(),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max(),
        }
    }

    /// Reset all state (between bench phases).
    pub fn reset(&self) {
        for b in self.buckets.iter() {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    /// Standard percentile summary (p50, p90, p95, p99, p999, max) in ns.
    pub fn summary(&self) -> HistogramSummary {
        self.snapshot().summary()
    }
}

/// Plain, mergeable image of a [`LatencyHistogram`] — the form `stats`
/// snapshots carry and sharded routers fold. `Default` is the empty
/// histogram (bucket storage allocates lazily on the first `absorb`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket counts; empty means "no buckets yet" (all zero).
    buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistogramSnapshot {
    /// Approximate `p`-quantile, same interpolation as the live
    /// histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        rank_percentile(
            |i| self.buckets.get(i).copied().unwrap_or(0),
            self.count,
            self.max,
            p,
        )
    }

    /// Mean sample in nanoseconds (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Fold another snapshot into this one (bucket-wise sum; the merge
    /// step behind sharded `stats latency`).
    pub fn absorb(&mut self, other: &HistogramSnapshot) {
        if !other.buckets.is_empty() {
            if self.buckets.is_empty() {
                self.buckets = vec![0; BUCKETS];
            }
            for (dst, &src) in self.buckets.iter_mut().zip(other.buckets.iter()) {
                *dst += src;
            }
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Standard percentile summary in ns.
    pub fn summary(&self) -> HistogramSummary {
        HistogramSummary {
            count: self.count,
            mean_ns: self.mean(),
            p50_ns: self.percentile(0.50),
            p90_ns: self.percentile(0.90),
            p95_ns: self.percentile(0.95),
            p99_ns: self.percentile(0.99),
            p999_ns: self.percentile(0.999),
            max_ns: self.max,
        }
    }
}

/// Plain summary emitted by benches and `stats`.
#[derive(Debug, Clone, Copy, Default)]
pub struct HistogramSummary {
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p90_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
    pub max_ns: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_zeroed() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn percentiles_of_uniform_ramp_are_close() {
        let h = LatencyHistogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 100); // 100ns .. 1ms
        }
        let p50 = h.percentile(0.5) as f64;
        assert!(
            (p50 / 500_000.0 - 1.0).abs() < 0.15,
            "p50 {p50} not within 15% of 500µs"
        );
        let p99 = h.percentile(0.99) as f64;
        assert!(
            (p99 / 990_000.0 - 1.0).abs() < 0.15,
            "p99 {p99} not within 15% of 990µs"
        );
        assert_eq!(h.max(), 1_000_000);
        assert!((h.mean() / 500_050.0 - 1.0).abs() < 0.01);
    }

    #[test]
    fn single_sample_dominates_all_percentiles() {
        let h = LatencyHistogram::new();
        h.record(12_345);
        for p in [0.01, 0.5, 0.99, 1.0] {
            let got = h.percentile(p) as f64;
            assert!(
                (got / 12_345.0 - 1.0).abs() < 0.13,
                "p{p} = {got} too far from the only sample"
            );
        }
    }

    #[test]
    fn interpolated_percentiles_track_a_log_uniform_sweep() {
        // Samples spread log-uniformly over 2^7..2^20 ns (uniform within
        // each octave → uniform across octaves on the log axis), with
        // deliberately non-power-of-two values; the interpolated
        // percentile must stay within the bucket resolution (≤ 12.5 %
        // relative error) of the exact order statistic.
        let h = LatencyHistogram::new();
        let mut all: Vec<u64> = Vec::new();
        for exp in 7u32..20 {
            let base = 1u64 << exp;
            for k in 0..200u64 {
                let v = base + (k * base) / 200 + 3; // off-grid offsets
                h.record(v);
                all.push(v);
            }
        }
        all.sort_unstable();
        let n = all.len() as f64;
        for p in [0.10, 0.25, 0.50, 0.75, 0.90, 0.95, 0.99, 0.999] {
            let exact = all[((p * n).ceil() as usize).max(1) - 1] as f64;
            let got = h.percentile(p) as f64;
            assert!(
                (got / exact - 1.0).abs() <= 0.125,
                "p{p}: interpolated {got} vs exact {exact} exceeds 12.5% relative error"
            );
        }
    }

    #[test]
    fn snapshot_merging_matches_combined_recording() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        let combined = LatencyHistogram::new();
        for v in (100..5_000u64).step_by(7) {
            a.record(v);
            combined.record(v);
        }
        for v in (3_000..50_000u64).step_by(13) {
            b.record(v);
            combined.record(v);
        }
        // Snapshot-level merge (the stats path)…
        let mut merged = HistogramSnapshot::default();
        merged.absorb(&a.snapshot());
        merged.absorb(&b.snapshot());
        assert_eq!(merged.count, combined.count());
        assert_eq!(merged.max, combined.max());
        for p in [0.5, 0.9, 0.99] {
            assert_eq!(merged.percentile(p), combined.percentile(p));
        }
        // …and the live-histogram merge agree with one another.
        a.absorb(&b);
        assert_eq!(a.count(), combined.count());
        assert_eq!(a.percentile(0.5), combined.percentile(0.5));
    }

    #[test]
    fn reset_clears_everything() {
        let h = LatencyHistogram::new();
        h.record(500);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.percentile(0.5), 0);
    }

    #[test]
    fn concurrent_recording_counts_all_samples() {
        use std::sync::Arc;
        let h = Arc::new(LatencyHistogram::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..25_000u64 {
                        h.record(100 + (t * 25_000 + i) % 1000);
                    }
                })
            })
            .collect();
        for hdl in handles {
            hdl.join().unwrap();
        }
        assert_eq!(h.count(), 100_000);
    }

    #[test]
    fn index_is_monotonic_in_value() {
        let mut last = 0;
        for shift in 0..40 {
            let v = 1u64 << shift;
            let idx = LatencyHistogram::index(v);
            assert!(idx >= last, "index must not decrease");
            last = idx;
        }
    }
}
