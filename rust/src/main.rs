//! `fleec` binary: serve / bench / hit-ratio / planner-demo.
//! See [`fleec::cli`] for the full option reference.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match fleec::cli::run(argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
