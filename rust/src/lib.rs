//! # FLeeC — a Fast Lock-Free Application Cache
//!
//! Full reproduction of *"FLeeC: a Fast Lock-Free Application Cache"*
//! (Costa, Preguiça, Lourenço — CS.DC 2024): a Memcached-compatible
//! application cache whose main data structures are lock-free.
//!
//! The paper replaces Memcached's three blocking structures (locked hash
//! table, strict-LRU doubly-linked list, slab allocator) with a single
//! lock-free hash table that *embeds* a CLOCK-based eviction policy:
//!
//! * buckets are Harris lock-free linked lists ([`lockfree`]),
//! * every bucket carries a multi-bit CLOCK value swept by a lock-free
//!   clock hand ([`cache::fleec`]),
//! * memory is reclaimed with a DEBRA-derived epoch scheme that only
//!   advances under memory pressure ([`ebr`]),
//! * the hash table expands without stopping the world (forwarding
//!   marks + cooperative helping).
//!
//! Three engines implement the common [`cache::Cache`] trait so the
//! paper's comparison is reproducible in-process:
//!
//! | engine | hash table | eviction | expansion |
//! |---|---|---|---|
//! | [`cache::memcached`] | striped locks | strict LRU (one lock) | stop-the-world |
//! | [`cache::memclock`]  | striped locks | per-bucket CLOCK | stop-the-world |
//! | [`cache::fleec`]     | lock-free (Harris) | embedded lock-free CLOCK | non-blocking |
//!
//! The serving plane ([`proto`], [`server`], [`client`]) makes FLeeC a
//! plug-in Memcached replacement; [`workload`] and the `benches/`
//! directory regenerate every figure in the paper's evaluation; the
//! [`runtime`] + [`coordinator`] pair loads AOT-compiled JAX/Pallas
//! maintenance kernels (eviction planner, analytic hit-ratio model) via
//! PJRT and runs them off the request path.

pub mod cache;
pub mod cli;
pub mod client;
pub mod coordinator;
pub mod ebr;
pub mod lockfree;
pub mod metrics;
pub mod proto;
pub mod runtime;
pub mod server;
pub mod slab;
pub mod sync;
pub mod testutil;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
