//! # FLeeC — a Fast Lock-Free Application Cache
//!
//! Full reproduction of *"FLeeC: a Fast Lock-Free Application Cache"*
//! (Costa, Preguiça, Lourenço — CS.DC 2024): a Memcached-compatible
//! application cache whose main data structures are lock-free.
//!
//! The paper replaces Memcached's three blocking structures (locked hash
//! table, strict-LRU doubly-linked list, slab allocator) with a single
//! lock-free hash table that *embeds* a CLOCK-based eviction policy:
//!
//! * buckets are Harris lock-free linked lists ([`lockfree`]),
//! * every bucket carries a multi-bit CLOCK value swept by a lock-free
//!   clock hand ([`cache::fleec`]),
//! * memory is reclaimed with a DEBRA-derived epoch scheme that only
//!   advances under memory pressure ([`ebr`]),
//! * the hash table expands without stopping the world (forwarding
//!   marks + cooperative helping).
//!
//! Four engines implement the common [`cache::Cache`] trait so the
//! paper's comparison is reproducible in-process:
//!
//! | engine | hash table | eviction | expansion |
//! |---|---|---|---|
//! | [`cache::memcached`] | striped locks | strict LRU (one lock) | stop-the-world |
//! | [`cache::memclock`]  | striped locks | per-bucket CLOCK | stop-the-world |
//! | [`cache::fleec`]     | lock-free (Harris) | embedded lock-free CLOCK | non-blocking |
//! | [`cache::oaflash`]   | lock-free open addressing | per-slot lock-free CLOCK | non-blocking |
//!
//! ## The two-tier cache API: sink-first
//!
//! [`cache::Cache`] exposes two tiers. The single-key methods
//! (`get`/`set`/…) are the convenience tier. The primary tier is the
//! batched, **sink-scoped** core: [`cache::Op`] is a typed, owner-less
//! command (keys/values are borrowed slices) and
//! [`cache::Cache::execute_batch_into`] runs a whole slice of them in
//! one engine crossing, streaming one result per op into a
//! caller-supplied [`cache::BatchSink`]. A GET hit is delivered as
//! `sink.value(idx, key, flags, cas, bytes)` with `bytes` **borrowed
//! from the engine** — the read path's zero-copy seam.
//! [`cache::Cache::execute_batch`] remains as the owned convenience
//! wrapper (a collecting sink returning index-aligned
//! [`cache::OpResult`]s).
//!
//! The guard-lifetime contract a [`cache::BatchSink`] implementor must
//! respect: the lent `bytes` are valid only during the `value` call
//! (copy to retain), delivery order is unspecified (routers deliver
//! shard-grouped; indices are always correct), and a sink must never
//! call back into the cache — the engine may be holding locks or an EBR
//! guard across the call. What the engine promises in return: FLeeC
//! lends the item's slab bytes *while its batch guard is pinned*, and
//! since overwrites/evictions/deletes only retire items through epoch
//! reclamation, the slice stays byte-stable until `execute_batch_into`
//! returns no matter what concurrent writers do
//! (`rust/tests/read_path.rs` stress-tests exactly this); the blocking
//! engines lend entry bytes under the held stripe lock.
//!
//! FLeeC's batched fast path: **one EBR guard pinned per batch** (plus
//! one short pre-read guard when the batch carries RMW ops), keys
//! pre-hashed and bucket heads prefetched up front, storage items
//! pre-allocated outside the guard, and `append`/`prepend`/`incr`/
//! `decr`/`touch` **staged like plain stores**: values pre-read, the
//! replacement items allocated unpinned, then installed token-guarded at
//! their turn (same-key in-batch dependencies rerun the classic loop in
//! place), so nothing allocates under the held guard and metrics fold
//! into one update per counter. A batch is always semantically identical
//! to running its ops sequentially (results, state, `cas`-token
//! sequence) — enforced by `rust/tests/batch_semantics.rs`.
//!
//! ## The write-side memory path
//!
//! The [`slab`] allocator behind every FLeeC item is privatized: each
//! thread keeps per-size-class **magazines** of up to `slab::MAG_CAP`
//! free chunks, so steady-state alloc/free touch only thread-local state;
//! refills and flushes exchange whole **segments** (intra-linked chunk
//! chains) with the shared lock-free free list, one tagged CAS per
//! ~`MAG_CAP` chunks. Accounting stays exact with chunks parked
//! privately (magazine residents count as free in
//! `utilization`/`mem_used`, thread exit flushes, `exhausted()` publishes
//! the caller's parked chunks before reporting pressure).
//!
//! ## The shard router
//!
//! Above the engines sits [`cache::sharded::Sharded`]: N independent
//! engine instances behind one `Cache` face, routed by the high bits of
//! the shared key hash (the engines consume the low bits for buckets and
//! lock stripes). A batch splits into per-shard **sub-batches** and the
//! results re-interleave into original order, so the batching win
//! compounds with the contention win (batch → shard → sub-batch); the
//! merged [`cache::Cache::stats`] view sums counters and memory across
//! shards, keeping `limit_maxbytes` truthful. Everything downstream —
//! server, driver, benches — is already generic over `Cache`, so
//! sharding is one `--shards N` flag. `rust/tests/shard_semantics.rs`
//! pins router equivalence; `rust/tests/concurrent_stress.rs` holds the
//! composition to per-key linearizability-style checks. The router seam
//! is also where the future async front-end will sit: one event loop per
//! shard group, feeding sub-batches.
//!
//! ## The serving plane: reactor front-end
//!
//! The serving plane ([`proto`], [`server`], [`client`]) makes FLeeC a
//! plug-in Memcached replacement, built around that batched core: the
//! protocol pump (`server::batch::drain`) turns every complete command in
//! a connection's read buffer into rounds of one `execute_batch_into`
//! crossing each (`stats`/`flush_all` act as barriers), and the sink it
//! passes **is the reply emitter** — results stream out of the engine
//! straight into the connection outbuf, so a GET hit's bytes go
//! slab→outbuf in one `memcpy` with zero per-hit allocation
//! (`rust/tests/read_path_alloc.rs` proves it with a counting
//! allocator; out-of-order shard-router deliveries park in recycled
//! buffers until their wire turn). Per-connection op/action arenas plus
//! the multi-key `get` scratch fed to `proto::parse_into` make the rest
//! of the path allocation-free once a connection is warm.
//! Two front-ends run that pump ([`server::ServerModel`]):
//!
//! * **`reactor`** (default on Unix): N event-loop threads, each owning
//!   an OS readiness poller (`epoll`/`poll` via a direct `extern "C"`
//!   shim — the offline crate set has no async runtime) and a set of
//!   non-blocking connections with per-connection state machines —
//!   partial writes re-arm WRITE interest, and a connection whose peer
//!   stops reading is capped at `max_outbuf` buffered reply bytes (it
//!   stops reading/executing until the peer drains, so a slow reader
//!   can neither stall other connections nor grow server memory). This
//!   is what lets the front-end hold thousands of sockets against the
//!   lock-free core's "any number of concurrent readers and writers".
//! * **`thread`**: one blocking native thread per connection — the
//!   portable fallback and the differential-testing oracle
//!   (`rust/tests/reactor_e2e.rs` holds the two byte-identical).
//!
//! [`client::Client::pipeline`] ships N commands in one write and decodes
//! N replies (split-phase variants multiplex many connections from one
//! load-generator thread). `benches/batch_pipeline.rs` sweeps batch depth
//! 1/4/16/64, shard count 1/2/4/8 and connection count 1/64/512 × both
//! front-end models, emitting `BENCH_batch_pipeline.json`. [`workload`]
//! and the rest of `benches/` regenerate every figure in the paper's
//! evaluation; the [`runtime`] + [`coordinator`] pair loads AOT-compiled
//! JAX/Pallas maintenance kernels (eviction planner, analytic hit-ratio
//! model) via PJRT (behind the `pjrt` feature) and runs them off the
//! request path.
//!
//! ## Concurrency discipline
//!
//! The unsafe core is held to a written, machine-checked discipline:
//! every `unsafe` carries a `SAFETY:` argument, every
//! `Release`/`AcqRel`/`SeqCst` site an `// ord:` tag naming its
//! `Acquire` counterpart, and every `Relaxed` in a lock-free path an
//! `ord: relaxed-ok <reason>` tag — enforced by the in-repo analyzer
//! ([`audit`]; `cargo run --bin fleec-audit -- rust/src`, gated by
//! `tests/audit.rs` and the required CI job). The cross-cutting
//! memory-ordering map — which atomics pair with which, and why each
//! `Relaxed` is safe — is `rust/docs/concurrency.md`.
//!
//! ## Observability
//!
//! The cache watches itself without locks or new shared-write
//! contention: sampled per-op-class latency histograms ([`metrics`]),
//! EBR/slab/probe internals ([`cache::InternalsSnapshot`]), serving-
//! plane gauges (`server::ServerObs`), the `stats
//! latency`/`slabs`/`internals` protocol subcommands, and an optional
//! Prometheus text endpoint (`--metrics-addr`). The design rules and
//! the full metric inventory are in `rust/docs/observability.md`.
//!
//! ## Robustness
//!
//! The serving plane degrades instead of dying: a panicking connection
//! state machine is caught per-connection (`catch_unwind`) and closes
//! only that connection; a reactor thread that dies is respawned by a
//! supervisor that re-homes its registered fds; `--max-conns` sheds new
//! accepts with `SERVER_ERROR busy` before fd exhaustion; dead peers are
//! reaped by `--conn-idle-timeout`; and `Server::drain` (the SIGTERM
//! path of `fleec serve`) stops accepting, flushes buffered replies and
//! shuts down within a deadline. All of it is exercised deterministically
//! by the [`faults`] failpoint harness (`faults` cargo feature,
//! `FLEEC_FAULTS=site:kind:rate:seed`) and `rust/tests/chaos_e2e.rs`.
//! The failure→behavior matrix, failpoint inventory and drain semantics
//! are in `rust/docs/robustness.md`.
//!
//! ## Multi-tenancy
//!
//! One process can serve many logical caches: `fleec serve --tenants`
//! gives each connection a `tenant <name>` namespace with isolated keys
//! and cas tokens, per-tenant slab accounting (one attribution byte in
//! the item header, unwound at dealloc), soft budgets enforced by
//! eviction steering (an over-budget tenant evicts from itself, a
//! tenant at its floor sees per-tenant OOM), and a Memshare-style
//! arbiter on the maintenance tick that moves page budget toward
//! shadow-hit pain ([`cache::tenant`], [`slab::tenant`]). The default
//! tenant's prefix is empty, so a client mix that never switches is
//! byte-exact with a tenant-less server. The design — namespacing,
//! accounting, arbitration, and the `stats tenants`/Prometheus surface
//! — is `rust/docs/multitenancy.md`.

pub mod audit;
pub mod cache;
pub mod cli;
pub mod client;
pub mod coordinator;
pub mod ebr;
pub mod faults;
pub mod lockfree;
pub mod metrics;
pub mod proto;
pub mod runtime;
pub mod server;
pub mod slab;
pub mod sync;
pub mod testutil;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
