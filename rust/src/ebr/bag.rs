//! Limbo bags: per-thread vectors of retired allocations stamped with the
//! epoch they were retired in.

/// A retired allocation plus the function that reclaims it.
///
/// `reclaim(ptr, ctx)` gives retirers one word of context — the slab uses
/// it to smuggle a `*const Slab` so retired items can be returned to their
/// size class without a global registry.
pub struct Retired {
    ptr: *mut u8,
    ctx: usize,
    bytes: usize,
    // SAFETY: the fn pointer is only invoked through [`Retired::reclaim`],
    // whose caller guarantees the grace period elapsed.
    reclaim_fn: unsafe fn(*mut u8, usize),
}

// SAFETY: Retired items are only handled by their owner thread or, after
// orphaning, under the collector's orphan mutex.
unsafe impl Send for Retired {}

impl Retired {
    /// Package a retirement. See [`crate::ebr::Guard::defer`] for the contract.
    // SAFETY: constructing is safe — `reclaim_fn` is not called here; its
    // `unsafe` contract is discharged by [`Retired::reclaim`]'s caller.
    pub fn new(ptr: *mut u8, ctx: usize, bytes: usize, reclaim_fn: unsafe fn(*mut u8, usize)) -> Self {
        Retired {
            ptr,
            ctx,
            bytes,
            reclaim_fn,
        }
    }

    /// Accounting hint supplied at retirement.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Run the reclaimer.
    ///
    /// # Safety
    /// The grace period must have elapsed: no thread may still hold a
    /// guard pinned at an epoch that could observe `ptr`.
    pub unsafe fn reclaim(self) {
        (self.reclaim_fn)(self.ptr, self.ctx);
    }
}

/// Items retired during one epoch by one thread.
pub struct Bag {
    pub epoch: u64,
    items: Vec<Retired>,
}

impl Bag {
    pub fn new(epoch: u64) -> Self {
        Bag {
            epoch,
            items: Vec::new(),
        }
    }

    pub fn push(&mut self, item: Retired) {
        self.items.push(item);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Reclaim everything in the bag; returns (count, bytes).
    pub fn drain(&mut self) -> (usize, usize) {
        let n = self.items.len();
        let mut bytes = 0;
        for item in self.items.drain(..) {
            bytes += item.bytes();
            // SAFETY: the collector only drains bags whose epoch is two
            // advances behind the global epoch, so the grace period for
            // every item in the bag has elapsed.
            unsafe { item.reclaim() };
        }
        (n, bytes)
    }

    /// Hand all items out without reclaiming (thread-exit orphaning).
    pub fn take_all(&mut self) -> Vec<Retired> {
        std::mem::take(&mut self.items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static FREED: AtomicUsize = AtomicUsize::new(0);

    unsafe fn fake_reclaim(_p: *mut u8, ctx: usize) {
        FREED.fetch_add(ctx, Ordering::SeqCst);
    }

    #[test]
    fn drain_runs_reclaimers_and_counts_bytes() {
        FREED.store(0, Ordering::SeqCst);
        let mut bag = Bag::new(7);
        bag.push(Retired::new(std::ptr::null_mut(), 2, 100, fake_reclaim));
        bag.push(Retired::new(std::ptr::null_mut(), 3, 50, fake_reclaim));
        assert_eq!(bag.len(), 2);
        let (n, bytes) = bag.drain();
        assert_eq!((n, bytes), (2, 150));
        assert_eq!(FREED.load(Ordering::SeqCst), 5);
        assert!(bag.is_empty());
    }

    #[test]
    fn take_all_moves_without_reclaiming() {
        FREED.store(0, Ordering::SeqCst);
        let mut bag = Bag::new(1);
        bag.push(Retired::new(std::ptr::null_mut(), 1, 10, fake_reclaim));
        let items = bag.take_all();
        assert_eq!(items.len(), 1);
        assert_eq!(FREED.load(Ordering::SeqCst), 0);
        for i in items {
            unsafe { i.reclaim() };
        }
        assert_eq!(FREED.load(Ordering::SeqCst), 1);
    }
}
