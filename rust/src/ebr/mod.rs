//! Epoch-based memory reclamation — FLeeC's DEBRA variant.
//!
//! The paper bases reclamation on DEBRA (Brown, PODC '15) with one
//! deliberate deviation: DEBRA amortizes epoch advancement over every
//! operation so memory is reclaimed continuously, but *a cache knows when
//! it is out of memory*, so FLeeC "only progress[es] the memory
//! reclamation scheme when it is absolutely necessary". Concretely, this
//! implementation:
//!
//! * announces (epoch, active) per thread on [`Collector::pin`] — the
//!   standard 3-epoch EBR protocol, wait-free for readers;
//! * on [`Guard::defer`]/retire, items land in the thread's limbo bag for
//!   the announced epoch; **no advancement is attempted** until either the
//!   thread's bag population crosses [`Config::retire_threshold`] or the
//!   slab raises the pressure flag ([`Collector::request_reclaim`]);
//! * [`Collector::force_reclaim`] lets the eviction path flush up to two
//!   whole epochs synchronously before it starts evicting live items —
//!   freeing memory that is merely *awaiting* a grace period is always
//!   preferable to evicting.
//!
//! Threads register into a fixed slot array (no allocation on the pin
//! path); exiting threads hand their unreclaimed bags to an orphan list
//! that any later collection drains.

mod bag;

pub use bag::Retired;

use std::cell::{Cell, RefCell, UnsafeCell};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crossbeam_utils::CachePadded;

use bag::Bag;

/// Maximum simultaneously-registered threads. Registration is one CAS per
/// thread lifetime; 128 is far above anything the benches spawn.
pub const MAX_THREADS: usize = 128;

/// Tuning knobs for the collector.
#[derive(Debug, Clone)]
pub struct Config {
    /// Retired items a single thread accumulates before it tries to
    /// advance the epoch. High on purpose: the paper's variant avoids
    /// background reclamation work until memory actually matters.
    pub retire_threshold: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            retire_threshold: 512,
        }
    }
}

/// Per-thread announcement slot. `state` packs `(epoch << 1) | active`.
struct Slot {
    state: AtomicU64,
    owned: AtomicBool,
}

/// An orphaned retired item: the epoch at which its owner thread exited,
/// plus the item itself. Safe to reclaim once `global >= epoch + 2`.
struct Orphan {
    epoch: u64,
    item: Retired,
}

/// The shared collector: global epoch + thread slots + orphan list.
///
/// One collector per cache engine; engines share `Arc<Collector>` with the
/// coordinator so pressure signals reach every participating thread.
pub struct Collector {
    global_epoch: CachePadded<AtomicU64>,
    slots: Box<[CachePadded<Slot>]>,
    /// Set by the slab on allocation failure; cleared after a successful
    /// advance. Makes the *next* retire/pin on every thread attempt
    /// reclamation regardless of thresholds.
    pressure: AtomicBool,
    /// Cold path only (thread exit / drain): not on any request path.
    orphans: Mutex<Vec<Orphan>>,
    /// Stats: total items/bytes currently awaiting a grace period.
    pending_items: AtomicUsize,
    pending_bytes: AtomicUsize,
    /// Stats: total items reclaimed over the collector's lifetime.
    reclaimed_items: AtomicUsize,
    advance_attempts: AtomicUsize,
    advances: AtomicUsize,
    /// Debug-build test hook: top-level pin events (re-entrant pins are
    /// free and not counted). Lets the batch tests assert that
    /// `execute_batch` pins exactly one guard per batch. Compiled out of
    /// release builds — no hot-path cost where it matters.
    #[cfg(debug_assertions)]
    top_pins: AtomicU64,
    /// Handle to the owning `Arc`, set at construction. Per-thread
    /// registrations clone it so a thread's limbo bags keep the collector
    /// alive, which is why the constructors return `Arc<Collector>`
    /// directly (`&Arc<Self>` is not a valid method receiver on stable
    /// Rust, so `pin` takes `&self` and upgrades this instead).
    self_weak: Weak<Collector>,
    config: Config,
}

// SAFETY: all shared state is atomics or mutex-protected.
unsafe impl Send for Collector {}
// SAFETY: same argument as Send — atomics, a Mutex, and an immutable
// config; the Weak self-handle is only upgraded, never mutated.
unsafe impl Sync for Collector {}

impl Collector {
    /// Collector with default tuning (the `Arc` is part of the API — see
    /// [`Collector::new`]).
    pub fn default() -> Arc<Self> {
        Self::new(Config::default())
    }

    /// Create a collector with the given tuning.
    pub fn new(config: Config) -> Arc<Self> {
        let slots = (0..MAX_THREADS)
            .map(|_| {
                CachePadded::new(Slot {
                    state: AtomicU64::new(0),
                    owned: AtomicBool::new(false),
                })
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Arc::new_cyclic(|self_weak| Collector {
            global_epoch: CachePadded::new(AtomicU64::new(2)), // start >1 so epoch-2 math never underflows
            slots,
            pressure: AtomicBool::new(false),
            orphans: Mutex::new(Vec::new()),
            pending_items: AtomicUsize::new(0),
            pending_bytes: AtomicUsize::new(0),
            reclaimed_items: AtomicUsize::new(0),
            advance_attempts: AtomicUsize::new(0),
            advances: AtomicUsize::new(0),
            #[cfg(debug_assertions)]
            top_pins: AtomicU64::new(0),
            self_weak: self_weak.clone(),
            config,
        })
    }

    /// Top-level pins since creation (debug builds; always 0 in release).
    /// A guard taken while another guard from the same collector is live
    /// on the same thread is re-entrant and does **not** count.
    pub fn top_level_pins(&self) -> u64 {
        #[cfg(debug_assertions)]
        {
            // ord: relaxed-ok — debug-only test counter; asserted after
            // joins.
            self.top_pins.load(Ordering::Relaxed)
        }
        #[cfg(not(debug_assertions))]
        {
            0
        }
    }

    /// Current global epoch (stats / tests).
    pub fn epoch(&self) -> u64 {
        self.global_epoch.load(Ordering::Acquire)
    }

    /// Items retired but not yet reclaimed.
    pub fn pending_items(&self) -> usize {
        // ord: relaxed-ok — stats snapshot; racy by design.
        self.pending_items.load(Ordering::Relaxed)
    }

    /// Bytes retired but not yet reclaimed (as reported by retirers).
    pub fn pending_bytes(&self) -> usize {
        // ord: relaxed-ok — stats snapshot; racy by design.
        self.pending_bytes.load(Ordering::Relaxed)
    }

    /// Items reclaimed since creation.
    pub fn reclaimed_items(&self) -> usize {
        // ord: relaxed-ok — stats snapshot; racy by design.
        self.reclaimed_items.load(Ordering::Relaxed)
    }

    /// (attempts, successes) of epoch advancement — the paper's variant
    /// should show far fewer attempts than ops.
    pub fn advance_stats(&self) -> (usize, usize) {
        (
            // ord: relaxed-ok — stats snapshot; racy by design.
            self.advance_attempts.load(Ordering::Relaxed),
            // ord: relaxed-ok — stats snapshot; racy by design.
            self.advances.load(Ordering::Relaxed),
        )
    }

    /// Raise the memory-pressure flag: the next pin/retire on every thread
    /// will attempt epoch advancement and collection. Called by the slab
    /// when an allocation fails.
    pub fn request_reclaim(&self) {
        // ord: Release orders the failed-allocation state before the flag;
        // Acquire counterpart: pressure_requested (the in-line pressure
        // checks in pin/defer_retired are deliberately Relaxed hints).
        self.pressure.store(true, Ordering::Release);
    }

    /// Whether pressure is currently requested (tests / coordinator).
    pub fn pressure_requested(&self) -> bool {
        self.pressure.load(Ordering::Acquire)
    }

    /// Pin the current thread: returns a guard inside which loads from the
    /// protected structures are safe. Re-entrant; inner pins are free.
    pub fn pin(&self) -> Guard {
        let local = local_handle(self);
        if local.pin_depth.get() == 0 {
            #[cfg(debug_assertions)]
            // ord: relaxed-ok — debug-only test counter.
            self.top_pins.fetch_add(1, Ordering::Relaxed);
            // Standard announce loop: publish (epoch, active), re-check.
            // Relaxed store + one SeqCst fence (crossbeam's pattern) is
            // one full barrier instead of the two an xchg+mfence pair
            // would cost; the fence orders the announce before the
            // re-check load, which is all the Dekker-style handshake
            // with try_advance needs.
            let slot = &self.slots[local.slot_idx].state;
            // ord: relaxed-ok — seed value only; the loop re-reads with
            // Acquire after the fence before trusting it.
            let mut e = self.global_epoch.load(Ordering::Relaxed);
            loop {
                // ord: relaxed-ok — the SeqCst fence below orders this
                // announce before the re-check load (and before any
                // protected loads); a Release store would not order the
                // *subsequent* loads, the fence does.
                slot.store((e << 1) | 1, Ordering::Relaxed);
                // ord: SeqCst fence — Dekker handshake with the fence in
                // try_advance_and_collect: either the scanner sees our
                // announce, or we see the new epoch and re-announce.
                std::sync::atomic::fence(Ordering::SeqCst);
                let e2 = self.global_epoch.load(Ordering::Acquire);
                if e == e2 {
                    break;
                }
                e = e2;
            }
            // Epoch changed since our last pin: bags two epochs behind are
            // now safe — drain them (cheap when empty).
            if local.observed_epoch.get() != e {
                local.observed_epoch.set(e);
                self.drain_expired(&local, e);
            }
            // Under pressure, try to make progress right away.
            // ord: relaxed-ok — hint only; missing the flag by one pin is
            // harmless and try_advance does its own synchronization.
            if self.pressure.load(Ordering::Relaxed) {
                self.try_advance_and_collect(&local);
            }
        }
        local.pin_depth.set(local.pin_depth.get() + 1);
        Guard { local }
    }

    /// Synchronously advance up to `rounds` epochs, collecting after each.
    /// Used by eviction before touching live items, and by drop/tests.
    ///
    /// Callable while pinned (the batched execution path allocates under
    /// a held guard): our own announced epoch then blocks the second
    /// advance, so the rounds are clamped to 1 — progress is reduced, not
    /// unsafe, because collection only frees bags whose grace period has
    /// already fully elapsed.
    pub fn force_reclaim(&self, rounds: usize) {
        let local = local_handle(self);
        let rounds = if local.pin_depth.get() > 0 { rounds.min(1) } else { rounds };
        for _ in 0..rounds {
            if !self.try_advance_and_collect(&local) {
                break;
            }
        }
    }

    /// Attempt one epoch advance; on success drain newly-expired bags and
    /// orphans. Returns whether the epoch moved.
    fn try_advance_and_collect(&self, local: &Rc<Local>) -> bool {
        // ord: relaxed-ok — stats counter only.
        self.advance_attempts.fetch_add(1, Ordering::Relaxed);
        let e = self.global_epoch.load(Ordering::Acquire);
        // Pair with the pin-side fence: everything announced before this
        // fence is visible to the scan below.
        // ord: SeqCst fence — the other half of pin's Dekker handshake.
        std::sync::atomic::fence(Ordering::SeqCst);
        for slot in self.slots.iter() {
            if !slot.owned.load(Ordering::Acquire) {
                continue;
            }
            let s = slot.state.load(Ordering::Acquire);
            let active = s & 1 == 1;
            let announced = s >> 1;
            if active && announced != e {
                // A straggler is still inside an older epoch: cannot advance.
                return false;
            }
        }
        let moved = self
            .global_epoch
            // ord: Release publishes the advance after a clean scan;
            // Acquire counterpart: global_epoch loads in pin,
            // defer_retired and epoch().
            .compare_exchange(e, e + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_ok();
        if moved {
            // ord: relaxed-ok — stats counter only.
            self.advances.fetch_add(1, Ordering::Relaxed);
        }
        // Whether we or a peer moved it, drain what is now expired.
        let now = self.global_epoch.load(Ordering::Acquire);
        local.observed_epoch.set(now);
        self.drain_expired(local, now);
        self.drain_orphans(now);
        // Pressure stays raised until the backlog is actually gone, so
        // successive pins keep making progress (items retired at e need
        // two further advances before they free).
        // ord: relaxed-ok — racy backlog check; worst case the flag stays
        // raised one extra round and the next pin re-tries.
        if self.pending_items.load(Ordering::Relaxed) == 0 {
            // ord: Release clears the flag after the drains above; Acquire
            // counterpart: pressure_requested.
            self.pressure.store(false, Ordering::Release);
        }
        moved
    }

    /// Free every bag of `local` whose epoch is ≤ `now - 2`.
    fn drain_expired(&self, local: &Rc<Local>, now: u64) {
        let mut bags = local.bags.borrow_mut();
        for bag in bags.iter_mut() {
            if bag.epoch + 2 <= now && !bag.is_empty() {
                let (n, bytes) = bag.drain();
                // ord: relaxed-ok — stats counters only (×3 below).
                self.pending_items.fetch_sub(n, Ordering::Relaxed);
                // ord: relaxed-ok — stats counter.
                self.pending_bytes.fetch_sub(bytes, Ordering::Relaxed);
                // ord: relaxed-ok — stats counter.
                self.reclaimed_items.fetch_add(n, Ordering::Relaxed);
            }
        }
    }

    /// Free orphaned items whose handoff epoch has expired.
    fn drain_orphans(&self, now: u64) {
        let mut orphans = match self.orphans.try_lock() {
            Ok(o) => o,
            Err(_) => return, // someone else is on it
        };
        let before = orphans.len();
        let mut kept = Vec::new();
        let mut bytes = 0usize;
        for o in orphans.drain(..) {
            if o.epoch + 2 <= now {
                bytes += o.item.bytes();
                // SAFETY: the item was orphaned at `o.epoch`; two full
                // advances have happened since, so no guard can still
                // observe it — the grace period has elapsed.
                unsafe { o.item.reclaim() };
            } else {
                kept.push(o);
            }
        }
        let freed = before - kept.len();
        *orphans = kept;
        if freed > 0 {
            // ord: relaxed-ok — stats counters only (×3 below).
            self.pending_items.fetch_sub(freed, Ordering::Relaxed);
            // ord: relaxed-ok — stats counter.
            self.pending_bytes.fetch_sub(bytes, Ordering::Relaxed);
            // ord: relaxed-ok — stats counter.
            self.reclaimed_items.fetch_add(freed, Ordering::Relaxed);
        }
    }
}

impl Drop for Collector {
    fn drop(&mut self) {
        // Pin-balance check: by the time the collector drops, every
        // thread's `Local` has dropped (they hold `Arc<Collector>`), and
        // `Local::drop` zeroes + releases its slot. A slot still owned or
        // announced active here means a guard or registration was leaked
        // past its collector — a use-after-free in waiting.
        #[cfg(debug_assertions)]
        for (i, slot) in self.slots.iter().enumerate() {
            // ord: relaxed-ok — `&mut self` in drop; no concurrent
            // writers exist (×2 below).
            let s = slot.state.load(Ordering::Relaxed);
            assert_eq!(s & 1, 0, "EBR slot {i} still pinned at collector drop");
            assert!(
                // ord: relaxed-ok — exclusive access in drop.
                !slot.owned.load(Ordering::Relaxed),
                "EBR slot {i} still registered at collector drop"
            );
        }
        // Exclusive access: every handle has been dropped (handles hold an
        // Arc), so all bags have been orphaned. Reclaim everything.
        let orphans = self.orphans.get_mut().unwrap();
        for o in orphans.drain(..) {
            // SAFETY: no guard can exist anymore (guards transitively hold
            // the collector alive), so every grace period has trivially
            // elapsed.
            unsafe { o.item.reclaim() };
        }
    }
}

/// RAII pin. While alive, loads from EBR-protected structures stay valid.
pub struct Guard {
    local: Rc<Local>,
}

impl Guard {
    /// Retire a raw allocation: `reclaim(ptr, ctx)` runs after a full
    /// grace period. `bytes` is an accounting hint for pressure stats.
    ///
    /// # Safety
    /// `ptr` must not be reachable by threads that pin *after* this call,
    /// and `reclaim` must be safe to run exactly once on it.
    pub unsafe fn defer(&self, ptr: *mut u8, ctx: usize, bytes: usize, reclaim: unsafe fn(*mut u8, usize)) {
        self.defer_retired(Retired::new(ptr, ctx, bytes, reclaim));
    }

    /// Retire a `Box<T>` so it is dropped after a grace period.
    ///
    /// # Safety
    /// Same reachability contract as [`Guard::defer`]; `ptr` must have
    /// come from `Box::into_raw`.
    pub unsafe fn defer_drop_box<T>(&self, ptr: *mut T) {
        // SAFETY: runs once, after the grace period, on the pointer passed
        // below — which the caller contract says came from Box::into_raw.
        unsafe fn dropper<T>(p: *mut u8, _ctx: usize) {
            drop(Box::from_raw(p as *mut T));
        }
        self.defer_retired(Retired::new(
            ptr as *mut u8,
            0,
            std::mem::size_of::<T>(),
            dropper::<T>,
        ));
    }

    fn defer_retired(&self, item: Retired) {
        let c = &self.local.collector;
        let bytes = item.bytes();
        {
            // Stamp with the *global* epoch, not this thread's announced
            // epoch: while we are pinned at e-1 the global may already be
            // at e, and a reader pinned at e could hold a reference to the
            // object — tagging e makes the free wait until e+2, which that
            // reader (announced e) provably blocks while pinned.
            let now = c.global_epoch.load(Ordering::Acquire);
            let mut bags = self.local.bags.borrow_mut();
            let bag = &mut bags[(now % 3) as usize];
            if bag.epoch != now {
                if !bag.is_empty() {
                    // Slot reuse: the previous occupant is ≥3 epochs old,
                    // hence expired — drain it first.
                    debug_assert!(bag.epoch + 2 <= now, "unexpired bag reuse");
                    let (n, freed_bytes) = bag.drain();
                    // ord: relaxed-ok — stats counters only (×3 below).
                    c.pending_items.fetch_sub(n, Ordering::Relaxed);
                    // ord: relaxed-ok — stats counter.
                    c.pending_bytes.fetch_sub(freed_bytes, Ordering::Relaxed);
                    // ord: relaxed-ok — stats counter.
                    c.reclaimed_items.fetch_add(n, Ordering::Relaxed);
                }
                bag.epoch = now;
            }
            bag.push(item);
        }
        // ord: relaxed-ok — stats counter (and the one below).
        c.pending_items.fetch_add(1, Ordering::Relaxed);
        // ord: relaxed-ok — stats counter.
        c.pending_bytes.fetch_add(bytes, Ordering::Relaxed);
        // The DEBRA deviation: only *attempt* progress when this thread's
        // backlog crosses the threshold or the slab asked for memory.
        let backlog: usize = self.local.bags.borrow().iter().map(Bag::len).sum();
        // ord: relaxed-ok — pressure is a hint here; try_advance does its
        // own synchronization.
        if backlog >= c.config.retire_threshold || c.pressure.load(Ordering::Relaxed) {
            c.try_advance_and_collect(&self.local);
        }
    }

    /// The collector this guard pins.
    pub fn collector(&self) -> &Arc<Collector> {
        &self.local.collector
    }
}

impl Drop for Guard {
    fn drop(&mut self) {
        let depth = self.local.pin_depth.get() - 1;
        self.local.pin_depth.set(depth);
        if depth == 0 {
            let slot = &self.local.collector.slots[self.local.slot_idx].state;
            // Deactivate but keep the announced epoch (DEBRA quiescence).
            // ord: relaxed-ok — reading our own announce word; only this
            // thread writes it while registered.
            let s = slot.load(Ordering::Relaxed);
            // ord: Release — the reads we did while pinned happen-before a
            // try_advance that observes us inactive; Acquire counterpart:
            // the state scan in try_advance_and_collect.
            slot.store(s & !1, Ordering::Release);
        }
    }
}

/// Thread-local registration with one collector.
struct Local {
    slot_idx: usize,
    pin_depth: Cell<usize>,
    observed_epoch: Cell<u64>,
    bags: RefCell<[Bag; 3]>,
    collector: Arc<Collector>,
}

impl Drop for Local {
    fn drop(&mut self) {
        // Thread exit: orphan remaining items, release the slot.
        let mut orphans = self.collector.orphans.lock().unwrap();
        let epoch = self.observed_epoch.get();
        for bag in self.bags.borrow_mut().iter_mut() {
            let bag_epoch = bag.epoch;
            for item in bag.take_all() {
                orphans.push(Orphan {
                    epoch: bag_epoch.max(epoch),
                    item,
                });
            }
        }
        let slot = &self.collector.slots[self.slot_idx];
        // ord: SeqCst — the deactivation must be totally ordered with the
        // pin/advance fences before the slot is recycled, so no scanner
        // can still see this exiting thread as an active straggler.
        slot.state.store(0, Ordering::SeqCst);
        // ord: Release hands the slot back (after the orphan handoff
        // above); Acquire counterpart: the claim CAS in local_handle and
        // the owned scan in try_advance_and_collect.
        slot.owned.store(false, Ordering::Release);
    }
}

thread_local! {
    /// (collector address → local registration); linear scan, tiny.
    static LOCALS: UnsafeCell<Vec<(usize, Rc<Local>)>> = const { UnsafeCell::new(Vec::new()) };
}

/// Find (or create) this thread's registration with `collector`.
fn local_handle(collector: &Collector) -> Rc<Local> {
    let key = collector as *const Collector as usize;
    LOCALS.with(|cell| {
        // SAFETY: single-threaded access (thread_local), no re-entrancy:
        // nothing below calls back into LOCALS.
        let locals = unsafe { &mut *cell.get() };
        if let Some((_, l)) = locals.iter().find(|(k, _)| *k == key) {
            return Rc::clone(l);
        }
        // Register: claim a free slot. The registration holds a strong
        // handle (upgraded from the collector's own weak) so limbo bags
        // never outlive the collector.
        let idx = collector
            .slots
            .iter()
            .position(|s| {
                // ord: relaxed-ok — optimistic pre-check; ownership is
                // decided by the CAS below.
                !s.owned.load(Ordering::Relaxed)
                    && s.owned
                        // ord: AcqRel claim — Acquire sees the previous
                        // owner's Release in Local::drop (zeroed state);
                        // Release pairs with the owned scan in
                        // try_advance_and_collect.
                        .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
            })
            .expect("EBR: more than MAX_THREADS concurrent threads");
        let epoch = collector.global_epoch.load(Ordering::Acquire);
        let local = Rc::new(Local {
            slot_idx: idx,
            pin_depth: Cell::new(0),
            observed_epoch: Cell::new(epoch),
            bags: RefCell::new([Bag::new(epoch), Bag::new(epoch), Bag::new(epoch)]),
            collector: collector
                .self_weak
                .upgrade()
                .expect("EBR: collector pinned while being dropped"),
        });
        locals.push((key, Rc::clone(&local)));
        // Opportunistically GC dead registrations (collector freed).
        locals.retain(|(_, l)| Rc::strong_count(l) > 1 || Arc::strong_count(&l.collector) > 1);
        local
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static DROPS: AtomicUsize = AtomicUsize::new(0);

    struct Tracked;
    impl Drop for Tracked {
        fn drop(&mut self) {
            DROPS.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn deferred_drop_waits_for_grace_period() {
        DROPS.store(0, Ordering::SeqCst);
        let c = Collector::new(Config {
            retire_threshold: usize::MAX, // never auto-advance
        });
        {
            let g = c.pin();
            unsafe { g.defer_drop_box(Box::into_raw(Box::new(Tracked))) };
            assert_eq!(DROPS.load(Ordering::SeqCst), 0);
        }
        // Still not dropped: no advancement happened.
        assert_eq!(DROPS.load(Ordering::SeqCst), 0);
        assert_eq!(c.pending_items(), 1);
        // Two forced epochs later it must be gone.
        c.force_reclaim(3);
        assert_eq!(DROPS.load(Ordering::SeqCst), 1);
        assert_eq!(c.pending_items(), 0);
        assert_eq!(c.reclaimed_items(), 1);
    }

    #[test]
    fn pinned_reader_blocks_advancement() {
        let c = Collector::default();
        let c2 = Arc::clone(&c);
        let epoch0 = c.epoch();
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        let reader = std::thread::spawn(move || {
            let _g = c2.pin();
            started_tx.send(()).unwrap();
            release_rx.recv().unwrap();
        });
        started_rx.recv().unwrap();
        // Reader is pinned at epoch0: at most one advance can happen
        // (threads announced at e can't block e->e+1 only if announced==e).
        c.force_reclaim(5);
        assert!(
            c.epoch() <= epoch0 + 1,
            "epoch ran ahead of a pinned reader: {} vs {}",
            c.epoch(),
            epoch0
        );
        release_tx.send(()).unwrap();
        reader.join().unwrap();
        c.force_reclaim(5);
        assert!(c.epoch() >= epoch0 + 2);
    }

    #[test]
    fn threshold_triggers_reclamation_without_explicit_force() {
        DROPS.store(0, Ordering::SeqCst);
        let c = Collector::new(Config {
            retire_threshold: 8,
        });
        // Retire from a worker thread so its Local (and the Arc it holds)
        // is gone after join; the main thread never pins.
        let c2 = Arc::clone(&c);
        std::thread::spawn(move || {
            for _ in 0..64 {
                let g = c2.pin();
                unsafe { g.defer_drop_box(Box::into_raw(Box::new(Tracked))) };
            }
        })
        .join()
        .unwrap();
        // Threshold-driven advances freed most; the tail was orphaned at
        // thread exit and Collector::drop flushes it.
        drop(c);
        assert_eq!(DROPS.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn pressure_flag_forces_progress_on_next_pin() {
        DROPS.store(0, Ordering::SeqCst);
        let c = Collector::new(Config {
            retire_threshold: usize::MAX,
        });
        {
            let g = c.pin();
            unsafe { g.defer_drop_box(Box::into_raw(Box::new(Tracked))) };
        }
        c.request_reclaim();
        assert!(c.pressure_requested());
        // A few pins from the only thread must flush it.
        for _ in 0..4 {
            drop(c.pin());
        }
        assert_eq!(DROPS.load(Ordering::SeqCst), 1);
        assert!(!c.pressure_requested());
    }

    #[test]
    fn exiting_thread_orphans_are_reclaimed() {
        DROPS.store(0, Ordering::SeqCst);
        let c = Collector::new(Config {
            retire_threshold: usize::MAX,
        });
        let c2 = Arc::clone(&c);
        std::thread::spawn(move || {
            let g = c2.pin();
            unsafe { g.defer_drop_box(Box::into_raw(Box::new(Tracked))) };
        })
        .join()
        .unwrap();
        assert_eq!(DROPS.load(Ordering::SeqCst), 0);
        c.force_reclaim(4);
        assert_eq!(DROPS.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn reentrant_pin_is_allowed() {
        let c = Collector::default();
        let g1 = c.pin();
        let g2 = c.pin();
        drop(g1);
        drop(g2);
        c.force_reclaim(3); // must not deadlock or panic
    }

    #[test]
    fn advance_stats_reflect_lazy_policy() {
        let c = Collector::new(Config {
            retire_threshold: usize::MAX,
        });
        for _ in 0..1000 {
            drop(c.pin());
        }
        let (attempts, _) = c.advance_stats();
        assert_eq!(attempts, 0, "lazy collector attempted advances with no pressure");
    }
}
