//! Batched request planning: lossless `proto::Command` → [`Op`]
//! translation, the reply plan that renders batch results back into wire
//! bytes, and [`drain`] — the protocol pump both server front-ends
//! (thread-per-connection and the reactor) run per connection.
//!
//! The pump drains complete commands out of a read buffer into one flat
//! `Vec<Op>` (a multi-key `get` fans out into one `Op::Get` per key) and
//! a parallel [`Action`] list that remembers how to reply — which ops
//! belong to which command, `noreply` suppression, `gets` CAS rendering.
//! Each round then crosses the engine in a single
//! [`crate::cache::Cache::execute_batch_into`] call whose sink **is the
//! reply emitter** ([`EmitSink`]): results stream out of the engine
//! directly into the connection outbuf. A GET hit's value bytes are
//! lent by the engine (FLeeC: slab bytes under the pinned batch guard)
//! and land in the outbuf in **one memcpy** — no `GetResult` Vec, no
//! intermediate copy, byte-identical to the owned reference renderer
//! [`emit`] (kept as the differential-testing oracle;
//! `rust/tests/read_path.rs` holds the two equal on random pipelines).
//!
//! Wire replies must come out in command order, but a sharded router
//! delivers results shard-grouped ([`crate::cache::BatchSink`] leaves
//! delivery order free). The emitter streams the in-order prefix
//! straight through and **parks** out-of-order arrivals — tiny outcomes
//! in a recycled slot array, value bytes in one recycled spill buffer —
//! flushing each as its turn comes. Over a bare engine (in-order
//! delivery) the parking machinery never engages and every hit takes
//! the zero-copy path.
//!
//! [`Action`] carries no borrowed data: value-reply keys are recovered
//! from the op list itself (`ops[first + i].key()`), so the action arena
//! recycles trivially and — together with [`BatchArena`]'s lifetime
//! laundering of the op vector, the multi-key `get` scratch it feeds
//! to [`proto::parse_into`], and the emitter's recycled park/spill
//! buffers — the read path allocates nothing once a connection's arenas
//! are warm, on both the request and the reply side (reply numerics are
//! formatted through the stack-buffer [`proto::write_uint`], not
//! `to_string`).
//!
//! Two commands cannot ride in a batch: `stats` (reads the very counters
//! the pending ops are about to bump) and `flush_all` (clobbers state the
//! pending ops must see first). Those are *barriers* — [`drain`] executes
//! the pending batch, handles them inline, and starts a new batch — so
//! pipelines containing them still observe sequential semantics. `quit`
//! is a barrier too (pending replies must flush before the connection
//! closes).
//!
//! Rounds are bounded: at most [`ROUND_OPS`] ops execute per engine
//! crossing, and [`drain`] stops consuming input once the output buffer
//! reaches the caller's budget. The bound is what makes a slow reader
//! harmless — un-executed commands stay as *bytes* in the read buffer
//! (or the kernel socket buffer) instead of materializing as reply
//! values, so a connection's reply memory is capped at
//! `budget + one round × max_item_size` no matter how many requests it
//! has pipelined (a round is < [`ROUND_OPS`] + [`MAX_GET_KEYS`] ops: the
//! cap is checked between commands, and no single command may fan out
//! into more than [`MAX_GET_KEYS`] ops).

use crate::cache::tenant::{TenantConn, TenantSink};
use crate::cache::{BatchSink, Cache, Op, OpResult, StoreOutcome};
use crate::proto::{self, Command, Parsed, StatsSub, StoreKind};
use crate::server::ServerObs;

/// The `version` reply, shared by both renderers (the owned oracle and
/// the streaming emitter must never drift apart byte-wise).
const VERSION_REPLY: &[u8] = b"VERSION fleec-0.1.0\r\n";

/// Maximum ops executed per engine crossing. Splitting an over-long
/// pipeline into rounds is semantically free (a batch is defined to equal
/// its sequential execution) and keeps the reply-buffer overshoot past
/// the drain budget bounded by one round.
pub const ROUND_OPS: usize = 64;

/// Maximum keys a single `get`/`gets` may carry. A multi-key get is one
/// command — its `VALUE…END` reply is atomic — so it cannot be split
/// across rounds; without a cap, one ~64 KiB command line of repeated
/// keys could materialize tens of thousands of values in a single round
/// and void the drain-budget memory bound. Over-limit gets answer
/// `CLIENT_ERROR` (a server-chosen limit, like Memcached's own line
/// cap), identically in both front-end models.
pub const MAX_GET_KEYS: usize = ROUND_OPS;

/// Reply plan for one parsed command: where its ops landed in the batch
/// and how to render their results. Deliberately borrow-free (see module
/// docs) so the plan vector survives across reads inside [`BatchArena`].
#[derive(Debug, Clone, Copy)]
pub enum Action {
    /// `get`/`gets`: `count` consecutive `Op::Get`s starting at `first`
    /// (reply keys are read back out of the ops themselves).
    Values {
        first: usize,
        count: usize,
        with_cas: bool,
    },
    /// Any of the six storage commands: one op at `first`.
    Store { first: usize, noreply: bool },
    /// `delete`: one op at `first`.
    Delete { first: usize, noreply: bool },
    /// `incr`/`decr`: one op at `first`.
    Counter { first: usize, noreply: bool },
    /// `touch`: one op at `first`.
    Touch { first: usize, noreply: bool },
    /// `version`: constant reply, no engine op.
    Version,
    /// `verbosity`: constant `OK`, no engine op.
    Ok { noreply: bool },
    /// Parse failure: `CLIENT_ERROR <msg>`, no engine op.
    ClientError(&'static str),
}

/// Per-connection reusable batch state: the op and action vectors live
/// here between reads so their allocations (and growth) are paid once per
/// connection, not once per wakeup.
///
/// `Op<'a>` borrows from the read buffer, so the op vector cannot be
/// *stored* at that lifetime; it is parked empty at `'static` and
/// re-borrowed per round via [`recycle_ops`].
#[derive(Default)]
pub struct BatchArena {
    ops: Vec<Op<'static>>,
    actions: Vec<Action>,
    /// Scratch for [`proto::parse_into`]'s multi-key `get` list; same
    /// park-empty-at-`'static` recycling as `ops`.
    keys: Vec<&'static [u8]>,
    /// [`EmitSink`]'s out-of-order parking slots (one per op; engaged
    /// only when a router delivers shard-grouped). Lifetime-free, so
    /// plain recycling.
    pending: Vec<Pending>,
    /// Value bytes of parked hits, appended end-to-end — one shared
    /// recycled buffer, not one allocation per parked value.
    spill: Vec<u8>,
    /// Namespaced execution ops for non-default tenants (same
    /// park-empty-at-`'static` recycling as `ops`); never engaged on the
    /// default tenant or a tenant-less server.
    ns_ops: Vec<Op<'static>>,
    /// Backing bytes for the namespaced keys (`<tenant>\x1f<key>`),
    /// appended end-to-end per flush and recycled.
    ns_buf: Vec<u8>,
}

impl BatchArena {
    /// Borrow the arenas for one drain call (empty, capacity retained).
    #[allow(clippy::type_complexity)]
    fn take<'a>(&mut self) -> (Vec<Op<'a>>, Vec<Action>, Vec<&'a [u8]>) {
        (
            recycle_ops(std::mem::take(&mut self.ops)),
            std::mem::take(&mut self.actions),
            recycle_keys(std::mem::take(&mut self.keys)),
        )
    }

    /// Return the arenas; contents are cleared, capacity kept.
    fn put(&mut self, ops: Vec<Op<'_>>, mut actions: Vec<Action>, keys: Vec<&[u8]>) {
        self.ops = recycle_ops(ops);
        actions.clear();
        self.actions = actions;
        self.keys = recycle_keys(keys);
    }
}

/// Re-lifetime an **emptied** op vector, keeping its allocation.
///
/// SAFETY: the vector is cleared first, so no `Op<'from>` value is ever
/// read at `'to`. `Op<'from>` and `Op<'to>` are the same type constructor
/// instantiated at different lifetimes — lifetimes do not affect layout,
/// so size, alignment and allocator contract are identical and rebuilding
/// the `Vec` around the same buffer is sound. (This is the standard
/// "recycle an empty Vec across lifetimes" pattern.)
fn recycle_ops<'from, 'to>(mut v: Vec<Op<'from>>) -> Vec<Op<'to>> {
    v.clear();
    let cap = v.capacity();
    let ptr = v.as_mut_ptr();
    std::mem::forget(v);
    // SAFETY: see the doc above — the Vec is empty, and `Op<'from>` /
    // `Op<'to>` share layout and allocator contract.
    unsafe { Vec::from_raw_parts(ptr as *mut Op<'to>, 0, cap) }
}

/// Same soundness argument as [`recycle_ops`], for the key scratch.
fn recycle_keys<'from, 'to>(mut v: Vec<&'from [u8]>) -> Vec<&'to [u8]> {
    v.clear();
    let cap = v.capacity();
    let ptr = v.as_mut_ptr();
    std::mem::forget(v);
    // SAFETY: empty Vec recycled across lifetimes — same argument as
    // [`recycle_ops`].
    unsafe { Vec::from_raw_parts(ptr as *mut &'to [u8], 0, cap) }
}

/// Render a `stats` barrier's reply. Goes through [`Cache::stats`], the
/// one coherent snapshot an engine can assemble however it likes — a
/// sharded router merges all its shards here (counters and `curr_items`
/// sum, per-shard `mem_limit`s add back up to the configured total, and
/// the latency/internals observability extras fold bucket-wise), so
/// `limit_maxbytes` over a sharded server stays truthful and every
/// subcommand renders from one coherent snapshot.
/// `server` carries the serving-plane gauges for `stats internals`
/// (`None` in tests and offline tools renders engine internals only).
/// `tenants` is the connection's tenant plane when one is configured;
/// `stats tenants` without a plane is a client error.
pub fn write_stats_reply(
    cache: &dyn Cache,
    sub: StatsSub,
    info: &proto::ServerInfo,
    server: Option<&proto::ServerGauges>,
    tenants: Option<&crate::cache::tenant::TenantPlane>,
    out: &mut Vec<u8>,
) {
    if let StatsSub::Tenants = sub {
        match tenants {
            Some(plane) => proto::write_stats_tenants(out, &plane.snapshot()),
            None => out.extend_from_slice(b"CLIENT_ERROR tenant support is not enabled\r\n"),
        }
        return;
    }
    let stats = cache.stats();
    match sub {
        StatsSub::All => proto::write_stats(out, cache.engine_name(), &stats, info),
        StatsSub::Latency => proto::write_stats_latency(out, &stats.latency),
        StatsSub::Slabs => proto::write_stats_slabs(out, &stats.slabs),
        StatsSub::Internals => proto::write_stats_internals(out, &stats.internals, server),
        StatsSub::Tenants => unreachable!("handled above"),
    }
}

/// Whether `cmd` must not share a batch with the ops queued before it
/// (see the module docs). [`drain`] executes the pending batch first and
/// then handles the command inline.
pub fn is_barrier(cmd: &Command<'_>) -> bool {
    matches!(
        cmd,
        Command::Stats { .. }
            | Command::FlushAll { .. }
            | Command::Tenant { .. }
            | Command::Quit
    )
}

/// Append the data ops backing `cmd` to `ops` and its reply plan to
/// `actions`. Lossless: every field of the parsed command survives into
/// either the op or the action. Barrier commands (see [`is_barrier`]) are
/// the caller's job and not accepted here.
///
/// `key_scratch` is the buffer [`proto::parse_into`] collected a `get`'s
/// keys into: a `Get` command hands it back here (cleared, capacity
/// kept) so the next parse reuses the allocation.
pub fn plan<'a>(
    cmd: Command<'a>,
    ops: &mut Vec<Op<'a>>,
    actions: &mut Vec<Action>,
    key_scratch: &mut Vec<&'a [u8]>,
) {
    match cmd {
        Command::Get { mut keys, with_cas } => {
            if keys.len() > MAX_GET_KEYS {
                actions.push(Action::ClientError("too many keys in get"));
            } else {
                let first = ops.len();
                let count = keys.len();
                for &key in &keys {
                    ops.push(Op::Get { key });
                }
                actions.push(Action::Values {
                    first,
                    count,
                    with_cas,
                });
            }
            keys.clear();
            *key_scratch = keys;
        }
        Command::Store {
            kind,
            key,
            flags,
            exptime,
            data,
            cas,
            noreply,
        } => {
            let first = ops.len();
            ops.push(match kind {
                StoreKind::Set => Op::Set {
                    key,
                    value: data,
                    flags,
                    exptime,
                },
                StoreKind::Add => Op::Add {
                    key,
                    value: data,
                    flags,
                    exptime,
                },
                StoreKind::Replace => Op::Replace {
                    key,
                    value: data,
                    flags,
                    exptime,
                },
                StoreKind::Append => Op::Append { key, suffix: data },
                StoreKind::Prepend => Op::Prepend { key, prefix: data },
                StoreKind::Cas => Op::CasOp {
                    key,
                    value: data,
                    flags,
                    exptime,
                    cas,
                },
            });
            actions.push(Action::Store { first, noreply });
        }
        Command::Delete { key, noreply } => {
            let first = ops.len();
            ops.push(Op::Delete { key });
            actions.push(Action::Delete { first, noreply });
        }
        Command::Incr { key, delta, noreply } => {
            let first = ops.len();
            ops.push(Op::Incr { key, delta });
            actions.push(Action::Counter { first, noreply });
        }
        Command::Decr { key, delta, noreply } => {
            let first = ops.len();
            ops.push(Op::Decr { key, delta });
            actions.push(Action::Counter { first, noreply });
        }
        Command::Touch { key, exptime, noreply } => {
            let first = ops.len();
            ops.push(Op::Touch { key, exptime });
            actions.push(Action::Touch { first, noreply });
        }
        Command::Version => actions.push(Action::Version),
        Command::Verbosity { noreply } => actions.push(Action::Ok { noreply }),
        Command::Stats { .. }
        | Command::FlushAll { .. }
        | Command::Tenant { .. }
        | Command::Quit => {
            unreachable!("barrier commands are handled by the caller")
        }
    }
}

/// Render replies for `actions` against **owned** batch `results`,
/// appending wire bytes to `out` in command order. `ops` is the batch
/// the actions index into (value replies read their keys from it).
///
/// This is the reference renderer over the owned
/// [`Cache::execute_batch`] tier. The live pump no longer uses it —
/// [`drain`] streams results through [`EmitSink`] instead — but it is
/// kept as the differential-testing oracle: `rust/tests/read_path.rs`
/// holds the two paths byte-identical on randomized pipelines across
/// every engine and the shard router.
///
/// Returns `true` when a result-variant mismatch turned the reply stream
/// fatal (see [`mismatch`]); callers serving a live connection must
/// flush and close.
pub fn emit(ops: &[Op<'_>], actions: &[Action], results: &[OpResult], out: &mut Vec<u8>) -> bool {
    let mut fatal = false;
    for action in actions {
        match *action {
            Action::Values {
                first,
                count,
                with_cas,
            } => {
                for i in 0..count {
                    if let OpResult::Value(Some(r)) = &results[first + i] {
                        proto::write_value(
                            out,
                            ops[first + i].key(),
                            r.flags,
                            &r.data,
                            with_cas.then_some(r.cas),
                        );
                    }
                }
                proto::write_end(out);
            }
            Action::Store { first, noreply } => {
                if !noreply {
                    match results[first] {
                        OpResult::Store(outcome) => {
                            out.extend_from_slice(proto::store_reply(outcome))
                        }
                        _ => mismatch(out, &mut fatal),
                    }
                }
            }
            Action::Delete { first, noreply } => {
                if !noreply {
                    match results[first] {
                        OpResult::Deleted(true) => out.extend_from_slice(b"DELETED\r\n"),
                        OpResult::Deleted(false) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out, &mut fatal),
                    }
                }
            }
            Action::Counter { first, noreply } => {
                if !noreply {
                    match results[first] {
                        OpResult::Counter(Some(v)) => {
                            proto::write_uint(out, v);
                            out.extend_from_slice(b"\r\n");
                        }
                        OpResult::Counter(None) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out, &mut fatal),
                    }
                }
            }
            Action::Touch { first, noreply } => {
                if !noreply {
                    match results[first] {
                        OpResult::Touched(true) => out.extend_from_slice(b"TOUCHED\r\n"),
                        OpResult::Touched(false) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out, &mut fatal),
                    }
                }
            }
            Action::Version => out.extend_from_slice(VERSION_REPLY),
            Action::Ok { noreply } => {
                if !noreply {
                    out.extend_from_slice(b"OK\r\n");
                }
            }
            Action::ClientError(msg) => {
                out.extend_from_slice(b"CLIENT_ERROR ");
                out.extend_from_slice(msg.as_bytes());
                out.extend_from_slice(b"\r\n");
            }
        }
    }
    fatal
}

/// An engine returned a result variant that doesn't match the op — a
/// `Cache::execute_batch` contract violation. Emit a framed error rather
/// than hanging the client, and flag the stream **fatal**: past this
/// point reply/command alignment is untrustworthy (the client counts
/// replies; a wrong variant may have produced the wrong number of
/// lines), so the connection must close after flushing. Serving on would
/// silently answer command N+1's reply to command N forever.
fn mismatch(out: &mut Vec<u8>, fatal: &mut bool) {
    out.extend_from_slice(b"SERVER_ERROR batch result mismatch\r\n");
    *fatal = true;
}

/// One parked out-of-order result inside [`EmitSink`]. Everything is
/// `Copy`-small; a parked hit's bytes live in the arena's shared spill
/// buffer at `spill[lo..hi]` (`u32` offsets: a round's reply volume is
/// bounded far below 4 GiB by [`ROUND_OPS`] × [`proto::MAX_DATA_LEN`]).
#[derive(Clone, Copy)]
enum Pending {
    /// Not delivered yet.
    NotYet,
    /// Value hit, bytes parked in the spill buffer.
    Value { flags: u32, cas: u64, lo: u32, hi: u32 },
    Miss,
    Store(StoreOutcome),
    Deleted(bool),
    Counter(Option<u64>),
    Touched(bool),
}

/// A result being rendered: either fresh from the engine (`data`
/// borrowed from slab/entry memory — this is the zero-copy path) or
/// re-materialized from the park slots.
enum Rendered<'a> {
    Value { flags: u32, cas: u64, data: &'a [u8] },
    Miss,
    Store(StoreOutcome),
    Deleted(bool),
    Counter(Option<u64>),
    Touched(bool),
    /// Exactly-once contract violation: the op was never delivered.
    /// Renders as a mismatch wherever a reply is owed (keeps framing).
    Missing,
}

/// The streaming reply emitter — a [`BatchSink`] that renders wire bytes
/// straight into the connection outbuf as the engine delivers results.
///
/// In-order deliveries (bare engines) render immediately: a GET hit's
/// borrowed bytes go slab→outbuf in one `memcpy`, store/counter/touch
/// outcomes become their reply lines, and the action cursor interleaves
/// zero-op replies (`VERSION`, `CLIENT_ERROR`, …) at their command
/// positions. Out-of-order deliveries (sharded routers) park in the
/// arena's recycled slot/spill buffers until their turn. [`finish`]
/// (`EmitSink::finish`) must run after `execute_batch_into` returns to
/// render any trailing zero-op actions.
struct EmitSink<'o, 'b> {
    ops: &'b [Op<'o>],
    actions: &'b [Action],
    out: &'b mut Vec<u8>,
    pending: &'b mut Vec<Pending>,
    spill: &'b mut Vec<u8>,
    /// Actions `[..a_idx]` are fully rendered.
    a_idx: usize,
    /// Next op index owed to the wire.
    next: usize,
    /// A [`mismatch`] was rendered: the stream is desynced and the
    /// connection must close after flushing (reported by
    /// [`EmitSink::finish`]).
    fatal: bool,
}

impl<'o, 'b> EmitSink<'o, 'b> {
    fn new(
        ops: &'b [Op<'o>],
        actions: &'b [Action],
        out: &'b mut Vec<u8>,
        pending: &'b mut Vec<Pending>,
        spill: &'b mut Vec<u8>,
    ) -> Self {
        pending.clear();
        pending.resize(ops.len(), Pending::NotYet);
        spill.clear();
        EmitSink {
            ops,
            actions,
            out,
            pending,
            spill,
            a_idx: 0,
            next: 0,
            fatal: false,
        }
    }

    /// Render every zero-op action at the cursor (they owe the wire a
    /// reply *before* the next op-bearing command's).
    fn catch_up_plain(out: &mut Vec<u8>, actions: &[Action], a_idx: &mut usize) {
        while let Some(action) = actions.get(*a_idx) {
            match *action {
                Action::Version => out.extend_from_slice(VERSION_REPLY),
                Action::Ok { noreply } => {
                    if !noreply {
                        out.extend_from_slice(b"OK\r\n");
                    }
                }
                Action::ClientError(msg) => {
                    out.extend_from_slice(b"CLIENT_ERROR ");
                    out.extend_from_slice(msg.as_bytes());
                    out.extend_from_slice(b"\r\n");
                }
                _ => break,
            }
            *a_idx += 1;
        }
    }

    /// Render op `idx`'s reply fragment (associated fn so callers can
    /// split-borrow `out`/`spill`). Byte-for-byte the same output as the
    /// owned [`emit`] renderer.
    #[allow(clippy::too_many_arguments)]
    fn render_one(
        out: &mut Vec<u8>,
        ops: &[Op<'_>],
        actions: &[Action],
        a_idx: &mut usize,
        fatal: &mut bool,
        idx: usize,
        r: Rendered<'_>,
    ) {
        Self::catch_up_plain(out, actions, a_idx);
        let Some(&action) = actions.get(*a_idx) else {
            debug_assert!(false, "result delivered past the last action");
            return;
        };
        match action {
            Action::Values {
                first,
                count,
                with_cas,
            } => {
                debug_assert!(first <= idx && idx < first + count, "op outside its action");
                match r {
                    Rendered::Value { flags, cas, data } => {
                        proto::write_value_header(
                            out,
                            ops[idx].key(),
                            flags,
                            data.len(),
                            with_cas.then_some(cas),
                        );
                        proto::write_data_crlf(out, data);
                    }
                    // Misses render nothing; so does a mismatched
                    // variant (same as the owned renderer's `if let`).
                    _ => {}
                }
                if idx + 1 == first + count {
                    proto::write_end(out);
                    *a_idx += 1;
                }
            }
            Action::Store { noreply, .. } => {
                if !noreply {
                    match r {
                        Rendered::Store(outcome) => {
                            out.extend_from_slice(proto::store_reply(outcome))
                        }
                        _ => mismatch(out, fatal),
                    }
                }
                *a_idx += 1;
            }
            Action::Delete { noreply, .. } => {
                if !noreply {
                    match r {
                        Rendered::Deleted(true) => out.extend_from_slice(b"DELETED\r\n"),
                        Rendered::Deleted(false) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out, fatal),
                    }
                }
                *a_idx += 1;
            }
            Action::Counter { noreply, .. } => {
                if !noreply {
                    match r {
                        Rendered::Counter(Some(v)) => {
                            proto::write_uint(out, v);
                            out.extend_from_slice(b"\r\n");
                        }
                        Rendered::Counter(None) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out, fatal),
                    }
                }
                *a_idx += 1;
            }
            Action::Touch { noreply, .. } => {
                if !noreply {
                    match r {
                        Rendered::Touched(true) => out.extend_from_slice(b"TOUCHED\r\n"),
                        Rendered::Touched(false) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out, fatal),
                    }
                }
                *a_idx += 1;
            }
            Action::Version | Action::Ok { .. } | Action::ClientError(..) => {
                unreachable!("catch_up_plain consumed every zero-op action")
            }
        }
    }

    /// Rebuild a parked result's [`Rendered`] view (value bytes from the
    /// spill buffer).
    fn unpark(p: Pending, spill: &[u8]) -> Rendered<'_> {
        match p {
            Pending::NotYet => Rendered::Missing,
            Pending::Value { flags, cas, lo, hi } => Rendered::Value {
                flags,
                cas,
                data: &spill[lo as usize..hi as usize],
            },
            Pending::Miss => Rendered::Miss,
            Pending::Store(o) => Rendered::Store(o),
            Pending::Deleted(b) => Rendered::Deleted(b),
            Pending::Counter(c) => Rendered::Counter(c),
            Pending::Touched(b) => Rendered::Touched(b),
        }
    }

    /// Accept one delivery: stream it if it's the next op owed to the
    /// wire (then flush any parked successors), park it otherwise.
    fn deliver(&mut self, idx: usize, r: Rendered<'_>) {
        debug_assert!(idx < self.pending.len(), "delivery index out of range");
        if idx != self.next {
            debug_assert!(
                matches!(self.pending[idx], Pending::NotYet),
                "double delivery for op {idx}"
            );
            self.pending[idx] = match r {
                Rendered::Value { flags, cas, data } => {
                    let lo = self.spill.len() as u32;
                    self.spill.extend_from_slice(data);
                    Pending::Value {
                        flags,
                        cas,
                        lo,
                        hi: self.spill.len() as u32,
                    }
                }
                Rendered::Miss => Pending::Miss,
                Rendered::Store(o) => Pending::Store(o),
                Rendered::Deleted(b) => Pending::Deleted(b),
                Rendered::Counter(c) => Pending::Counter(c),
                Rendered::Touched(b) => Pending::Touched(b),
                // `Missing` is synthesized only by `finish` for
                // undelivered slots; it is never a sink delivery. Keep
                // the slot NotYet (release renders a framed mismatch at
                // finish) but trip loudly in debug builds.
                Rendered::Missing => {
                    debug_assert!(false, "Rendered::Missing delivered to the sink");
                    Pending::NotYet
                }
            };
            return;
        }
        Self::render_one(
            self.out,
            self.ops,
            self.actions,
            &mut self.a_idx,
            &mut self.fatal,
            idx,
            r,
        );
        self.next += 1;
        while self.next < self.pending.len() {
            let p = std::mem::replace(&mut self.pending[self.next], Pending::NotYet);
            if matches!(p, Pending::NotYet) {
                break;
            }
            let r = Self::unpark(p, self.spill);
            Self::render_one(
                self.out,
                self.ops,
                self.actions,
                &mut self.a_idx,
                &mut self.fatal,
                self.next,
                r,
            );
            self.next += 1;
        }
    }

    /// Close out the round after `execute_batch_into` returned: render
    /// anything still owed (undelivered ops — an engine contract
    /// violation — render as framed mismatches) and the trailing zero-op
    /// actions. Returns `true` when the round turned the stream fatal
    /// (any [`mismatch`] rendered): the connection must flush and close.
    fn finish(mut self) -> bool {
        while self.next < self.pending.len() {
            let p = std::mem::replace(&mut self.pending[self.next], Pending::NotYet);
            debug_assert!(
                !matches!(p, Pending::NotYet),
                "engine left op {} undelivered",
                self.next
            );
            let r = Self::unpark(p, self.spill);
            Self::render_one(
                self.out,
                self.ops,
                self.actions,
                &mut self.a_idx,
                &mut self.fatal,
                self.next,
                r,
            );
            self.next += 1;
        }
        Self::catch_up_plain(self.out, self.actions, &mut self.a_idx);
        debug_assert_eq!(self.a_idx, self.actions.len(), "unrendered trailing actions");
        self.fatal
    }
}

impl BatchSink for EmitSink<'_, '_> {
    fn value(&mut self, idx: usize, _key: &[u8], flags: u32, cas: u64, data: &[u8]) {
        // Reply keys come from `ops[idx]` (the engine's `key` is the
        // same bytes by contract).
        self.deliver(idx, Rendered::Value { flags, cas, data });
    }

    fn miss(&mut self, idx: usize) {
        self.deliver(idx, Rendered::Miss);
    }

    fn store(&mut self, idx: usize, outcome: StoreOutcome) {
        self.deliver(idx, Rendered::Store(outcome));
    }

    fn deleted(&mut self, idx: usize, existed: bool) {
        self.deliver(idx, Rendered::Deleted(existed));
    }

    fn counter(&mut self, idx: usize, value: Option<u64>) {
        self.deliver(idx, Rendered::Counter(value));
    }

    fn touched(&mut self, idx: usize, existed: bool) {
        self.deliver(idx, Rendered::Touched(existed));
    }
}

/// Why [`drain`] stopped consuming input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainStop {
    /// The next command is incomplete — feed more bytes, then call again.
    NeedMoreInput,
    /// `out` reached the budget — flush it downstream, then call again
    /// with the *unconsumed* remainder of the input.
    Budget,
    /// A `quit` was executed (pending replies are already in `out`); the
    /// connection should flush and close. Input past the `quit` is
    /// intentionally not consumed.
    Quit,
}

/// Result of one [`drain`] call.
#[derive(Debug, Clone, Copy)]
pub struct Drained {
    /// Bytes of `input` consumed; the caller advances its buffer by this.
    pub consumed: usize,
    pub stop: DrainStop,
    /// A result-variant mismatch desynced the reply stream (see
    /// [`mismatch`]): everything in `out` is still well-framed, but the
    /// caller must flush it and **close the connection** — further
    /// replies could answer the wrong commands.
    pub fatal: bool,
}

/// The protocol pump: parse, plan, execute and reply for every complete
/// command at the head of `input`, appending wire bytes to `out`.
///
/// Executes in rounds of at most [`ROUND_OPS`] ops (one
/// [`Cache::execute_batch`] crossing each) and re-checks `out.len()`
/// against `out_budget` between rounds, so the reply bytes buffered for a
/// connection that isn't draining stay bounded (see module docs).
/// Barriers (`stats`, `flush_all`, `quit`) end a round early and run
/// inline. Both server front-ends call this in a loop: the thread model
/// with a blocking flush between calls, the reactor from its readiness
/// state machine.
///
/// `obs` is the serving plane's observability sink (`None` in tests and
/// offline tools): it supplies the `stats` reply's server facts and, on
/// sampled calls, receives this drain's wall time and per-flush batch
/// sizes. The non-sampled steady state touches only `obs.sample()`'s one
/// relaxed tick.
///
/// `tenant` is the connection's tenant state when the server runs a
/// multi-tenant plane (`None` otherwise): the `tenant` barrier switches
/// it, and every flushed batch executes under its namespace prefix and
/// accounting (see [`crate::cache::tenant`]). A named tenant's prefix
/// consumes key-length budget: client keys longer than
/// `MAX_KEY_LEN - prefix.len()` degrade to the engines' oversized-key
/// behavior (miss / `NOT_STORED`).
pub fn drain(
    cache: &dyn Cache,
    curr_connections: usize,
    input: &[u8],
    out: &mut Vec<u8>,
    arena: &mut BatchArena,
    out_budget: usize,
    obs: Option<&ServerObs>,
    mut tenant: Option<&mut TenantConn>,
) -> Drained {
    let t0 = match obs {
        Some(o) if o.sample() => Some(std::time::Instant::now()),
        _ => None,
    };
    let sampled = t0.is_some();
    let mut consumed = 0;
    let mut fatal = false;
    let (mut ops, mut actions, mut keys) = arena.take();
    let stop = 'drain: loop {
        if out.len() >= out_budget {
            break DrainStop::Budget;
        }
        // One round: plan up to ROUND_OPS ops, or up to a barrier.
        loop {
            match proto::parse_into(&input[consumed..], &mut keys) {
                Parsed::Done(cmd, n) => {
                    consumed += n;
                    if is_barrier(&cmd) {
                        note_batch(obs, sampled, ops.len());
                        fatal |= flush_batch(cache, &mut ops, &mut actions, arena, out, tenant.as_deref());
                        match cmd {
                            Command::Stats { sub } => {
                                let info = match obs {
                                    Some(o) => o.info(curr_connections),
                                    None => proto::ServerInfo {
                                        curr_connections: curr_connections as u64,
                                        ..proto::ServerInfo::default()
                                    },
                                };
                                let gauges = obs.map(|o| o.gauges());
                                let plane = tenant.as_deref().map(|t| &**t.plane());
                                write_stats_reply(cache, sub, &info, gauges.as_ref(), plane, out);
                            }
                            Command::FlushAll { noreply } => {
                                cache.flush_all();
                                if !noreply {
                                    out.extend_from_slice(b"OK\r\n");
                                }
                            }
                            Command::Tenant { name, noreply } => match tenant.as_deref_mut() {
                                None => out.extend_from_slice(
                                    b"CLIENT_ERROR tenant support is not enabled\r\n",
                                ),
                                Some(conn) => match conn.switch(name) {
                                    Ok(()) => {
                                        if !noreply {
                                            out.extend_from_slice(b"OK\r\n");
                                        }
                                    }
                                    Err(msg) => {
                                        out.extend_from_slice(b"CLIENT_ERROR ");
                                        out.extend_from_slice(msg.as_bytes());
                                        out.extend_from_slice(b"\r\n");
                                    }
                                },
                            },
                            Command::Quit => break 'drain DrainStop::Quit,
                            _ => unreachable!("is_barrier covers exactly these"),
                        }
                        break; // barrier ends the round; re-check budget
                    }
                    plan(cmd, &mut ops, &mut actions, &mut keys);
                    if ops.len() >= ROUND_OPS {
                        break; // round full; execute and re-check budget
                    }
                }
                Parsed::Error(msg, n) => {
                    consumed += n;
                    actions.push(Action::ClientError(msg));
                    if actions.len() >= ROUND_OPS {
                        break;
                    }
                }
                Parsed::Incomplete => {
                    note_batch(obs, sampled, ops.len());
                    fatal |= flush_batch(cache, &mut ops, &mut actions, arena, out, tenant.as_deref());
                    break 'drain DrainStop::NeedMoreInput;
                }
            }
        }
        note_batch(obs, sampled, ops.len());
        fatal |= flush_batch(cache, &mut ops, &mut actions, arena, out, tenant.as_deref());
    };
    arena.put(ops, actions, keys);
    if let (Some(o), Some(t0)) = (obs, t0) {
        o.drain_ns.record(t0.elapsed().as_nanos() as u64);
    }
    Drained {
        consumed,
        stop,
        fatal,
    }
}

/// On a sampled drain, record one flushed batch's op count (empty
/// flushes — barrier with nothing pending — are not samples).
#[inline]
fn note_batch(obs: Option<&ServerObs>, sampled: bool, n: usize) {
    if sampled && n > 0 {
        if let Some(o) = obs {
            o.batch_sizes.record(n as u64);
        }
    }
}

/// Execute the pending batch, streaming its replies into `out` through
/// an [`EmitSink`] (the engine lends GET-hit bytes straight into the
/// outbuf); clears both lists. `arena` only contributes the emitter's
/// recycled park/spill buffers — the op/action/key vectors stay checked
/// out with the caller. Returns [`EmitSink::finish`]'s fatal flag.
fn flush_batch(
    cache: &dyn Cache,
    ops: &mut Vec<Op<'_>>,
    actions: &mut Vec<Action>,
    arena: &mut BatchArena,
    out: &mut Vec<u8>,
    tenant: Option<&TenantConn>,
) -> bool {
    if actions.is_empty() && ops.is_empty() {
        return false;
    }
    let fatal = {
        let ops: &[Op<'_>] = ops.as_slice();
        let BatchArena {
            pending,
            spill,
            ns_ops,
            ns_buf,
            ..
        } = arena;
        let mut sink = EmitSink::new(ops, actions.as_slice(), out, pending, spill);
        match tenant {
            None => cache.execute_batch_into(ops, &mut sink),
            Some(conn) => {
                // Accounting wraps the emitter; reply bytes still render
                // from the original ops, so the wrapper is invisible on
                // the wire. Slab attribution follows the thread-local
                // tenant stamp for exactly this engine crossing.
                let mut tsink = TenantSink::new(&mut sink, conn.plane(), conn.id(), ops);
                crate::slab::tenant::set_current(conn.id());
                if conn.prefix().is_empty() {
                    // Default tenant: execution keys are the client keys
                    // byte-for-byte — nothing namespaced, nothing copied.
                    cache.execute_batch_into(ops, &mut tsink);
                } else {
                    // Two passes: materialize every `<prefix><key>` into
                    // one recycled buffer first, then slice it — the
                    // buffer never reallocates under a live borrow.
                    ns_buf.clear();
                    let prefix = conn.prefix();
                    ns_buf.reserve(
                        ops.iter()
                            .map(|op| prefix.len() + op.key().len())
                            .sum(),
                    );
                    for op in ops {
                        ns_buf.extend_from_slice(prefix);
                        ns_buf.extend_from_slice(op.key());
                    }
                    let buf: &[u8] = ns_buf.as_slice();
                    let mut exec_ops = recycle_ops(std::mem::take(ns_ops));
                    let mut at = 0;
                    for op in ops {
                        let len = prefix.len() + op.key().len();
                        exec_ops.push(rekey(op, &buf[at..at + len]));
                        at += len;
                    }
                    cache.execute_batch_into(&exec_ops, &mut tsink);
                    *ns_ops = recycle_ops(exec_ops);
                }
                crate::slab::tenant::set_current(crate::slab::DEFAULT_TENANT);
            }
        }
        sink.finish()
    };
    ops.clear();
    actions.clear();
    fatal
}

/// Clone `op` with its key swapped for the namespaced execution key;
/// every other field is borrowed unchanged. (`Op` is covariant in its
/// lifetime, so the result's lifetime is the shorter of the input
/// buffer's and the namespace buffer's.)
fn rekey<'a>(op: &Op<'a>, key: &'a [u8]) -> Op<'a> {
    match *op {
        Op::Get { .. } => Op::Get { key },
        Op::Set {
            value,
            flags,
            exptime,
            ..
        } => Op::Set {
            key,
            value,
            flags,
            exptime,
        },
        Op::Add {
            value,
            flags,
            exptime,
            ..
        } => Op::Add {
            key,
            value,
            flags,
            exptime,
        },
        Op::Replace {
            value,
            flags,
            exptime,
            ..
        } => Op::Replace {
            key,
            value,
            flags,
            exptime,
        },
        Op::Append { suffix, .. } => Op::Append { key, suffix },
        Op::Prepend { prefix, .. } => Op::Prepend { key, prefix },
        Op::CasOp {
            value,
            flags,
            exptime,
            cas,
            ..
        } => Op::CasOp {
            key,
            value,
            flags,
            exptime,
            cas,
        },
        Op::Delete { .. } => Op::Delete { key },
        Op::Incr { delta, .. } => Op::Incr { key, delta },
        Op::Decr { delta, .. } => Op::Decr { key, delta },
        Op::Touch { exptime, .. } => Op::Touch { key, exptime },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};

    /// Pump a full pipelined buffer through [`drain`] (budget-unbounded)
    /// and return the reply bytes.
    fn run_pipeline(wire: &[u8]) -> Vec<u8> {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let mut arena = BatchArena::default();
        let mut out = Vec::new();
        let mut consumed = 0;
        loop {
            let d = drain(
                cache.as_ref(),
                1,
                &wire[consumed..],
                &mut out,
                &mut arena,
                usize::MAX,
                None,
                None,
            );
            consumed += d.consumed;
            match d.stop {
                DrainStop::NeedMoreInput => break,
                DrainStop::Quit => break,
                DrainStop::Budget => unreachable!("budget is unbounded"),
            }
        }
        assert_eq!(consumed, wire.len(), "pipeline fully consumed");
        out
    }

    #[test]
    fn pipeline_replies_match_per_command_bytes() {
        let out = run_pipeline(
            b"set a 7 0 3\r\nfoo\r\nget a\r\nadd a 0 0 1\r\nx\r\ndelete a\r\ndelete a\r\nget a\r\n",
        );
        assert_eq!(
            out,
            b"STORED\r\nVALUE a 7 3\r\nfoo\r\nEND\r\nNOT_STORED\r\nDELETED\r\nNOT_FOUND\r\nEND\r\n"
                as &[u8],
            "got {:?}",
            String::from_utf8_lossy(&out)
        );
    }

    #[test]
    fn multikey_get_fans_out_and_reassembles() {
        let out = run_pipeline(b"set a 0 0 1\r\n1\r\nset c 0 0 1\r\n3\r\nget a b c\r\n");
        assert_eq!(
            out,
            b"STORED\r\nSTORED\r\nVALUE a 0 1\r\n1\r\nVALUE c 0 1\r\n3\r\nEND\r\n" as &[u8],
            "got {:?}",
            String::from_utf8_lossy(&out)
        );
    }

    #[test]
    fn noreply_and_errors_keep_stream_position() {
        let out = run_pipeline(b"set a 0 0 1 noreply\r\nx\r\nfrobnicate\r\nincr a 1\r\nversion\r\n");
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("CLIENT_ERROR"), "{text}");
        assert!(text.contains("NOT_FOUND"), "{text}"); // 'x' is not numeric
        assert!(text.ends_with("VERSION fleec-0.1.0\r\n"), "{text}");
    }

    #[test]
    fn barriers_execute_inline_and_in_order() {
        let out = run_pipeline(b"set f 0 0 1\r\nx\r\nget f\r\nflush_all\r\nget f\r\n");
        assert_eq!(
            out,
            b"STORED\r\nVALUE f 0 1\r\nx\r\nEND\r\nOK\r\nEND\r\n" as &[u8],
            "got {:?}",
            String::from_utf8_lossy(&out)
        );
    }

    #[test]
    fn quit_stops_consuming_and_reports() {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let mut arena = BatchArena::default();
        let mut out = Vec::new();
        let wire = b"version\r\nquit\r\nget never-parsed\r\n";
        let d = drain(cache.as_ref(), 0, wire, &mut out, &mut arena, usize::MAX, None, None);
        assert_eq!(d.stop, DrainStop::Quit);
        assert_eq!(out, b"VERSION fleec-0.1.0\r\n");
        // Everything through the quit line is consumed; the rest is not.
        assert_eq!(&wire[d.consumed..], b"get never-parsed\r\n");
    }

    #[test]
    fn budget_pauses_between_rounds_without_losing_replies() {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let mut arena = BatchArena::default();
        // 1 KiB values; a tiny budget must stop the pump long before the
        // whole pipeline executes.
        let val = vec![b'v'; 1024];
        let mut wire = Vec::new();
        let n_cmds = 4 * ROUND_OPS;
        for i in 0..n_cmds {
            wire.extend_from_slice(format!("set bp{i} 0 0 {}\r\n", val.len()).as_bytes());
            wire.extend_from_slice(&val);
            wire.extend_from_slice(b"\r\n");
        }
        for i in 0..n_cmds {
            wire.extend_from_slice(format!("get bp{i}\r\n").as_bytes());
        }
        let budget = 4 * 1024;
        let mut out = Vec::new();
        let mut consumed = 0;
        let mut calls = 0;
        let mut replies = Vec::new();
        loop {
            let d = drain(
                cache.as_ref(),
                0,
                &wire[consumed..],
                &mut out,
                &mut arena,
                budget,
                None,
                None,
            );
            consumed += d.consumed;
            calls += 1;
            // Overshoot past the budget is bounded by one round's replies.
            assert!(
                out.len() <= budget + ROUND_OPS * (val.len() + 64),
                "out grew to {} against budget {budget}",
                out.len()
            );
            replies.extend_from_slice(&out);
            out.clear(); // the "socket" drained
            match d.stop {
                DrainStop::Budget => continue,
                DrainStop::NeedMoreInput => break,
                DrainStop::Quit => unreachable!(),
            }
        }
        assert_eq!(consumed, wire.len());
        assert!(calls > 2, "budget never paused the pump ({calls} calls)");
        let text = String::from_utf8_lossy(&replies);
        assert_eq!(text.matches("STORED\r\n").count(), n_cmds);
        assert_eq!(text.matches("VALUE ").count(), n_cmds);
    }

    #[test]
    fn arena_allocates_only_on_first_use() {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let mut arena = BatchArena::default();
        // Multi-key get included so the parse key scratch is exercised.
        let wire = b"set k 0 0 1\r\nv\r\nget k k k\r\nget k\r\n";
        let mut out = Vec::new();
        drain(cache.as_ref(), 0, wire, &mut out, &mut arena, usize::MAX, None, None);
        let (cap_ops, cap_actions, cap_keys, cap_pending) = (
            arena.ops.capacity(),
            arena.actions.capacity(),
            arena.keys.capacity(),
            arena.pending.capacity(),
        );
        assert!(cap_ops >= 2 && cap_actions >= 2, "arena warmed");
        assert!(cap_keys >= 3, "key scratch warmed by the multi-key get");
        assert!(cap_pending >= 2, "emitter park slots warmed");
        // A same-shape drain must not grow (or shrink) any arena.
        for _ in 0..8 {
            out.clear();
            drain(cache.as_ref(), 0, wire, &mut out, &mut arena, usize::MAX, None, None);
            assert_eq!(arena.ops.capacity(), cap_ops);
            assert_eq!(arena.actions.capacity(), cap_actions);
            assert_eq!(arena.keys.capacity(), cap_keys, "key scratch recycled");
            assert_eq!(arena.pending.capacity(), cap_pending, "park slots recycled");
        }
        // A bare engine delivers in order: the value-byte spill buffer
        // must never have engaged (its capacity is still zero), i.e.
        // every hit streamed slab→outbuf without an intermediate copy.
        assert_eq!(
            arena.spill.capacity(),
            0,
            "in-order delivery must never copy into the spill buffer"
        );
        assert_eq!(
            out,
            b"STORED\r\nVALUE k 0 1\r\nv\r\nVALUE k 0 1\r\nv\r\nVALUE k 0 1\r\nv\r\nEND\r\nVALUE k 0 1\r\nv\r\nEND\r\n"
                as &[u8],
            "recycled arenas must not corrupt replies"
        );
    }

    #[test]
    fn sharded_cache_replies_come_back_in_command_order() {
        // A 4-shard router delivers results shard-grouped; the emitter
        // must still put wire replies in command order, byte-identical
        // to what a flat engine would produce (plain `get`s only — cas
        // token *values* are per-shard).
        let cache = crate::cache::build_sharded("fleec", 4, CacheConfig::small()).unwrap();
        let mut arena = BatchArena::default();
        let n = 12usize;
        let mut wire = Vec::new();
        for i in 0..n {
            wire.extend_from_slice(format!("set sh{i} 7 0 3\r\nv{i:02}\r\n").as_bytes());
        }
        wire.extend_from_slice(b"get");
        for i in 0..n {
            wire.extend_from_slice(format!(" sh{i}").as_bytes());
        }
        wire.extend_from_slice(b"\r\n");
        wire.extend_from_slice(b"delete sh3\r\nincr sh5 1\r\nget sh3 sh4\r\nversion\r\n");
        let mut out = Vec::new();
        let mut consumed = 0;
        loop {
            let d = drain(
                cache.as_ref(),
                0,
                &wire[consumed..],
                &mut out,
                &mut arena,
                usize::MAX,
                None,
                None,
            );
            consumed += d.consumed;
            if d.stop == DrainStop::NeedMoreInput {
                break;
            }
        }
        assert_eq!(consumed, wire.len());
        let mut expect = Vec::new();
        for _ in 0..n {
            expect.extend_from_slice(b"STORED\r\n");
        }
        for i in 0..n {
            expect.extend_from_slice(format!("VALUE sh{i} 7 3\r\nv{i:02}\r\n").as_bytes());
        }
        expect.extend_from_slice(b"END\r\n");
        expect.extend_from_slice(b"DELETED\r\nNOT_FOUND\r\n"); // v05 is not numeric
        expect.extend_from_slice(b"VALUE sh4 7 3\r\nv04\r\nEND\r\n"); // sh3 deleted
        expect.extend_from_slice(b"VERSION fleec-0.1.0\r\n");
        assert_eq!(
            out,
            expect,
            "got {:?}, want {:?}",
            String::from_utf8_lossy(&out),
            String::from_utf8_lossy(&expect)
        );
    }

    #[test]
    fn oversized_multiget_is_rejected_and_keeps_stream_position() {
        let mut wire = b"set mk 0 0 1\r\nv\r\n".to_vec();
        // Exactly at the limit: served normally.
        wire.extend_from_slice(b"get");
        for _ in 0..MAX_GET_KEYS {
            wire.extend_from_slice(b" mk");
        }
        wire.extend_from_slice(b"\r\n");
        // One past the limit: CLIENT_ERROR, but later commands still run.
        wire.extend_from_slice(b"get");
        for _ in 0..=MAX_GET_KEYS {
            wire.extend_from_slice(b" mk");
        }
        wire.extend_from_slice(b"\r\nget mk\r\n");
        let out = run_pipeline(&wire);
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("STORED\r\nVALUE mk 0 1\r\nv\r\n"), "{text}");
        assert_eq!(
            text.matches("VALUE mk 0 1\r\n").count(),
            MAX_GET_KEYS + 1,
            "at-limit get serves every key, over-limit get serves none: {text}"
        );
        assert!(text.contains("CLIENT_ERROR too many keys in get\r\n"), "{text}");
        assert!(text.ends_with("VALUE mk 0 1\r\nv\r\nEND\r\n"), "{text}");
    }

    #[test]
    fn result_mismatch_flags_fatal_and_keeps_framing() {
        // A contract-violating engine answers a `set` with the wrong
        // result variant: the pump must emit a framed SERVER_ERROR *and*
        // flag the stream fatal — the front-ends close the connection on
        // that flag (a desynced stream would answer command N+1's reply
        // to command N forever).
        let cache = crate::testutil::MismatchCache;
        let mut arena = BatchArena::default();
        let mut out = Vec::new();
        let d = drain(
            &cache,
            0,
            b"set m 0 0 1\r\nx\r\n",
            &mut out,
            &mut arena,
            usize::MAX,
            None,
            None,
        );
        assert!(d.fatal, "mismatch must flag the stream fatal");
        assert_eq!(d.stop, DrainStop::NeedMoreInput);
        assert_eq!(out, b"SERVER_ERROR batch result mismatch\r\n");
        // A healthy engine never trips the flag.
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        out.clear();
        let d = drain(
            cache.as_ref(),
            0,
            b"set m 0 0 1\r\nx\r\n",
            &mut out,
            &mut arena,
            usize::MAX,
            None,
            None,
        );
        assert!(!d.fatal);
        assert_eq!(out, b"STORED\r\n");
    }

    #[test]
    fn owned_oracle_reports_mismatch_fatal_identically() {
        use crate::cache::OpResult;
        let ops = vec![Op::Set {
            key: b"m",
            value: b"x",
            flags: 0,
            exptime: 0,
        }];
        let actions = vec![Action::Store {
            first: 0,
            noreply: false,
        }];
        let mut out = Vec::new();
        let fatal = emit(&ops, &actions, &[OpResult::Touched(true)], &mut out);
        assert!(fatal, "oracle must report the mismatch as fatal");
        assert_eq!(out, b"SERVER_ERROR batch result mismatch\r\n");
        out.clear();
        let fatal = emit(
            &ops,
            &actions,
            &[OpResult::Store(crate::cache::StoreOutcome::Stored)],
            &mut out,
        );
        assert!(!fatal);
        assert_eq!(out, b"STORED\r\n");
    }

    #[test]
    fn barrier_classification() {
        assert!(is_barrier(&Command::Stats { sub: StatsSub::All }));
        assert!(is_barrier(&Command::FlushAll { noreply: false }));
        assert!(is_barrier(&Command::Quit));
        assert!(!is_barrier(&Command::Version));
        assert!(!is_barrier(&Command::Get {
            keys: vec![b"k" as &[u8]],
            with_cas: false
        }));
    }
}
