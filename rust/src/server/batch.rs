//! Batched request planning: lossless `proto::Command` → [`Op`]
//! translation plus the reply plan that renders batch results back into
//! wire bytes.
//!
//! The server drains every complete command out of a read buffer into one
//! flat `Vec<Op>` (a multi-key `get` fans out into one `Op::Get` per key)
//! and a parallel [`Action`] list that remembers how to reply — which ops
//! belong to which command, `noreply` suppression, `gets` CAS rendering.
//! The whole batch then crosses the engine in a single
//! [`crate::cache::Cache::execute_batch`] call, and [`emit`] renders the
//! results **byte-identically** to the old one-dispatch-per-command path.
//!
//! Two commands cannot ride in a batch: `stats` (reads the very counters
//! the pending ops are about to bump) and `flush_all` (clobbers state the
//! pending ops must see first). Those are *barriers* — the server
//! executes the pending batch, handles them inline, and starts a new
//! batch — so pipelines containing them still observe sequential
//! semantics. `quit` is a barrier too (pending replies must flush before
//! the connection closes).

use crate::cache::{Cache, Op, OpResult};
use crate::proto::{self, Command, StoreKind};

/// Reply plan for one parsed command: where its ops landed in the batch
/// and how to render their results.
#[derive(Debug)]
pub enum Action<'a> {
    /// `get`/`gets`: `keys.len()` consecutive `Op::Get`s from `first`.
    Values {
        keys: Vec<&'a [u8]>,
        with_cas: bool,
        first: usize,
    },
    /// Any of the six storage commands: one op at `first`.
    Store { first: usize, noreply: bool },
    /// `delete`: one op at `first`.
    Delete { first: usize, noreply: bool },
    /// `incr`/`decr`: one op at `first`.
    Counter { first: usize, noreply: bool },
    /// `touch`: one op at `first`.
    Touch { first: usize, noreply: bool },
    /// `version`: constant reply, no engine op.
    Version,
    /// `verbosity`: constant `OK`, no engine op.
    Ok { noreply: bool },
    /// Parse failure: `CLIENT_ERROR <msg>`, no engine op.
    ClientError(&'static str),
}

/// Render the `stats` barrier's reply. Goes through [`Cache::stats`], the
/// one coherent snapshot an engine can assemble however it likes — a
/// sharded router merges all its shards here (counters and `curr_items`
/// sum, per-shard `mem_limit`s add back up to the configured total), so
/// `limit_maxbytes` over a sharded server stays truthful.
pub fn write_stats_reply(cache: &dyn Cache, curr_connections: usize, out: &mut Vec<u8>) {
    let stats = cache.stats();
    proto::write_stats(out, cache.engine_name(), &stats, curr_connections);
}

/// Whether `cmd` must not share a batch with the ops queued before it
/// (see the module docs). The caller executes the pending batch first and
/// then handles the command inline.
pub fn is_barrier(cmd: &Command<'_>) -> bool {
    matches!(
        cmd,
        Command::Stats | Command::FlushAll { .. } | Command::Quit
    )
}

/// Append the data ops backing `cmd` to `ops` and its reply plan to
/// `actions`. Lossless: every field of the parsed command survives into
/// either the op or the action. Barrier commands (see [`is_barrier`]) are
/// the caller's job and not accepted here.
pub fn plan<'a>(cmd: Command<'a>, ops: &mut Vec<Op<'a>>, actions: &mut Vec<Action<'a>>) {
    match cmd {
        Command::Get { keys, with_cas } => {
            let first = ops.len();
            for &key in &keys {
                ops.push(Op::Get { key });
            }
            actions.push(Action::Values {
                keys,
                with_cas,
                first,
            });
        }
        Command::Store {
            kind,
            key,
            flags,
            exptime,
            data,
            cas,
            noreply,
        } => {
            let first = ops.len();
            ops.push(match kind {
                StoreKind::Set => Op::Set {
                    key,
                    value: data,
                    flags,
                    exptime,
                },
                StoreKind::Add => Op::Add {
                    key,
                    value: data,
                    flags,
                    exptime,
                },
                StoreKind::Replace => Op::Replace {
                    key,
                    value: data,
                    flags,
                    exptime,
                },
                StoreKind::Append => Op::Append { key, suffix: data },
                StoreKind::Prepend => Op::Prepend { key, prefix: data },
                StoreKind::Cas => Op::CasOp {
                    key,
                    value: data,
                    flags,
                    exptime,
                    cas,
                },
            });
            actions.push(Action::Store { first, noreply });
        }
        Command::Delete { key, noreply } => {
            let first = ops.len();
            ops.push(Op::Delete { key });
            actions.push(Action::Delete { first, noreply });
        }
        Command::Incr { key, delta, noreply } => {
            let first = ops.len();
            ops.push(Op::Incr { key, delta });
            actions.push(Action::Counter { first, noreply });
        }
        Command::Decr { key, delta, noreply } => {
            let first = ops.len();
            ops.push(Op::Decr { key, delta });
            actions.push(Action::Counter { first, noreply });
        }
        Command::Touch { key, exptime, noreply } => {
            let first = ops.len();
            ops.push(Op::Touch { key, exptime });
            actions.push(Action::Touch { first, noreply });
        }
        Command::Version => actions.push(Action::Version),
        Command::Verbosity { noreply } => actions.push(Action::Ok { noreply }),
        Command::Stats | Command::FlushAll { .. } | Command::Quit => {
            unreachable!("barrier commands are handled by the caller")
        }
    }
}

/// Render replies for `actions` against the batch `results`, appending
/// wire bytes to `out` in command order.
pub fn emit(actions: &[Action<'_>], results: &[OpResult], out: &mut Vec<u8>) {
    for action in actions {
        match action {
            Action::Values {
                keys,
                with_cas,
                first,
            } => {
                for (i, key) in keys.iter().enumerate() {
                    if let OpResult::Value(Some(r)) = &results[first + i] {
                        proto::write_value(out, key, r.flags, &r.data, with_cas.then_some(r.cas));
                    }
                }
                proto::write_end(out);
            }
            Action::Store { first, noreply } => {
                if !noreply {
                    match results[*first] {
                        OpResult::Store(outcome) => {
                            out.extend_from_slice(proto::store_reply(outcome))
                        }
                        _ => mismatch(out),
                    }
                }
            }
            Action::Delete { first, noreply } => {
                if !noreply {
                    match results[*first] {
                        OpResult::Deleted(true) => out.extend_from_slice(b"DELETED\r\n"),
                        OpResult::Deleted(false) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out),
                    }
                }
            }
            Action::Counter { first, noreply } => {
                if !noreply {
                    match results[*first] {
                        OpResult::Counter(Some(v)) => {
                            out.extend_from_slice(v.to_string().as_bytes());
                            out.extend_from_slice(b"\r\n");
                        }
                        OpResult::Counter(None) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out),
                    }
                }
            }
            Action::Touch { first, noreply } => {
                if !noreply {
                    match results[*first] {
                        OpResult::Touched(true) => out.extend_from_slice(b"TOUCHED\r\n"),
                        OpResult::Touched(false) => out.extend_from_slice(b"NOT_FOUND\r\n"),
                        _ => mismatch(out),
                    }
                }
            }
            Action::Version => out.extend_from_slice(b"VERSION fleec-0.1.0\r\n"),
            Action::Ok { noreply } => {
                if !noreply {
                    out.extend_from_slice(b"OK\r\n");
                }
            }
            Action::ClientError(msg) => {
                out.extend_from_slice(b"CLIENT_ERROR ");
                out.extend_from_slice(msg.as_bytes());
                out.extend_from_slice(b"\r\n");
            }
        }
    }
}

/// An engine returned a result variant that doesn't match the op — a
/// `Cache::execute_batch` contract violation. Keep the wire stream framed
/// rather than hanging the client.
fn mismatch(out: &mut Vec<u8>) {
    debug_assert!(false, "execute_batch result variant mismatch");
    out.extend_from_slice(b"SERVER_ERROR batch result mismatch\r\n");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};
    use crate::proto::Parsed;

    /// Parse a full pipelined buffer, batch it, execute it, emit replies.
    fn run_pipeline(wire: &[u8]) -> Vec<u8> {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let mut ops = Vec::new();
        let mut actions = Vec::new();
        let mut consumed = 0;
        while consumed < wire.len() {
            match crate::proto::parse(&wire[consumed..]) {
                Parsed::Done(cmd, n) => {
                    consumed += n;
                    assert!(!is_barrier(&cmd), "test pipeline must be barrier-free");
                    plan(cmd, &mut ops, &mut actions);
                }
                Parsed::Error(msg, n) => {
                    consumed += n;
                    actions.push(Action::ClientError(msg));
                }
                Parsed::Incomplete => panic!("truncated test pipeline"),
            }
        }
        let results = cache.execute_batch(&ops);
        let mut out = Vec::new();
        emit(&actions, &results, &mut out);
        out
    }

    #[test]
    fn pipeline_replies_match_per_command_bytes() {
        let out = run_pipeline(
            b"set a 7 0 3\r\nfoo\r\nget a\r\nadd a 0 0 1\r\nx\r\ndelete a\r\ndelete a\r\nget a\r\n",
        );
        assert_eq!(
            out,
            b"STORED\r\nVALUE a 7 3\r\nfoo\r\nEND\r\nNOT_STORED\r\nDELETED\r\nNOT_FOUND\r\nEND\r\n"
                as &[u8],
            "got {:?}",
            String::from_utf8_lossy(&out)
        );
    }

    #[test]
    fn multikey_get_fans_out_and_reassembles() {
        let out = run_pipeline(b"set a 0 0 1\r\n1\r\nset c 0 0 1\r\n3\r\nget a b c\r\n");
        assert_eq!(
            out,
            b"STORED\r\nSTORED\r\nVALUE a 0 1\r\n1\r\nVALUE c 0 1\r\n3\r\nEND\r\n" as &[u8],
            "got {:?}",
            String::from_utf8_lossy(&out)
        );
    }

    #[test]
    fn noreply_and_errors_keep_stream_position() {
        let out = run_pipeline(b"set a 0 0 1 noreply\r\nx\r\nfrobnicate\r\nincr a 1\r\nversion\r\n");
        let text = String::from_utf8_lossy(&out);
        assert!(text.starts_with("CLIENT_ERROR"), "{text}");
        assert!(text.contains("NOT_FOUND"), "{text}"); // 'x' is not numeric
        assert!(text.ends_with("VERSION fleec-0.1.0\r\n"), "{text}");
    }

    #[test]
    fn barrier_classification() {
        assert!(is_barrier(&Command::Stats));
        assert!(is_barrier(&Command::FlushAll { noreply: false }));
        assert!(is_barrier(&Command::Quit));
        assert!(!is_barrier(&Command::Version));
        assert!(!is_barrier(&Command::Get {
            keys: vec![b"k" as &[u8]],
            with_cas: false
        }));
    }
}
