//! OS readiness poller — a thin `cfg(unix)` wrapper over `epoll` (Linux)
//! or `poll` (other Unixes), declared through a direct `extern "C"` shim.
//!
//! The offline crate set has no `mio`/`tokio`, and the reactor needs only
//! the smallest possible surface: register a file descriptor with a
//! `usize` token and a read/write [`Interest`], block until something is
//! ready, get back `(token, readable, writable)` [`Event`]s. Both
//! backends are **level-triggered**: a fd that stays readable/writable
//! keeps reporting, so the reactor never has to drain a socket to
//! exhaustion in one wakeup to stay correct — interest re-arming is a
//! pure optimization, not a correctness requirement.
//!
//! Error/hangup conditions (`EPOLLERR`/`EPOLLHUP`/`POLLERR`/`POLLHUP`)
//! are folded into *both* readability and writability: the connection
//! state machine discovers the actual failure from the `read`/`write`
//! syscall (`0`/`EPIPE`/`ECONNRESET`) and tears the connection down,
//! which keeps the poller free of any connection-lifecycle knowledge.

use std::time::Duration;

/// Which readiness classes a registration wants to be woken for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

impl Interest {
    /// Read-only interest (the initial state of every connection).
    pub const READ: Interest = Interest {
        read: true,
        write: false,
    };
}

/// One readiness report from [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// The token the fd was registered with.
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
}

/// Clamp an optional timeout to the `int` milliseconds the syscalls take
/// (`None` = block forever = `-1`).
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => d.as_millis().min(i32::MAX as u128) as i32,
    }
}

pub use sys::Poller;

/// Linux backend: one `epoll` instance per poller. O(ready) wakeups and
/// kernel-side interest storage — the production path for the reactor.
#[cfg(target_os = "linux")]
mod sys {
    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    use super::{Event, Interest};

    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    /// `struct epoll_event`. The kernel packs it on x86 so the 64-bit
    /// `data` field sits at offset 4.
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = 0;
        if interest.read {
            m |= EPOLLIN | EPOLLRDHUP;
        }
        if interest.write {
            m |= EPOLLOUT;
        }
        m
    }

    pub struct Poller {
        epfd: c_int,
        /// Kernel-filled scratch; capacity caps events per wakeup, not
        /// registrations (level triggering re-reports the overflow).
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            // SAFETY: FFI call with no pointer arguments; the kernel
            // validates the flags and reports failure via the return.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 1024],
            })
        }

        fn ctl(&self, op: c_int, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: mask(interest),
                data: token as u64,
            };
            let evp = if op == EPOLL_CTL_DEL {
                std::ptr::null_mut()
            } else {
                &mut ev as *mut EpollEvent
            };
            // SAFETY: `evp` is either null (DEL, where the kernel ignores
            // it) or points at `ev`, which outlives the call; the kernel
            // validates `epfd`/`op`/`fd`.
            if unsafe { epoll_ctl(self.epfd, op, fd, evp) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest::READ)
        }

        /// Block until readiness or timeout; fills `out` (cleared first).
        /// A signal interruption reports as zero events, not an error.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            // SAFETY: `buf` is a live Vec whose length bounds how many
            // events the kernel may write; `&mut self` keeps it exclusive
            // for the duration of the call.
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as c_int,
                    super::timeout_ms(timeout),
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for i in 0..n as usize {
                let raw = self.buf[i];
                let events = raw.events;
                out.push(Event {
                    token: raw.data as usize,
                    readable: events & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                    writable: events & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: `epfd` was returned by `epoll_create1` and is owned
            // exclusively by this Poller; closing it at most once.
            unsafe {
                close(self.epfd);
            }
        }
    }
}

/// Portable Unix backend: `poll(2)` over a userspace registration table.
/// O(registrations) per wakeup — fine for the per-reactor connection
/// counts this front-end targets on non-Linux hosts.
#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use std::io;
    use std::os::raw::{c_int, c_short, c_uint};
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    use super::{Event, Interest};

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;
    const POLLNVAL: c_short = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_uint, timeout: c_int) -> c_int;
    }

    pub struct Poller {
        entries: Vec<(RawFd, usize, Interest)>,
        fds: Vec<PollFd>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller {
                entries: Vec::new(),
                fds: Vec::new(),
            })
        }

        pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.entries.push((fd, token, interest));
            Ok(())
        }

        pub fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            for e in self.entries.iter_mut() {
                if e.0 == fd {
                    *e = (fd, token, interest);
                    return Ok(());
                }
            }
            Err(io::Error::new(
                io::ErrorKind::NotFound,
                "modify of unregistered fd",
            ))
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.entries.retain(|e| e.0 != fd);
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            out.clear();
            self.fds.clear();
            for &(fd, _, interest) in &self.entries {
                let mut events = 0;
                if interest.read {
                    events |= POLLIN;
                }
                if interest.write {
                    events |= POLLOUT;
                }
                self.fds.push(PollFd {
                    fd,
                    events,
                    revents: 0,
                });
            }
            // SAFETY: `fds` is a live Vec sized to the registration table;
            // `&mut self` keeps it exclusive while the kernel fills
            // `revents`.
            let n = unsafe {
                poll(
                    self.fds.as_mut_ptr(),
                    self.fds.len() as c_uint,
                    super::timeout_ms(timeout),
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (i, pfd) in self.fds.iter().enumerate() {
                let r = pfd.revents;
                if r == 0 {
                    continue;
                }
                out.push(Event {
                    token: self.entries[i].1,
                    readable: r & (POLLIN | POLLHUP | POLLERR | POLLNVAL) != 0,
                    writable: r & (POLLOUT | POLLHUP | POLLERR | POLLNVAL) != 0,
                });
            }
            Ok(())
        }
    }
}
