//! TCP server: the Memcached-compatible serving front-end.
//!
//! Thread-per-connection over `std::net` — the same threading model as
//! Memcached itself (one worker per connection via libevent there, native
//! threads here; the offline crate set has no async runtime, and the
//! paper's contention story lives in the *shared data structures*, which
//! every connection thread hits concurrently).
//!
//! The server is engine-agnostic: any [`Cache`] implementation plugs in,
//! so `fleec serve --engine memcached|memclock|fleec` serves identical
//! wire behavior with different concurrency cores.

pub mod batch;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::cache::{Cache, Op};
use crate::proto::{self, Command, Parsed};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: SocketAddr,
    /// Disable Nagle on accepted sockets (latency experiments need it).
    pub nodelay: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:11211".parse().unwrap(),
            nodelay: true,
        }
    }
}

/// A running server; dropping it (or calling [`Server::shutdown`]) stops
/// the accept loop and joins every connection thread.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    active_conns: Arc<AtomicUsize>,
}

impl Server {
    /// Bind and start serving `cache` in background threads.
    pub fn start(config: ServerConfig, cache: Arc<dyn Cache>) -> std::io::Result<Server> {
        let listener = TcpListener::bind(config.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let active_conns = Arc::new(AtomicUsize::new(0));
        let accept_stop = Arc::clone(&stop);
        let accept_active = Arc::clone(&active_conns);
        let nodelay = config.nodelay;
        let accept_thread = std::thread::Builder::new()
            .name("fleec-accept".into())
            .spawn(move || {
                let mut conn_threads = Vec::new();
                while !accept_stop.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let _ = stream.set_nodelay(nodelay);
                            let _ = stream.set_nonblocking(false);
                            let cache = Arc::clone(&cache);
                            let stop = Arc::clone(&accept_stop);
                            let active = Arc::clone(&accept_active);
                            active.fetch_add(1, Ordering::AcqRel);
                            conn_threads.push(
                                std::thread::Builder::new()
                                    .name("fleec-conn".into())
                                    .spawn(move || {
                                        let _ = handle_connection(
                                            stream,
                                            cache,
                                            stop,
                                            Arc::clone(&active),
                                        );
                                        active.fetch_sub(1, Ordering::AcqRel);
                                    })
                                    .expect("spawn connection thread"),
                            );
                            // Opportunistically reap finished threads.
                            conn_threads.retain(|h| !h.is_finished());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                for h in conn_threads {
                    let _ = h.join();
                }
            })?;
        Ok(Server {
            addr,
            stop,
            accept_thread: Some(accept_thread),
            active_conns,
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of currently-open connections.
    pub fn active_connections(&self) -> usize {
        self.active_conns.load(Ordering::Acquire)
    }

    /// Stop accepting, close the loop, join threads.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Read-plan-execute loop for one connection.
///
/// Each wakeup drains **all** complete commands from the read buffer into
/// one flat `Vec<Op>` + reply plan (see [`batch`]) and crosses the engine
/// with a single [`Cache::execute_batch`] call — pipelined clients pay
/// one engine crossing per read instead of one per command. `stats`,
/// `flush_all` and `quit` are barriers: the pending batch executes first,
/// then the barrier runs inline, preserving sequential semantics.
fn handle_connection(
    mut stream: TcpStream,
    cache: Arc<dyn Cache>,
    stop: Arc<AtomicBool>,
    active_conns: Arc<AtomicUsize>,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut inbuf: Vec<u8> = Vec::with_capacity(16 * 1024);
    let mut outbuf: Vec<u8> = Vec::with_capacity(16 * 1024);
    let mut chunk = [0u8; 16 * 1024];
    'conn: loop {
        if stop.load(Ordering::Acquire) {
            return Ok(());
        }
        // Plan + execute everything currently buffered.
        let mut consumed_total = 0;
        let mut quit = false;
        {
            let mut ops: Vec<Op<'_>> = Vec::new();
            let mut actions: Vec<batch::Action<'_>> = Vec::new();
            loop {
                match proto::parse(&inbuf[consumed_total..]) {
                    Parsed::Done(cmd, n) => {
                        consumed_total += n;
                        if batch::is_barrier(&cmd) {
                            flush_batch(cache.as_ref(), &mut ops, &mut actions, &mut outbuf);
                            match cmd {
                                Command::Stats => {
                                    batch::write_stats_reply(
                                        cache.as_ref(),
                                        active_conns.load(Ordering::Acquire),
                                        &mut outbuf,
                                    );
                                }
                                Command::FlushAll { noreply } => {
                                    cache.flush_all();
                                    if !noreply {
                                        outbuf.extend_from_slice(b"OK\r\n");
                                    }
                                }
                                Command::Quit => {
                                    quit = true;
                                    break;
                                }
                                _ => unreachable!("is_barrier covers exactly these"),
                            }
                        } else {
                            batch::plan(cmd, &mut ops, &mut actions);
                        }
                    }
                    Parsed::Error(msg, n) => {
                        consumed_total += n;
                        actions.push(batch::Action::ClientError(msg));
                    }
                    Parsed::Incomplete => break,
                }
            }
            // The whole read crosses the engine once (barrier-free case).
            flush_batch(cache.as_ref(), &mut ops, &mut actions, &mut outbuf);
        }
        if consumed_total > 0 {
            inbuf.drain(..consumed_total);
        }
        if !outbuf.is_empty() {
            stream.write_all(&outbuf)?;
            outbuf.clear();
        }
        if quit {
            return Ok(());
        }
        // Refill.
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(()), // peer closed
            Ok(n) => inbuf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue 'conn;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Execute the pending batch and render its replies; clears both lists.
fn flush_batch<'a>(
    cache: &dyn Cache,
    ops: &mut Vec<Op<'a>>,
    actions: &mut Vec<batch::Action<'a>>,
    out: &mut Vec<u8>,
) {
    if actions.is_empty() && ops.is_empty() {
        return;
    }
    let results = cache.execute_batch(ops);
    batch::emit(actions, &results, out);
    ops.clear();
    actions.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};

    fn start_test_server() -> (Server, SocketAddr) {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                nodelay: true,
            },
            cache,
        )
        .unwrap();
        let addr = server.addr();
        (server, addr)
    }

    fn roundtrip(stream: &mut TcpStream, send: &[u8], expect: &[u8]) {
        stream.write_all(send).unwrap();
        let mut got = vec![0u8; expect.len()];
        stream.read_exact(&mut got).unwrap();
        assert_eq!(
            got,
            expect,
            "sent {:?}, expected {:?}, got {:?}",
            String::from_utf8_lossy(send),
            String::from_utf8_lossy(expect),
            String::from_utf8_lossy(&got)
        );
    }

    #[test]
    fn wire_level_session() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        roundtrip(&mut s, b"set foo 7 0 3\r\nbar\r\n", b"STORED\r\n");
        roundtrip(&mut s, b"get foo\r\n", b"VALUE foo 7 3\r\nbar\r\nEND\r\n");
        roundtrip(&mut s, b"get nope\r\n", b"END\r\n");
        roundtrip(&mut s, b"add foo 0 0 1\r\nx\r\n", b"NOT_STORED\r\n");
        roundtrip(&mut s, b"append foo 0 0 3\r\nbaz\r\n", b"STORED\r\n");
        roundtrip(&mut s, b"get foo\r\n", b"VALUE foo 7 6\r\nbarbaz\r\nEND\r\n");
        roundtrip(&mut s, b"delete foo\r\n", b"DELETED\r\n");
        roundtrip(&mut s, b"delete foo\r\n", b"NOT_FOUND\r\n");
        roundtrip(&mut s, b"set n 0 0 1\r\n5\r\n", b"STORED\r\n");
        roundtrip(&mut s, b"incr n 10\r\n", b"15\r\n");
        roundtrip(&mut s, b"decr n 20\r\n", b"0\r\n");
        roundtrip(&mut s, b"version\r\n", b"VERSION fleec-0.1.0\r\n");
        s.write_all(b"quit\r\n").unwrap();
    }

    #[test]
    fn noreply_suppresses_responses() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        // Two noreply sets then a get: the first bytes back must be VALUE.
        s.write_all(b"set a 0 0 1 noreply\r\nx\r\nset b 0 0 1 noreply\r\ny\r\nget b\r\n")
            .unwrap();
        let mut buf = [0u8; 64];
        let n = s.read(&mut buf).unwrap();
        assert!(
            buf[..n].starts_with(b"VALUE b 0 1\r\ny\r\nEND\r\n"),
            "got {:?}",
            String::from_utf8_lossy(&buf[..n])
        );
    }

    #[test]
    fn pipelined_commands_in_one_write() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"set p 0 0 2\r\nhi\r\nget p\r\nstats\r\n").unwrap();
        let mut acc = Vec::new();
        let mut buf = [0u8; 4096];
        while !acc.windows(5).any(|w| w == b"END\r\n")
            || String::from_utf8_lossy(&acc).matches("END\r\n").count() < 2
        {
            let n = s.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            acc.extend_from_slice(&buf[..n]);
        }
        let text = String::from_utf8_lossy(&acc);
        assert!(text.starts_with("STORED\r\nVALUE p 0 2\r\nhi\r\nEND\r\n"), "{text}");
        assert!(text.contains("STAT engine fleec"), "{text}");
    }

    #[test]
    fn stats_barrier_sees_preceding_pipelined_ops() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        // set + get + stats in ONE write: the stats barrier must execute
        // after the batched ops so the counters include them.
        s.write_all(b"set sb 0 0 1\r\nv\r\nget sb\r\nstats\r\n").unwrap();
        let mut acc = Vec::new();
        let mut buf = [0u8; 4096];
        while String::from_utf8_lossy(&acc).matches("END\r\n").count() < 2 {
            let n = s.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            acc.extend_from_slice(&buf[..n]);
        }
        let text = String::from_utf8_lossy(&acc);
        assert!(text.starts_with("STORED\r\nVALUE sb 0 1\r\nv\r\nEND\r\n"), "{text}");
        assert!(text.contains("STAT cmd_get 1\r\n"), "{text}");
        assert!(text.contains("STAT cmd_set 1\r\n"), "{text}");
        assert!(text.contains("STAT curr_connections 1\r\n"), "{text}");
    }

    #[test]
    fn flush_all_barrier_orders_with_batched_ops() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        // The get before the flush must hit; the get after must miss —
        // even though all five commands arrive in one read.
        roundtrip(
            &mut s,
            b"set f 0 0 1\r\nx\r\nget f\r\nflush_all\r\nget f\r\n",
            b"STORED\r\nVALUE f 0 1\r\nx\r\nEND\r\nOK\r\nEND\r\n",
        );
    }

    #[test]
    fn malformed_command_gets_client_error() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"frobnicate\r\nversion\r\n").unwrap();
        let mut buf = [0u8; 256];
        let mut acc = Vec::new();
        while !acc.windows(2).any(|w| w == b"\r\n") || acc.len() < 20 {
            let n = s.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            acc.extend_from_slice(&buf[..n]);
        }
        let text = String::from_utf8_lossy(&acc);
        assert!(text.starts_with("CLIENT_ERROR"), "{text}");
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let (mut server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        roundtrip(&mut s, b"set x 0 0 1\r\nv\r\n", b"STORED\r\n");
        server.shutdown();
        // Post-shutdown connects must fail or be reset quickly.
        std::thread::sleep(Duration::from_millis(50));
    }
}
