//! TCP server: the Memcached-compatible serving front-end.
//!
//! Two front-end models serve the same wire protocol through the same
//! protocol pump ([`batch::drain`] — parse → plan → one
//! [`Cache::execute_batch`] crossing per round → reply bytes):
//!
//! * [`ServerModel::Reactor`] (default on Unix for `fleec serve`):
//!   N event-loop threads ([`reactor`]), each multiplexing non-blocking
//!   connections over an OS readiness poller ([`poller`]) with
//!   per-connection state machines, partial-write handling and bounded
//!   reply buffering. This is the front-end that scales connection count
//!   to what the lock-free core can absorb.
//! * [`ServerModel::Thread`]: one native thread per connection over
//!   blocking `std::net` — the portable fallback, and the simple oracle
//!   the reactor is differentially tested against
//!   (`rust/tests/reactor_e2e.rs`).
//!
//! The server is engine-agnostic: any [`Cache`] implementation plugs in,
//! so `fleec serve --engine memcached|memclock|fleec` serves identical
//! wire behavior with different concurrency cores.

pub mod batch;
#[cfg(unix)]
pub mod poller;
#[cfg(unix)]
mod reactor;

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::cache::Cache;
use crate::metrics::{LatencyHistogram, ShardedCounter};
use crate::proto;

/// Serving-plane observability state, shared by every front-end thread.
/// All counters are stats-grade striped/relaxed atomics — recording
/// takes no lock and the hot path touches at most one relaxed tick per
/// [`batch::drain`] call (see `rust/docs/observability.md`).
pub struct ServerObs {
    /// When the server started accepting (uptime anchor).
    start: Instant,
    /// Server I/O threads: reactor count, or the accept loop (1) under
    /// the thread model. Set once at startup.
    threads: AtomicU64,
    /// Connections ever accepted.
    pub total_connections: ShardedCounter,
    /// Connections closed (any reason).
    pub closed_connections: ShardedCounter,
    /// Reactor poller wakeups (0 under the thread model).
    pub poller_wakeups: ShardedCounter,
    /// Connections closed because their state machine panicked (caught
    /// per-connection; the server survives).
    pub conn_panics: ShardedCounter,
    /// Reactor threads respawned by the supervisor after dying.
    pub reactor_respawns: ShardedCounter,
    /// Accepts shed by `--max-conns` admission control
    /// (`SERVER_ERROR busy`).
    pub sheds: ShardedCounter,
    /// Connections reaped by `--conn-idle-timeout`.
    pub idle_reaped: ShardedCounter,
    /// High-water mark of any single connection's pending reply bytes.
    outbuf_high_water: AtomicU64,
    /// Ops per flushed batch (count units, not nanoseconds), recorded on
    /// sampled drains.
    pub batch_sizes: LatencyHistogram,
    /// Whole-drain-call wall time, recorded on sampled drains.
    pub drain_ns: LatencyHistogram,
    /// 1-in-N drain sampling stride; 0 disables.
    sample_every: u32,
    /// Private sampling tick (see [`ServerObs::sample`]).
    tick: AtomicU64,
}

impl ServerObs {
    /// Build with the given drain-sampling stride (0 disables sampling;
    /// the `stats` server facts still work).
    pub fn new(sample_every: u32) -> ServerObs {
        ServerObs {
            start: Instant::now(),
            threads: AtomicU64::new(0),
            total_connections: ShardedCounter::new(),
            closed_connections: ShardedCounter::new(),
            poller_wakeups: ShardedCounter::new(),
            conn_panics: ShardedCounter::new(),
            reactor_respawns: ShardedCounter::new(),
            sheds: ShardedCounter::new(),
            idle_reaped: ShardedCounter::new(),
            outbuf_high_water: AtomicU64::new(0),
            batch_sizes: LatencyHistogram::new(),
            drain_ns: LatencyHistogram::new(),
            sample_every,
            tick: AtomicU64::new(0),
        }
    }

    /// Record the serving-thread count (startup, once).
    fn set_threads(&self, n: usize) {
        // ord: relaxed-ok — written once before serving starts; readers
        // are stats renderers.
        self.threads.store(n as u64, Ordering::Relaxed);
    }

    /// Sampled-clock tick: true on 1-in-`sample_every` calls (the first
    /// call always samples). One relaxed `fetch_add` — the entire cost a
    /// non-sampled drain pays.
    pub fn sample(&self) -> bool {
        if self.sample_every == 0 {
            return false;
        }
        // ord: relaxed-ok — private sampling tick; counts drain calls
        // only, orders nothing, and an occasional torn stride is
        // harmless.
        let t = self.tick.fetch_add(1, Ordering::Relaxed);
        t % u64::from(self.sample_every) == 0
    }

    /// Fold one connection's pending reply bytes into the high-water
    /// mark.
    pub fn note_outbuf(&self, pending: usize) {
        // ord: relaxed-ok — monotonic stats-grade high-water mark; no
        // data is ordered against it.
        self.outbuf_high_water.fetch_max(pending as u64, Ordering::Relaxed);
    }

    /// Assemble the `stats` reply's server facts.
    pub fn info(&self, curr_connections: usize) -> proto::ServerInfo {
        proto::ServerInfo {
            uptime_secs: self.start.elapsed().as_secs(),
            time_secs: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            // ord: relaxed-ok — startup-written thread count.
            threads: self.threads.load(Ordering::Relaxed),
            curr_connections: curr_connections as u64,
            total_connections: self.total_connections.get(),
        }
    }

    /// Snapshot the serving-plane gauges for `/metrics`.
    pub fn gauges(&self) -> proto::ServerGauges {
        let batch = self.batch_sizes.snapshot();
        let drain = self.drain_ns.snapshot();
        proto::ServerGauges {
            closed_connections: self.closed_connections.get(),
            poller_wakeups: self.poller_wakeups.get(),
            conn_panics: self.conn_panics.get(),
            reactor_respawns: self.reactor_respawns.get(),
            sheds: self.sheds.get(),
            idle_reaped: self.idle_reaped.get(),
            // ord: relaxed-ok — stats-grade high-water mark.
            outbuf_high_water: self.outbuf_high_water.load(Ordering::Relaxed),
            batch_size_p50: batch.percentile(0.50),
            batch_size_p99: batch.percentile(0.99),
            drain_samples: drain.count,
            drain_p50_ns: drain.percentile(0.50),
            drain_p99_ns: drain.percentile(0.99),
        }
    }
}

/// Which connection-handling front-end a server runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerModel {
    /// One blocking native thread per connection.
    Thread,
    /// Event-driven reactor threads (Unix only). `io_threads == 0` means
    /// one reactor per available core.
    Reactor { io_threads: usize },
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: SocketAddr,
    /// Disable Nagle on accepted sockets (latency experiments need it).
    pub nodelay: bool,
    /// Front-end model.
    pub model: ServerModel,
    /// Per-connection pending-reply cap: past this many buffered reply
    /// bytes a connection stops reading (and executing) until its peer
    /// drains. Bounds server memory against slow/non-reading clients;
    /// see [`batch::drain`] for the precise bound.
    pub max_outbuf: usize,
    /// 1-in-N sampling stride for the serving-plane batch/drain
    /// histograms (0 disables). Mirrors `CacheConfig::latency_sample`.
    pub drain_sample: u32,
    /// Bind a Prometheus-style text exposition endpoint here (`GET
    /// /metrics`); `None` (default) serves no HTTP.
    pub metrics_addr: Option<SocketAddr>,
    /// Admission cap: past this many live connections, new accepts are
    /// shed with `SERVER_ERROR busy` and closed instead of admitted —
    /// explicit degradation at the edge rather than an `EMFILE` spiral
    /// that takes working connections down. 0 (default) = unlimited.
    pub max_conns: usize,
    /// Close connections with no activity for this long (coarse — the
    /// reap sweep runs on the existing poller wakeup, never per event).
    /// `None` (default) = never reap.
    pub idle_timeout: Option<Duration>,
    /// Multi-tenant control plane (see [`crate::cache::tenant`]):
    /// `Some` enables the `tenant` command, per-tenant namespacing and
    /// accounting on every connection. `None` (default) serves exactly
    /// the pre-tenancy wire protocol.
    pub tenants: Option<Arc<crate::cache::tenant::TenantPlane>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:11211".parse().unwrap(),
            nodelay: true,
            model: ServerModel::Thread,
            max_outbuf: 256 * 1024,
            drain_sample: 64,
            metrics_addr: None,
            max_conns: 0,
            idle_timeout: None,
            tenants: None,
        }
    }
}

/// Resolve `io_threads == 0` to the machine's available parallelism.
pub fn resolve_io_threads(io_threads: usize) -> usize {
    if io_threads > 0 {
        io_threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    }
}

/// A running server; dropping it (or calling [`Server::shutdown`]) stops
/// the accept/reactor loops and joins every server thread.
pub struct Server {
    addr: SocketAddr,
    metrics_addr: Option<SocketAddr>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    curr_conns: Arc<AtomicUsize>,
    buffered_out: Arc<AtomicUsize>,
    obs: Arc<ServerObs>,
}

impl Server {
    /// Bind and start serving `cache` in background threads.
    pub fn start(config: ServerConfig, cache: Arc<dyn Cache>) -> std::io::Result<Server> {
        let listener = TcpListener::bind(config.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let curr_conns = Arc::new(AtomicUsize::new(0));
        let buffered_out = Arc::new(AtomicUsize::new(0));
        let obs = Arc::new(ServerObs::new(config.drain_sample));
        obs.set_threads(match config.model {
            ServerModel::Thread => 1,
            ServerModel::Reactor { io_threads } => resolve_io_threads(io_threads),
        });
        let mut threads = match config.model {
            ServerModel::Thread => vec![spawn_thread_model(
                listener,
                Arc::clone(&cache),
                &config,
                &stop,
                &draining,
                &curr_conns,
                &obs,
            )?],
            ServerModel::Reactor { io_threads } => spawn_reactors(
                listener,
                Arc::clone(&cache),
                &config,
                io_threads,
                &stop,
                &draining,
                &curr_conns,
                &buffered_out,
                &obs,
            )?,
        };
        let mut metrics_addr = None;
        if let Some(want) = config.metrics_addr {
            let ml = TcpListener::bind(want)?;
            metrics_addr = Some(ml.local_addr()?);
            threads.push(spawn_metrics_listener(
                ml,
                cache,
                Arc::clone(&obs),
                Arc::clone(&stop),
                Arc::clone(&curr_conns),
                config.tenants.clone(),
            )?);
        }
        Ok(Server {
            addr,
            metrics_addr,
            stop,
            draining,
            threads,
            curr_conns,
            buffered_out,
            obs,
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The bound `/metrics` address, when the endpoint is enabled
    /// (useful with port 0).
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// Serving-plane observability state (tests and embedders).
    pub fn obs(&self) -> &ServerObs {
        &self.obs
    }

    /// Number of currently-open connections.
    pub fn active_connections(&self) -> usize {
        self.curr_conns.load(Ordering::Acquire)
    }

    /// Total reply bytes buffered in userspace across all connections
    /// (reactor model; always 0 under the thread model, which writes
    /// synchronously). The backpressure tests hold this bounded against
    /// non-reading peers.
    pub fn buffered_out_bytes(&self) -> usize {
        self.buffered_out.load(Ordering::Acquire)
    }

    /// Stop accepting, close the loops, join threads.
    pub fn shutdown(&mut self) {
        // ord: Release stop flag; Acquire counterpart: accept/conn loops'
        // stop.load (join below is the real sync — the flag only exits).
        self.stop.store(true, Ordering::Release);
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful shutdown (the SIGTERM path of `fleec serve`): stop
    /// accepting, let every connection flush its buffered replies, close
    /// each as its outbuf empties, and wait up to `deadline` for the
    /// count to reach zero — then hard-stop whatever is left and join
    /// all server threads. Returns `true` when every connection drained
    /// within the deadline (the clean case), `false` when the deadline
    /// tripped first.
    ///
    /// Drain semantics: commands already *answered into* a connection's
    /// outbuf are delivered; buffered-but-unexecuted request bytes are
    /// dropped (a client that pipelined past the drain point sees the
    /// close and retries against the replacement server — the protocol
    /// is idempotent-retry shaped, this is the Memcached operational
    /// norm).
    pub fn drain(&mut self, deadline: Duration) -> bool {
        // ord: Release drain flag; Acquire counterpart: reactor/conn
        // loops' draining.load on their next wakeup.
        self.draining.store(true, Ordering::Release);
        let start = Instant::now();
        while start.elapsed() < deadline {
            if self.curr_conns.load(Ordering::Acquire) == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        let clean = self.curr_conns.load(Ordering::Acquire) == 0;
        self.shutdown();
        clean
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Spawn the reactor fleet under its supervisor: one supervisor thread
/// that spawns `n` reactors (each with a clone of the shared,
/// non-blocking listener), respawns any that die while the server is
/// live (re-homing their connections — see [`reactor::supervise`]), and
/// joins them all at stop.
#[cfg(unix)]
#[allow(clippy::too_many_arguments)]
fn spawn_reactors(
    listener: TcpListener,
    cache: Arc<dyn Cache>,
    config: &ServerConfig,
    io_threads: usize,
    stop: &Arc<AtomicBool>,
    draining: &Arc<AtomicBool>,
    curr_conns: &Arc<AtomicUsize>,
    buffered_out: &Arc<AtomicUsize>,
    obs: &Arc<ServerObs>,
) -> std::io::Result<Vec<std::thread::JoinHandle<()>>> {
    let n = resolve_io_threads(io_threads);
    let shared = reactor::ReactorShared {
        cache,
        stop: Arc::clone(stop),
        draining: Arc::clone(draining),
        curr_conns: Arc::clone(curr_conns),
        buffered_out: Arc::clone(buffered_out),
        max_outbuf: config.max_outbuf,
        max_conns: config.max_conns,
        idle_timeout: config.idle_timeout,
        nodelay: config.nodelay,
        obs: Arc::clone(obs),
        handoff: Arc::new(std::sync::Mutex::new(Vec::new())),
        tenants: config.tenants.clone(),
    };
    let supervisor = std::thread::Builder::new()
        .name("fleec-supervisor".into())
        .spawn(move || reactor::supervise(listener, shared, n))?;
    Ok(vec![supervisor])
}

/// Reactor model on a platform without a poller backend.
#[cfg(not(unix))]
#[allow(clippy::too_many_arguments)]
fn spawn_reactors(
    _listener: TcpListener,
    _cache: Arc<dyn Cache>,
    _config: &ServerConfig,
    _io_threads: usize,
    _stop: &Arc<AtomicBool>,
    _draining: &Arc<AtomicBool>,
    _curr_conns: &Arc<AtomicUsize>,
    _buffered_out: &Arc<AtomicUsize>,
    _obs: &Arc<ServerObs>,
) -> std::io::Result<Vec<std::thread::JoinHandle<()>>> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "the reactor model requires a Unix readiness poller; use --model thread",
    ))
}

/// Shed one over-cap accept: best-effort `SERVER_ERROR busy`, then
/// close. The reply is a courtesy (the socket was never admitted, so it
/// must not block the accept path — non-blocking write, failure
/// ignored); the close is the contract. Both front-end models shed
/// through here.
fn shed_stream(mut stream: TcpStream, obs: &ServerObs) {
    use std::io::Write;
    let _ = stream.set_nonblocking(true);
    let _ = stream.write(b"SERVER_ERROR busy\r\n");
    obs.sheds.inc();
    // Dropping `stream` closes the socket.
}

/// Idle-wait helper for the thread-model accept loop: a poller wait on
/// the listener fd where available (wakes the instant a connection
/// arrives), a short sleep elsewhere.
struct AcceptWaiter {
    #[cfg(unix)]
    poller: Option<(poller::Poller, Vec<poller::Event>)>,
}

impl AcceptWaiter {
    #[allow(unused_variables)]
    fn new(listener: &TcpListener) -> AcceptWaiter {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let poller = poller::Poller::new().ok().and_then(|mut p| {
                p.register(listener.as_raw_fd(), 0, poller::Interest::READ)
                    .ok()?;
                Some((p, Vec::new()))
            });
            AcceptWaiter { poller }
        }
        #[cfg(not(unix))]
        {
            AcceptWaiter {}
        }
    }

    /// Block until the listener is likely ready, or the reap interval
    /// elapses — the accept loop reaps finished connection threads on
    /// every return, so joins happen on a timer even with no new
    /// accepts.
    fn wait(&mut self) {
        const REAP_INTERVAL: Duration = Duration::from_millis(100);
        #[cfg(unix)]
        if let Some((p, events)) = self.poller.as_mut() {
            let _ = p.wait(events, Some(REAP_INTERVAL));
            return;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Spawn the thread-per-connection accept loop.
fn spawn_thread_model(
    listener: TcpListener,
    cache: Arc<dyn Cache>,
    config: &ServerConfig,
    stop: &Arc<AtomicBool>,
    draining: &Arc<AtomicBool>,
    curr_conns: &Arc<AtomicUsize>,
    obs: &Arc<ServerObs>,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    let accept_stop = Arc::clone(stop);
    let accept_draining = Arc::clone(draining);
    let accept_conns = Arc::clone(curr_conns);
    let accept_obs = Arc::clone(obs);
    let nodelay = config.nodelay;
    let max_outbuf = config.max_outbuf;
    let max_conns = config.max_conns;
    let idle_timeout = config.idle_timeout;
    let tenants = config.tenants.clone();
    std::thread::Builder::new()
        .name("fleec-accept".into())
        .spawn(move || {
            let mut waiter = AcceptWaiter::new(&listener);
            let mut conn_threads = Vec::new();
            while !accept_stop.load(Ordering::Acquire) {
                if accept_draining.load(Ordering::Acquire) {
                    // Draining: accept nothing more; just keep reaping
                    // finished connection threads until the stop flag.
                    std::thread::sleep(Duration::from_millis(10));
                    conn_threads.retain(|h: &std::thread::JoinHandle<()>| !h.is_finished());
                    continue;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // Admission control: past the cap, shed at the
                        // edge instead of marching into thread/fd
                        // exhaustion.
                        if max_conns != 0
                            // ord: Acquire connection gauge (pairs with
                            // the AcqRel increments/decrements); an
                            // approximate read is fine — the cap is
                            // advisory by a connection or two under
                            // races, never unbounded.
                            && accept_conns.load(Ordering::Acquire) >= max_conns
                        {
                            shed_stream(stream, &accept_obs);
                            conn_threads.retain(|h| !h.is_finished());
                            continue;
                        }
                        let _ = stream.set_nodelay(nodelay);
                        let _ = stream.set_nonblocking(false);
                        let cache = Arc::clone(&cache);
                        let stop = Arc::clone(&accept_stop);
                        let draining = Arc::clone(&accept_draining);
                        let active = Arc::clone(&accept_conns);
                        let obs = Arc::clone(&accept_obs);
                        let tenants = tenants.clone();
                        obs.total_connections.inc();
                        // ord: AcqRel connection gauge — increments and
                        // decrements form one modification order; Acquire
                        // counterpart: curr_conns() observers.
                        active.fetch_add(1, Ordering::AcqRel);
                        let spawned = std::thread::Builder::new()
                            .name("fleec-conn".into())
                            .spawn(move || {
                                // Panic isolation: a connection state
                                // machine that panics (engine bug,
                                // injected fault) takes down this
                                // connection only — same contract as the
                                // reactor's per-dispatch guard.
                                // `AssertUnwindSafe` is justified because
                                // all per-connection state lives inside
                                // the closure and dies with it.
                                let result =
                                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                        let _ = handle_connection(
                                            stream,
                                            cache,
                                            stop,
                                            draining,
                                            Arc::clone(&active),
                                            max_outbuf,
                                            idle_timeout,
                                            Arc::clone(&obs),
                                            tenants,
                                        );
                                    }));
                                if result.is_err() {
                                    obs.conn_panics.inc();
                                }
                                obs.closed_connections.inc();
                                // ord: AcqRel gauge decrement; pairs with
                                // the Acquire curr_conns() observers.
                                active.fetch_sub(1, Ordering::AcqRel);
                            });
                        match spawned {
                            Ok(h) => conn_threads.push(h),
                            // Thread exhaustion (EAGAIN) is the same
                            // resource-pressure class as EMFILE: drop
                            // this connection (the closure — and with it
                            // the stream — is gone), back off, keep
                            // serving. This is exactly the load point the
                            // reactor model exists for.
                            Err(_) => {
                                accept_obs.closed_connections.inc();
                                // ord: AcqRel gauge decrement; pairs with
                                // the Acquire curr_conns() observers.
                                accept_conns.fetch_sub(1, Ordering::AcqRel);
                                std::thread::sleep(Duration::from_millis(50));
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        waiter.wait();
                    }
                    // Transient accept failures (EMFILE, aborted
                    // handshakes) must not kill the server — same policy
                    // as the reactor's accept path. A *sleep*, not a
                    // poller wait: the failed connection is still in the
                    // backlog keeping the listener readable, so a poll
                    // would return instantly and the loop would spin hot.
                    Err(_) => std::thread::sleep(Duration::from_millis(50)),
                }
                // Reap on every pass — new accepts *and* waiter timeouts
                // — so finished threads join promptly on idle servers.
                conn_threads.retain(|h| !h.is_finished());
            }
            for h in conn_threads {
                let _ = h.join();
            }
        })
}

/// Blocking read-pump-write loop for one thread-model connection. The
/// protocol work all lives in [`batch::drain`]; this wrapper just moves
/// bytes and honors the stop/drain flags via a read timeout.
#[allow(clippy::too_many_arguments)]
fn handle_connection(
    mut stream: TcpStream,
    cache: Arc<dyn Cache>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    curr_conns: Arc<AtomicUsize>,
    max_outbuf: usize,
    idle_timeout: Option<Duration>,
    obs: Arc<ServerObs>,
    tenants: Option<Arc<crate::cache::tenant::TenantPlane>>,
) -> std::io::Result<()> {
    use std::io::Write;
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut inbuf: Vec<u8> = Vec::with_capacity(16 * 1024);
    let mut outbuf: Vec<u8> = Vec::with_capacity(16 * 1024);
    let mut arena = batch::BatchArena::default();
    let mut tenant = tenants.map(crate::cache::tenant::TenantConn::new);
    let mut chunk = [0u8; 16 * 1024];
    let mut pos = 0usize;
    let mut last_active = Instant::now();
    'conn: loop {
        if stop.load(Ordering::Acquire) {
            return Ok(());
        }
        // Draining: replies are written synchronously below, so nothing
        // is buffered — everything already answered has been delivered.
        // Buffered-but-unexecuted request bytes are dead (see
        // `Server::drain`); just close.
        if draining.load(Ordering::Acquire) {
            return Ok(());
        }
        // Pump everything buffered; blocking writes between budget stops
        // mean the outbuf never accumulates past one drain call.
        loop {
            // Failpoint `batch.drain`: an error closes this connection; a
            // panic unwinds into the spawn closure's `catch_unwind`.
            crate::faults::io("batch.drain")?;
            let d = batch::drain(
                cache.as_ref(),
                curr_conns.load(Ordering::Acquire),
                &inbuf[pos..],
                &mut outbuf,
                &mut arena,
                max_outbuf,
                Some(obs.as_ref()),
                tenant.as_mut(),
            );
            pos += d.consumed;
            obs.note_outbuf(outbuf.len());
            if !outbuf.is_empty() {
                // Failpoint `conn.write`: an injected error closes this
                // connection like a real broken pipe.
                crate::faults::io("conn.write")?;
                stream.write_all(&outbuf)?;
                outbuf.clear();
            }
            if d.fatal {
                // The reply stream is no longer trustworthy (batch result
                // mismatch): everything rendered was written above —
                // close so the peer can't read desynced replies.
                return Ok(());
            }
            match d.stop {
                batch::DrainStop::Quit => return Ok(()),
                batch::DrainStop::NeedMoreInput => break,
                batch::DrainStop::Budget => continue,
            }
        }
        if pos > 0 {
            inbuf.drain(..pos);
            pos = 0;
        }
        // Refill. Failpoint `conn.read`: an injected error closes this
        // connection like a real peer reset.
        crate::faults::io("conn.read")?;
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(()), // peer closed
            Ok(n) => {
                inbuf.extend_from_slice(&chunk[..n]);
                last_active = Instant::now();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle reap: the 200ms read timeout doubles as the sweep
                // tick (coarse by contract — same as the reactor's
                // wakeup-driven sweep).
                if let Some(idle) = idle_timeout {
                    if last_active.elapsed() >= idle {
                        obs.idle_reaped.inc();
                        return Ok(());
                    }
                }
                continue 'conn;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Spawn the optional Prometheus scrape listener. Scrapes are rare,
/// serial, and fully off the cache hot path; each request renders a
/// fresh exposition from the engine and serving-plane snapshots.
fn spawn_metrics_listener(
    listener: TcpListener,
    cache: Arc<dyn Cache>,
    obs: Arc<ServerObs>,
    stop: Arc<AtomicBool>,
    curr_conns: Arc<AtomicUsize>,
    tenants: Option<Arc<crate::cache::tenant::TenantPlane>>,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    listener.set_nonblocking(true)?;
    std::thread::Builder::new()
        .name("fleec-metrics".into())
        .spawn(move || {
            let mut waiter = AcceptWaiter::new(&listener);
            while !stop.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = serve_metrics_once(
                            stream,
                            cache.as_ref(),
                            &obs,
                            curr_conns.load(Ordering::Acquire),
                            tenants.as_deref(),
                        );
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => waiter.wait(),
                    // Same transient-failure policy as the accept loops.
                    Err(_) => std::thread::sleep(Duration::from_millis(50)),
                }
            }
        })
}

/// Serve one HTTP GET on an accepted scrape connection. Handwritten
/// minimal HTTP/1.1: the offline crate set has no HTTP stack and a
/// text-exposition endpoint needs none.
fn serve_metrics_once(
    mut stream: TcpStream,
    cache: &dyn Cache,
    obs: &ServerObs,
    curr_connections: usize,
    tenants: Option<&crate::cache::tenant::TenantPlane>,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let _ = stream.set_nodelay(true);
    let mut req: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    // Read up to the header terminator; request bodies are not accepted.
    while !req.windows(4).any(|w| w == b"\r\n\r\n") {
        if req.len() > 8 * 1024 {
            return write_http(&mut stream, "431 Request Header Fields Too Large", b"");
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(()), // peer gave up mid-request
            Ok(n) => req.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let line_end = req
        .windows(2)
        .position(|w| w == b"\r\n")
        .unwrap_or(req.len());
    let mut parts = req[..line_end].split(|&b| b == b' ');
    let method = parts.next().unwrap_or(b"");
    let path = parts.next().unwrap_or(b"");
    if method != b"GET" {
        return write_http(&mut stream, "405 Method Not Allowed", b"");
    }
    if path != b"/metrics" {
        return write_http(&mut stream, "404 Not Found", b"");
    }
    let stats = cache.stats();
    let info = obs.info(curr_connections);
    let mut body = Vec::with_capacity(4096);
    proto::write_prometheus(&mut body, cache.engine_name(), &stats, &info);
    proto::write_prometheus_server(&mut body, cache.engine_name(), &obs.gauges());
    if let Some(plane) = tenants {
        proto::write_prometheus_tenants(&mut body, cache.engine_name(), &plane.snapshot());
    }
    write_http(&mut stream, "200 OK", &body)
}

/// Write a complete HTTP/1.1 response and finish the exchange.
fn write_http(stream: &mut TcpStream, status: &str, body: &[u8]) -> std::io::Result<()> {
    use std::io::Write;
    let mut msg = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .into_bytes();
    msg.extend_from_slice(body);
    stream.write_all(&msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};
    use std::io::Write;

    fn start_test_server_on(model: ServerModel) -> (Server, SocketAddr) {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                ..ServerConfig::default()
            },
            cache,
        )
        .unwrap();
        let addr = server.addr();
        (server, addr)
    }

    fn start_test_server() -> (Server, SocketAddr) {
        start_test_server_on(ServerModel::Thread)
    }

    fn roundtrip(stream: &mut TcpStream, send: &[u8], expect: &[u8]) {
        stream.write_all(send).unwrap();
        let mut got = vec![0u8; expect.len()];
        stream.read_exact(&mut got).unwrap();
        assert_eq!(
            got,
            expect,
            "sent {:?}, expected {:?}, got {:?}",
            String::from_utf8_lossy(send),
            String::from_utf8_lossy(expect),
            String::from_utf8_lossy(&got)
        );
    }

    #[test]
    fn wire_level_session() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        roundtrip(&mut s, b"set foo 7 0 3\r\nbar\r\n", b"STORED\r\n");
        roundtrip(&mut s, b"get foo\r\n", b"VALUE foo 7 3\r\nbar\r\nEND\r\n");
        roundtrip(&mut s, b"get nope\r\n", b"END\r\n");
        roundtrip(&mut s, b"add foo 0 0 1\r\nx\r\n", b"NOT_STORED\r\n");
        roundtrip(&mut s, b"append foo 0 0 3\r\nbaz\r\n", b"STORED\r\n");
        roundtrip(&mut s, b"get foo\r\n", b"VALUE foo 7 6\r\nbarbaz\r\nEND\r\n");
        roundtrip(&mut s, b"delete foo\r\n", b"DELETED\r\n");
        roundtrip(&mut s, b"delete foo\r\n", b"NOT_FOUND\r\n");
        roundtrip(&mut s, b"set n 0 0 1\r\n5\r\n", b"STORED\r\n");
        roundtrip(&mut s, b"incr n 10\r\n", b"15\r\n");
        roundtrip(&mut s, b"decr n 20\r\n", b"0\r\n");
        roundtrip(&mut s, b"version\r\n", b"VERSION fleec-0.1.0\r\n");
        s.write_all(b"quit\r\n").unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn wire_level_session_reactor() {
        let (_server, addr) = start_test_server_on(ServerModel::Reactor { io_threads: 2 });
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        roundtrip(&mut s, b"set foo 7 0 3\r\nbar\r\n", b"STORED\r\n");
        roundtrip(&mut s, b"get foo\r\n", b"VALUE foo 7 3\r\nbar\r\nEND\r\n");
        roundtrip(&mut s, b"incr missing 1\r\n", b"NOT_FOUND\r\n");
        roundtrip(&mut s, b"version\r\n", b"VERSION fleec-0.1.0\r\n");
        s.write_all(b"quit\r\n").unwrap();
        // quit closes the connection from the server side.
        let mut buf = [0u8; 8];
        assert_eq!(s.read(&mut buf).unwrap_or(0), 0, "server must close after quit");
    }

    #[test]
    fn noreply_suppresses_responses() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        // Two noreply sets then a get: the first bytes back must be VALUE.
        s.write_all(b"set a 0 0 1 noreply\r\nx\r\nset b 0 0 1 noreply\r\ny\r\nget b\r\n")
            .unwrap();
        let mut buf = [0u8; 64];
        let n = s.read(&mut buf).unwrap();
        assert!(
            buf[..n].starts_with(b"VALUE b 0 1\r\ny\r\nEND\r\n"),
            "got {:?}",
            String::from_utf8_lossy(&buf[..n])
        );
    }

    #[test]
    fn pipelined_commands_in_one_write() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"set p 0 0 2\r\nhi\r\nget p\r\nstats\r\n").unwrap();
        let mut acc = Vec::new();
        let mut buf = [0u8; 4096];
        while !acc.windows(5).any(|w| w == b"END\r\n")
            || String::from_utf8_lossy(&acc).matches("END\r\n").count() < 2
        {
            let n = s.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            acc.extend_from_slice(&buf[..n]);
        }
        let text = String::from_utf8_lossy(&acc);
        assert!(text.starts_with("STORED\r\nVALUE p 0 2\r\nhi\r\nEND\r\n"), "{text}");
        assert!(text.contains("STAT engine fleec"), "{text}");
    }

    #[test]
    fn stats_barrier_sees_preceding_pipelined_ops() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        // set + get + stats in ONE write: the stats barrier must execute
        // after the batched ops so the counters include them.
        s.write_all(b"set sb 0 0 1\r\nv\r\nget sb\r\nstats\r\n").unwrap();
        let mut acc = Vec::new();
        let mut buf = [0u8; 4096];
        while String::from_utf8_lossy(&acc).matches("END\r\n").count() < 2 {
            let n = s.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            acc.extend_from_slice(&buf[..n]);
        }
        let text = String::from_utf8_lossy(&acc);
        assert!(text.starts_with("STORED\r\nVALUE sb 0 1\r\nv\r\nEND\r\n"), "{text}");
        assert!(text.contains("STAT cmd_get 1\r\n"), "{text}");
        assert!(text.contains("STAT cmd_set 1\r\n"), "{text}");
        assert!(text.contains("STAT curr_connections 1\r\n"), "{text}");
    }

    #[test]
    fn flush_all_barrier_orders_with_batched_ops() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        // The get before the flush must hit; the get after must miss —
        // even though all five commands arrive in one read.
        roundtrip(
            &mut s,
            b"set f 0 0 1\r\nx\r\nget f\r\nflush_all\r\nget f\r\n",
            b"STORED\r\nVALUE f 0 1\r\nx\r\nEND\r\nOK\r\nEND\r\n",
        );
    }

    #[test]
    fn malformed_command_gets_client_error() {
        let (_server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"frobnicate\r\nversion\r\n").unwrap();
        let mut buf = [0u8; 256];
        let mut acc = Vec::new();
        while !acc.windows(2).any(|w| w == b"\r\n") || acc.len() < 20 {
            let n = s.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            acc.extend_from_slice(&buf[..n]);
        }
        let text = String::from_utf8_lossy(&acc);
        assert!(text.starts_with("CLIENT_ERROR"), "{text}");
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let (mut server, addr) = start_test_server();
        let mut s = TcpStream::connect(addr).unwrap();
        roundtrip(&mut s, b"set x 0 0 1\r\nv\r\n", b"STORED\r\n");
        server.shutdown();
        // Post-shutdown connects must fail or be reset quickly.
        std::thread::sleep(Duration::from_millis(50));
    }

    #[cfg(unix)]
    #[test]
    fn reactor_shutdown_joins_cleanly() {
        let (mut server, addr) = start_test_server_on(ServerModel::Reactor { io_threads: 2 });
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        roundtrip(&mut s, b"set x 0 0 1\r\nv\r\n", b"STORED\r\n");
        assert_eq!(server.active_connections(), 1);
        server.shutdown();
    }

    fn start_cfg_server(config: ServerConfig) -> (Server, SocketAddr) {
        let cache = build_engine("fleec", CacheConfig::small()).unwrap();
        let server = Server::start(config, cache).unwrap();
        let addr = server.addr();
        (server, addr)
    }

    fn shed_scenario(model: ServerModel) {
        let (server, addr) = start_cfg_server(ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            model,
            max_conns: 1,
            ..ServerConfig::default()
        });
        // Admit one connection and prove it's registered (the op forces
        // the accept to have completed server-side).
        let mut keep = TcpStream::connect(addr).unwrap();
        keep.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        roundtrip(&mut keep, b"set k 0 0 1\r\nv\r\n", b"STORED\r\n");
        // The second connection must be shed with an explicit reply.
        let mut shed = TcpStream::connect(addr).unwrap();
        shed.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut acc = Vec::new();
        let mut buf = [0u8; 64];
        loop {
            match shed.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => acc.extend_from_slice(&buf[..n]),
                Err(e) => panic!("expected busy reply then close, got {e}"),
            }
        }
        assert_eq!(acc, b"SERVER_ERROR busy\r\n");
        assert!(server.obs().sheds.get() >= 1);
        // The admitted connection is unaffected.
        roundtrip(&mut keep, b"get k\r\n", b"VALUE k 0 1\r\nv\r\nEND\r\n");
        assert_eq!(server.active_connections(), 1);
    }

    #[test]
    fn max_conns_sheds_with_busy_thread_model() {
        shed_scenario(ServerModel::Thread);
    }

    #[cfg(unix)]
    #[test]
    fn max_conns_sheds_with_busy_reactor() {
        shed_scenario(ServerModel::Reactor { io_threads: 1 });
    }

    fn drain_scenario(model: ServerModel) {
        let (mut server, addr) = start_cfg_server(ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            model,
            ..ServerConfig::default()
        });
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        roundtrip(&mut s, b"set d 0 0 1\r\nx\r\n", b"STORED\r\n");
        let clean = server.drain(Duration::from_secs(5));
        assert!(clean, "drain must complete within the deadline");
        assert_eq!(server.active_connections(), 0);
        // The drained connection was closed from the server side.
        let mut buf = [0u8; 8];
        assert_eq!(s.read(&mut buf).unwrap_or(0), 0, "peer must see EOF after drain");
    }

    #[test]
    fn drain_closes_connections_thread_model() {
        drain_scenario(ServerModel::Thread);
    }

    #[cfg(unix)]
    #[test]
    fn drain_closes_connections_reactor() {
        drain_scenario(ServerModel::Reactor { io_threads: 2 });
    }

    fn idle_reap_scenario(model: ServerModel) {
        let (server, addr) = start_cfg_server(ServerConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            model,
            idle_timeout: Some(Duration::from_millis(100)),
            ..ServerConfig::default()
        });
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        roundtrip(&mut s, b"set i 0 0 1\r\nx\r\n", b"STORED\r\n");
        // Go idle well past the timeout; the sweep is coarse (500ms
        // cadence in the reactor, 200ms tick in the thread model), so
        // give it generous room before asserting.
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut buf = [0u8; 8];
        loop {
            match s.read(&mut buf) {
                Ok(0) => break, // reaped: server closed us
                Ok(_) => panic!("unexpected bytes on an idle connection"),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    assert!(Instant::now() < deadline, "connection never reaped");
                }
                Err(_) => break, // reset also counts as closed
            }
        }
        assert!(server.obs().idle_reaped.get() >= 1);
        assert_eq!(server.active_connections(), 0);
    }

    #[test]
    fn idle_timeout_reaps_thread_model() {
        idle_reap_scenario(ServerModel::Thread);
    }

    fn mismatch_closes_scenario(model: ServerModel) {
        // Regression: a batch-result mismatch used to leave the protocol
        // stream desynced but *open* — every later reply answered the
        // wrong command. The server must emit the framed error and close.
        let cache: Arc<dyn Cache> = Arc::new(crate::testutil::MismatchCache);
        let server = Server::start(
            ServerConfig {
                addr: "127.0.0.1:0".parse().unwrap(),
                model,
                ..ServerConfig::default()
            },
            cache,
        )
        .unwrap();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(b"set m 0 0 1\r\nx\r\n").unwrap();
        let mut acc = Vec::new();
        let mut buf = [0u8; 128];
        loop {
            match s.read(&mut buf) {
                Ok(0) => break, // server closed us: the new contract
                Ok(n) => acc.extend_from_slice(&buf[..n]),
                Err(e) => panic!("expected framed error then close, got {e}"),
            }
        }
        assert_eq!(acc, b"SERVER_ERROR batch result mismatch\r\n");
    }

    #[test]
    fn mismatch_closes_connection_thread_model() {
        mismatch_closes_scenario(ServerModel::Thread);
    }

    #[cfg(unix)]
    #[test]
    fn mismatch_closes_connection_reactor() {
        mismatch_closes_scenario(ServerModel::Reactor { io_threads: 1 });
    }

    #[cfg(unix)]
    #[test]
    fn idle_timeout_reaps_reactor() {
        idle_reap_scenario(ServerModel::Reactor { io_threads: 1 });
    }
}
