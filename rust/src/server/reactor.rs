//! Event-driven server front-end: N reactor threads multiplexing
//! non-blocking connections over a [`Poller`].
//!
//! The thread-per-connection model spends one native thread (stack,
//! scheduler slot, context switches) per socket, which caps connection
//! counts long before the lock-free core saturates. A reactor thread
//! instead owns an OS readiness poller and a set of connections, each a
//! small state machine:
//!
//! ```text
//! readable ─→ read into inbuf ─→ batch::drain (parse → plan → one
//!   Cache::execute_batch crossing per round) ─→ outbuf ─→ write
//!      ↑                                                    │ partial
//!      └────── re-armed READ interest                WRITE interest ──→
//!              (dropped while backpressured)         drained on writable
//! ```
//!
//! **Backpressure.** A connection whose peer stops reading accumulates
//! reply bytes in `outbuf`. Once the pending bytes cross the configured
//! cap the connection stops *reading* (READ interest dropped) and stops
//! *executing* ([`batch::drain`]'s budget), so further pipelined requests
//! stay as bytes in kernel buffers instead of materializing as reply
//! values. Other connections are unaffected — the reactor never blocks on
//! any single socket. When the peer drains, writable readiness resumes
//! the flush, then the pump, then reading.
//!
//! **Accept.** Every reactor registers the shared listener; whichever
//! thread wakes first accepts (losers observe `WouldBlock`). This spreads
//! connections across reactors without any cross-thread handoff, queues
//! or wakeup pipes — connections never migrate between reactors, so all
//! per-connection state stays thread-local.
//!
//! **Shutdown.** Reactors wake at least every [`WAIT`] to observe the
//! server's stop flag; dropping a reactor closes its poller and all its
//! connections.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::batch::{self, BatchArena, DrainStop};
use super::poller::{Event, Interest, Poller};
use crate::cache::Cache;

/// Token reserved for the listener; connection tokens are slab indices.
const LISTENER_TOKEN: usize = usize::MAX;

/// Upper bound on one poller wait, so stop flags are observed promptly.
const WAIT: Duration = Duration::from_millis(25);

/// Drop the consumed prefix of a connection's read buffer once it grows
/// past this (smaller prefixes wait for the buffer to empty — a memmove
/// per read would defeat the arena work).
const COMPACT_AT: usize = 8 * 1024;

/// Per-reactor configuration (shared fields come in as `Arc`s).
pub(super) struct ReactorShared {
    pub cache: Arc<dyn Cache>,
    pub stop: Arc<AtomicBool>,
    /// Live connection count across all reactors (`stats` truthfulness).
    pub curr_conns: Arc<AtomicUsize>,
    /// Total un-flushed reply bytes across all connections — the
    /// observable the backpressure tests (and future `stats` fields)
    /// read.
    pub buffered_out: Arc<AtomicUsize>,
    /// Per-connection pending-reply cap before reading stops.
    pub max_outbuf: usize,
    pub nodelay: bool,
    /// Serving-plane observability (counters, sampled histograms).
    pub obs: Arc<super::ServerObs>,
}

/// Run one reactor until the stop flag trips (or the poller itself
/// fails — never for per-connection errors). All exits run the
/// connection-count/gauge accounting.
pub(super) fn run_reactor(listener: TcpListener, shared: ReactorShared) -> io::Result<()> {
    let mut poller = Poller::new()?;
    poller.register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ)?;
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events: Vec<Event> = Vec::new();
    while !shared.stop.load(Ordering::Acquire) {
        // A hard poller failure ends this reactor, but via `break` so the
        // gauge/connection-count accounting below still runs.
        if poller.wait(&mut events, Some(WAIT)).is_err() {
            break;
        }
        shared.obs.poller_wakeups.inc();
        for i in 0..events.len() {
            let ev = events[i];
            if ev.token == LISTENER_TOKEN {
                accept_ready(&listener, &mut poller, &mut conns, &mut free, &shared);
                continue;
            }
            let Some(slot) = conns.get_mut(ev.token) else {
                continue;
            };
            let Some(conn) = slot.as_mut() else {
                continue;
            };
            let before = conn.out_pending();
            let keep = matches!(conn.on_ready(ev.readable, ev.writable, &shared), Ok(true));
            let after = if keep { conn.out_pending() } else { 0 };
            adjust_gauge(&shared.buffered_out, before, after);
            // Re-arm only on change; level triggering makes a stale-but-
            // wider interest harmless, but a *failed* re-arm would leave
            // the connection unable to make progress — close it.
            let keep = keep && conn.rearm(&mut poller).is_ok();
            if !keep {
                adjust_gauge(&shared.buffered_out, after, 0);
                let conn = slot.take().expect("conn checked above");
                let _ = poller.deregister(conn.stream.as_raw_fd());
                free.push(ev.token);
                shared.obs.closed_connections.inc();
                // ord: AcqRel connection gauge; Acquire counterpart:
                // Server::curr_conns observers.
                shared.curr_conns.fetch_sub(1, Ordering::AcqRel);
                // Dropping `conn` closes the socket.
            }
        }
    }
    // Account the connections this reactor takes down with it.
    for conn in conns.iter().flatten() {
        adjust_gauge(&shared.buffered_out, conn.out_pending(), 0);
        shared.obs.closed_connections.inc();
        // ord: AcqRel connection gauge; Acquire counterpart:
        // Server::curr_conns observers.
        shared.curr_conns.fetch_sub(1, Ordering::AcqRel);
    }
    Ok(())
}

/// Accept until `WouldBlock`; each new socket becomes a registered
/// connection on *this* reactor.
fn accept_ready(
    listener: &TcpListener,
    poller: &mut Poller,
    conns: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    shared: &ReactorShared,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(shared.nodelay);
                if stream.set_nonblocking(true).is_err() {
                    continue; // drop the socket; the peer sees a reset
                }
                let token = free.pop().unwrap_or_else(|| {
                    conns.push(None);
                    conns.len() - 1
                });
                let conn = Conn::new(stream, token, shared.max_outbuf);
                if poller
                    .register(conn.stream.as_raw_fd(), token, Interest::READ)
                    .is_err()
                {
                    free.push(token);
                    continue;
                }
                conns[token] = Some(conn);
                shared.obs.total_connections.inc();
                // ord: AcqRel connection gauge; Acquire counterpart:
                // Server::curr_conns observers.
                shared.curr_conns.fetch_add(1, Ordering::AcqRel);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            // Transient accept failures (EMFILE, aborted handshake): the
            // un-accepted connection stays in the backlog keeping the
            // level-triggered listener readable, so returning straight to
            // the poller would spin hot. Sleep a beat first — blocking
            // this reactor briefly under fd exhaustion is the least-bad
            // option (its own connections resume right after).
            Err(_) => {
                std::thread::sleep(Duration::from_millis(10));
                return;
            }
        }
    }
}

/// Move the shared pending-reply gauge by the delta one connection
/// produced this wakeup.
fn adjust_gauge(gauge: &AtomicUsize, before: usize, after: usize) {
    if after > before {
        gauge.fetch_add(after - before, Ordering::Relaxed);
    } else if before > after {
        gauge.fetch_sub(before - after, Ordering::Relaxed);
    }
}

/// One non-blocking connection: buffers, batch arenas, and the flags the
/// state machine steers by.
struct Conn {
    stream: TcpStream,
    token: usize,
    /// Raw request bytes; `pos..` is unconsumed.
    inbuf: Vec<u8>,
    pos: usize,
    /// Rendered reply bytes; `out_pos..` is unwritten.
    outbuf: Vec<u8>,
    out_pos: usize,
    /// Reusable op/action arenas — the depth-1 steady state performs no
    /// allocation per read.
    arena: BatchArena,
    /// Interest currently registered with the poller.
    interest: Interest,
    max_outbuf: usize,
    /// `quit` executed: flush remaining replies, then close.
    closing: bool,
    /// Peer closed its write half (read returned 0).
    read_closed: bool,
    /// The pump stopped for lack of a complete command (vs. budget).
    need_input: bool,
}

impl Conn {
    fn new(stream: TcpStream, token: usize, max_outbuf: usize) -> Conn {
        Conn {
            stream,
            token,
            inbuf: Vec::with_capacity(16 * 1024),
            pos: 0,
            outbuf: Vec::with_capacity(16 * 1024),
            out_pos: 0,
            arena: BatchArena::default(),
            interest: Interest::READ,
            max_outbuf,
            closing: false,
            read_closed: false,
            need_input: true,
        }
    }

    fn out_pending(&self) -> usize {
        self.outbuf.len() - self.out_pos
    }

    fn backpressured(&self) -> bool {
        self.out_pending() >= self.max_outbuf
    }

    /// Readiness entry point. `Ok(false)` means the connection is done
    /// (close it); `Err` means it failed (close it).
    fn on_ready(
        &mut self,
        readable: bool,
        writable: bool,
        shared: &ReactorShared,
    ) -> io::Result<bool> {
        if writable || self.out_pending() > 0 {
            self.flush()?;
        }
        // Resume work an earlier budget stop left buffered (this is how a
        // connection leaves backpressure: the writable event lands here).
        self.pump(shared)?;
        if readable {
            self.fill(shared)?;
        }
        if self.out_pending() == 0 {
            if self.closing {
                return Ok(false);
            }
            // Peer EOF: once every complete buffered command has been
            // answered, trailing bytes can only be an unfinished command.
            if self.read_closed && (self.need_input || self.pos == self.inbuf.len()) {
                return Ok(false);
            }
        }
        Ok(true)
    }

    /// Write `outbuf` to the socket until drained or `WouldBlock`.
    fn flush(&mut self) -> io::Result<()> {
        while self.out_pos < self.outbuf.len() {
            match self.stream.write(&self.outbuf[self.out_pos..]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.out_pos > 0 && self.out_pos == self.outbuf.len() {
            self.outbuf.clear();
            self.out_pos = 0;
        } else if self.out_pos >= COMPACT_AT {
            // Reclaim the written prefix even when the buffer never fully
            // drains (a peer that reads steadily but slower than we
            // produce would otherwise grow `outbuf` by everything ever
            // sent); the memmove moves only the < max_outbuf pending
            // tail.
            self.outbuf.drain(..self.out_pos);
            self.out_pos = 0;
        }
        Ok(())
    }

    /// Run [`batch::drain`] rounds over the buffered input until it needs
    /// more bytes, the connection backpressures, or a `quit` lands.
    fn pump(&mut self, shared: &ReactorShared) -> io::Result<()> {
        while !self.closing && !self.need_input && !self.backpressured() {
            let budget = self.out_pos.saturating_add(self.max_outbuf);
            let d = batch::drain(
                shared.cache.as_ref(),
                shared.curr_conns.load(Ordering::Acquire),
                &self.inbuf[self.pos..],
                &mut self.outbuf,
                &mut self.arena,
                budget,
                Some(shared.obs.as_ref()),
            );
            self.pos += d.consumed;
            shared.obs.note_outbuf(self.out_pending());
            match d.stop {
                DrainStop::Quit => self.closing = true,
                DrainStop::NeedMoreInput => self.need_input = true,
                DrainStop::Budget => {}
            }
            self.compact();
            // Push replies out eagerly; if the socket absorbs them the
            // budget check above un-backpressures and the loop continues.
            self.flush()?;
        }
        if self.closing {
            // Commands pipelined after `quit` are dead; drop their bytes.
            self.inbuf.clear();
            self.pos = 0;
        }
        Ok(())
    }

    /// Read until `WouldBlock`/EOF, pumping after every chunk so `inbuf`
    /// holds at most one chunk plus an incomplete command tail.
    fn fill(&mut self, shared: &ReactorShared) -> io::Result<()> {
        let mut chunk = [0u8; 16 * 1024];
        while !self.read_closed && !self.closing && !self.backpressured() {
            match self.stream.read(&mut chunk) {
                Ok(0) => self.read_closed = true,
                Ok(n) => {
                    self.inbuf.extend_from_slice(&chunk[..n]);
                    self.need_input = false;
                    self.pump(shared)?;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    fn compact(&mut self) {
        if self.pos == self.inbuf.len() {
            self.inbuf.clear();
            self.pos = 0;
        } else if self.pos >= COMPACT_AT {
            self.inbuf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Recompute and (when changed) re-register poller interest.
    ///
    /// Liveness invariant: an open connection always wants at least one
    /// readiness class. READ is dropped only while closing, past EOF, or
    /// backpressured; the first is closed once `outbuf` drains, and the
    /// latter two imply pending output — hence WRITE interest.
    fn rearm(&mut self, poller: &mut Poller) -> io::Result<()> {
        let want = Interest {
            read: !self.read_closed && !self.closing && !self.backpressured(),
            write: self.out_pending() > 0,
        };
        if want != self.interest {
            poller.modify(self.stream.as_raw_fd(), self.token, want)?;
            self.interest = want;
        }
        Ok(())
    }
}
