//! Event-driven server front-end: N reactor threads multiplexing
//! non-blocking connections over a [`Poller`], under a supervisor.
//!
//! The thread-per-connection model spends one native thread (stack,
//! scheduler slot, context switches) per socket, which caps connection
//! counts long before the lock-free core saturates. A reactor thread
//! instead owns an OS readiness poller and a set of connections, each a
//! small state machine:
//!
//! ```text
//! readable ─→ read into inbuf ─→ batch::drain (parse → plan → one
//!   Cache::execute_batch crossing per round) ─→ outbuf ─→ write
//!      ↑                                                    │ partial
//!      └────── re-armed READ interest                WRITE interest ──→
//!              (dropped while backpressured)         drained on writable
//! ```
//!
//! **Backpressure.** A connection whose peer stops reading accumulates
//! reply bytes in `outbuf`. Once the pending bytes cross the configured
//! cap the connection stops *reading* (READ interest dropped) and stops
//! *executing* ([`batch::drain`]'s budget), so further pipelined requests
//! stay as bytes in kernel buffers instead of materializing as reply
//! values. Other connections are unaffected — the reactor never blocks on
//! any single socket. When the peer drains, writable readiness resumes
//! the flush, then the pump, then reading.
//!
//! **Accept.** Every reactor registers the shared listener; whichever
//! thread wakes first accepts (losers observe `WouldBlock`). This spreads
//! connections across reactors without any cross-thread handoff, queues
//! or wakeup pipes — connections never migrate between reactors in
//! steady state, so all per-connection state stays thread-local. Past
//! `max_conns` live connections, new accepts are **shed**: a best-effort
//! `SERVER_ERROR busy` reply, then close — degrading at the edge instead
//! of marching into `EMFILE` and taking working connections with it.
//!
//! **Fault isolation.** Each readiness dispatch runs the connection's
//! state machine under `catch_unwind`: a panic (an engine bug, a protocol
//! state machine bug, an injected `faults` panic) closes *that*
//! connection (`conn_panics` in `ServerObs`) and nothing else. If the
//! reactor loop itself dies — poller failure, or a panic outside the
//! per-connection guard — the thread parks its surviving connections in
//! the fleet-wide handoff pen and exits; the [`supervise`] loop respawns
//! a replacement thread, which **re-homes** the parked fds into its fresh
//! poller instead of orphaning them. Clients riding a re-homed connection
//! observe at most a pause (level-triggered readiness re-reports pending
//! work to the new poller).
//!
//! **Idle reaping.** With `--conn-idle-timeout`, each connection carries
//! a coarse last-activity timestamp (refreshed from one clock read per
//! poller wakeup — never per event) and a periodic sweep on the existing
//! [`WAIT`] wakeup closes connections idle past the limit
//! (`idle_reaped`). Dead peers stop holding fds forever.
//!
//! **Shutdown and drain.** Reactors wake at least every [`WAIT`] to
//! observe the server's stop flag; dropping a reactor closes its poller
//! and all its connections. The graceful path (`Server::drain`) sets the
//! `draining` flag instead: reactors disarm the listener, stop reading,
//! flush every connection's buffered replies, and close each connection
//! as its outbuf empties — then the deadline in `Server::drain` trips the
//! hard stop for whatever is left.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::batch::{self, BatchArena, DrainStop};
use super::poller::{Event, Interest, Poller};
use crate::cache::Cache;
use crate::faults;

/// Token reserved for the listener; connection tokens are slab indices.
const LISTENER_TOKEN: usize = usize::MAX;

/// Upper bound on one poller wait, so stop flags are observed promptly.
const WAIT: Duration = Duration::from_millis(25);

/// Drop the consumed prefix of a connection's read buffer once it grows
/// past this (smaller prefixes wait for the buffer to empty — a memmove
/// per read would defeat the arena work).
const COMPACT_AT: usize = 8 * 1024;

/// Idle-reap sweep cadence: connections are checked for staleness at
/// most this often (a linear pass over the slab — cheap at this rate,
/// and the timeout itself is coarse by contract).
const SWEEP: Duration = Duration::from_millis(500);

/// How often the supervisor checks its reactors for unexpected exits.
const SUPERVISE_EVERY: Duration = Duration::from_millis(20);

/// Fleet-wide pen for connections whose reactor died: the dying thread
/// parks its survivors here, the supervisor's replacement adopts them.
pub(super) type Handoff = Mutex<Vec<Conn>>;

/// Per-reactor configuration (shared fields come in as `Arc`s).
pub(super) struct ReactorShared {
    pub cache: Arc<dyn Cache>,
    pub stop: Arc<AtomicBool>,
    /// Graceful-drain flag: stop accepting, flush, close as emptied.
    pub draining: Arc<AtomicBool>,
    /// Live connection count across all reactors (`stats` truthfulness).
    pub curr_conns: Arc<AtomicUsize>,
    /// Total un-flushed reply bytes across all connections — the
    /// observable the backpressure tests (and future `stats` fields)
    /// read.
    pub buffered_out: Arc<AtomicUsize>,
    /// Per-connection pending-reply cap before reading stops.
    pub max_outbuf: usize,
    /// Admission cap: shed accepts past this many live connections
    /// (0 = unlimited).
    pub max_conns: usize,
    /// Reap connections with no events for this long (`None` = never).
    pub idle_timeout: Option<Duration>,
    pub nodelay: bool,
    /// Serving-plane observability (counters, sampled histograms).
    pub obs: Arc<super::ServerObs>,
    /// Orphan pen for supervisor re-homing (see module docs).
    pub handoff: Arc<Handoff>,
    /// Multi-tenant control plane (`None` = tenant-less wire protocol).
    pub tenants: Option<Arc<crate::cache::tenant::TenantPlane>>,
}

impl Clone for ReactorShared {
    fn clone(&self) -> ReactorShared {
        ReactorShared {
            cache: Arc::clone(&self.cache),
            stop: Arc::clone(&self.stop),
            draining: Arc::clone(&self.draining),
            curr_conns: Arc::clone(&self.curr_conns),
            buffered_out: Arc::clone(&self.buffered_out),
            max_outbuf: self.max_outbuf,
            max_conns: self.max_conns,
            idle_timeout: self.idle_timeout,
            nodelay: self.nodelay,
            obs: Arc::clone(&self.obs),
            handoff: Arc::clone(&self.handoff),
            tenants: self.tenants.clone(),
        }
    }
}

/// One reactor's connection table. Owned by the thread *closure*, outside
/// the `catch_unwind` around the event loop, so survivors can be parked
/// for re-homing even when the loop dies by panic.
#[derive(Default)]
struct ReactorState {
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
}

/// Run the reactor fleet to completion: spawn `n` reactor threads, then
/// watch them — a thread that exits while the server is live is
/// respawned (its connections adopted from the handoff pen by the
/// replacement). Called on the supervisor thread; returns when the stop
/// flag trips and every reactor has joined.
pub(super) fn supervise(listener: TcpListener, shared: ReactorShared, n: usize) {
    let mut slots: Vec<Option<std::thread::JoinHandle<()>>> = Vec::with_capacity(n);
    for i in 0..n {
        slots.push(spawn_reactor(&listener, &shared, i));
    }
    while !shared.stop.load(Ordering::Acquire) {
        std::thread::sleep(SUPERVISE_EVERY);
        for (i, slot) in slots.iter_mut().enumerate() {
            let finished = slot.as_ref().map(|h| h.is_finished()).unwrap_or(true);
            if !finished || shared.stop.load(Ordering::Acquire) {
                continue;
            }
            if let Some(h) = slot.take() {
                let _ = h.join();
            }
            shared.obs.reactor_respawns.inc();
            *slot = spawn_reactor(&listener, &shared, i);
        }
    }
    for slot in slots.iter_mut() {
        if let Some(h) = slot.take() {
            let _ = h.join();
        }
    }
    // A reactor that died just as the stop flag tripped may have parked
    // connections no replacement ever adopted: account them closed here
    // so the gauges end truthful.
    let parked = {
        let mut pen = shared.handoff.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::take(&mut *pen)
    };
    for conn in parked {
        account_closed(&conn, &shared);
    }
}

/// Spawn one reactor thread (`None` if thread creation itself failed —
/// the supervisor retries on its next tick).
fn spawn_reactor(
    listener: &TcpListener,
    shared: &ReactorShared,
    index: usize,
) -> Option<std::thread::JoinHandle<()>> {
    // Each reactor owns a dup of the listening fd; the clones keep
    // listening no matter which thread dies.
    let own = listener.try_clone().ok()?;
    let shared = shared.clone();
    std::thread::Builder::new()
        .name(format!("fleec-reactor-{index}"))
        .spawn(move || reactor_thread(own, shared))
        .ok()
}

/// Thread body for one reactor: the event loop under a loop-level
/// `catch_unwind`. A clean exit (stop flag) accounts its connections
/// closed; an abnormal exit (poller failure, escaped panic) parks the
/// survivors for the supervisor's replacement and returns, which is what
/// the supervisor observes as a died thread.
fn reactor_thread(listener: TcpListener, shared: ReactorShared) {
    let mut state = ReactorState::default();
    let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
        run_reactor(&listener, &shared, &mut state)
    }));
    let clean = matches!(result, Ok(Ok(()))) || shared.stop.load(Ordering::Acquire);
    if !clean {
        let mut pen = shared.handoff.lock().unwrap_or_else(|e| e.into_inner());
        for conn in state.conns.iter_mut().filter_map(Option::take) {
            pen.push(conn);
        }
        return;
    }
    // Account the connections this reactor takes down with it.
    for conn in state.conns.iter().flatten() {
        account_closed(conn, &shared);
    }
}

/// Gauge/counter accounting for one connection leaving the server.
fn account_closed(conn: &Conn, shared: &ReactorShared) {
    adjust_gauge(&shared.buffered_out, conn.out_pending(), 0);
    shared.obs.closed_connections.inc();
    // ord: AcqRel connection gauge; Acquire counterpart:
    // Server::curr_conns observers.
    shared.curr_conns.fetch_sub(1, Ordering::AcqRel);
}

/// One reactor's event loop, until the stop flag trips. `Err` means the
/// loop can no longer run (poller failure — real or injected); the
/// caller parks `state`'s survivors for re-homing. Never errors for
/// per-connection failures.
fn run_reactor(
    listener: &TcpListener,
    shared: &ReactorShared,
    state: &mut ReactorState,
) -> io::Result<()> {
    let mut poller = Poller::new()?;
    let mut listener_armed = !shared.draining.load(Ordering::Acquire);
    if listener_armed {
        poller.register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ)?;
    }
    adopt_handoff(&mut poller, state, shared);
    let mut events: Vec<Event> = Vec::new();
    let mut last_sweep = Instant::now();
    while !shared.stop.load(Ordering::Acquire) {
        poller.wait(&mut events, Some(WAIT))?;
        // Failpoint `poller.wait`: an injected failure kills this
        // reactor the same way a real epoll_wait failure would —
        // exercising supervisor respawn + fd re-homing.
        faults::io("poller.wait")?;
        shared.obs.poller_wakeups.inc();
        // One clock read per wakeup — the coarse tick every
        // last-activity stamp this wakeup shares. Never per event.
        let now = Instant::now();
        let draining = shared.draining.load(Ordering::Acquire);
        for i in 0..events.len() {
            let ev = events[i];
            if ev.token == LISTENER_TOKEN {
                if !draining {
                    accept_ready(listener, &mut poller, state, shared, now);
                }
                continue;
            }
            let Some(slot) = state.conns.get_mut(ev.token) else {
                continue;
            };
            let Some(conn) = slot.as_mut() else {
                continue;
            };
            let before = conn.out_pending();
            // Panic isolation: a connection state machine that panics
            // (engine bug, injected fault) takes down this connection
            // only. `AssertUnwindSafe` is justified because the `conn`
            // the closure may leave half-mutated is closed and dropped
            // on the panic path before anything reads it again; the
            // cache itself guards its own invariants (EBR guards and
            // stripe locks release on unwind).
            let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
                conn.on_ready(ev.readable, ev.writable, shared)
            }));
            let panicked = result.is_err();
            if panicked {
                shared.obs.conn_panics.inc();
            }
            let mut keep = matches!(result, Ok(Ok(true)));
            let after = if keep { conn.out_pending() } else { 0 };
            adjust_gauge(&shared.buffered_out, before, after);
            // Re-arm only on change; level triggering makes a stale-but-
            // wider interest harmless, but a *failed* re-arm would leave
            // the connection unable to make progress — close it.
            keep = keep && conn.rearm(&mut poller).is_ok();
            if keep {
                conn.last_active = now;
            } else {
                adjust_gauge(&shared.buffered_out, after, 0);
                let conn = slot.take().expect("conn checked above");
                let _ = poller.deregister(conn.stream.as_raw_fd());
                state.free.push(ev.token);
                shared.obs.closed_connections.inc();
                // ord: AcqRel connection gauge; Acquire counterpart:
                // Server::curr_conns observers.
                shared.curr_conns.fetch_sub(1, Ordering::AcqRel);
                // Dropping `conn` closes the socket.
            }
        }
        if draining {
            if listener_armed {
                // Stop accepting: un-accepted backlog connections stay
                // in the kernel (reset when the listener finally closes)
                // instead of spinning the level-triggered poller.
                let _ = poller.deregister(listener.as_raw_fd());
                listener_armed = false;
            }
            drain_sweep(&mut poller, state, shared);
        } else if let Some(idle) = shared.idle_timeout {
            if now.duration_since(last_sweep) >= SWEEP {
                last_sweep = now;
                idle_sweep(&mut poller, state, shared, now, idle);
            }
        }
    }
    Ok(())
}

/// Adopt connections a died reactor parked: register each into this
/// reactor's fresh poller (re-homing). A connection whose fd can no
/// longer register is closed and accounted.
fn adopt_handoff(poller: &mut Poller, state: &mut ReactorState, shared: &ReactorShared) {
    let parked = {
        let mut pen = shared.handoff.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::take(&mut *pen)
    };
    for mut conn in parked {
        let token = state.free.pop().unwrap_or_else(|| {
            state.conns.push(None);
            state.conns.len() - 1
        });
        conn.token = token;
        // Want-everything interest: level triggering re-reports whatever
        // is actually pending, and the first dispatch re-arms precisely.
        let want = Interest {
            read: !conn.read_closed && !conn.closing && !conn.backpressured(),
            write: true,
        };
        if poller.register(conn.stream.as_raw_fd(), token, want).is_err() {
            state.free.push(token);
            account_closed(&conn, shared);
            continue;
        }
        conn.interest = want;
        conn.last_active = Instant::now();
        state.conns[token] = Some(conn);
    }
}

/// Close every connection idle past `idle`: dead peers must not hold
/// fds (and their outbuf memory) forever. Runs at most once per
/// [`SWEEP`] on the existing wakeup — no per-event cost.
fn idle_sweep(
    poller: &mut Poller,
    state: &mut ReactorState,
    shared: &ReactorShared,
    now: Instant,
    idle: Duration,
) {
    for token in 0..state.conns.len() {
        let Some(conn) = state.conns[token].as_ref() else {
            continue;
        };
        if now.duration_since(conn.last_active) < idle {
            continue;
        }
        let conn = state.conns[token].take().expect("conn checked above");
        let _ = poller.deregister(conn.stream.as_raw_fd());
        state.free.push(token);
        shared.obs.idle_reaped.inc();
        account_closed(&conn, shared);
    }
}

/// One drain pass: push every connection toward flush-and-close. Called
/// on each wakeup while draining, so a connection closes within one
/// [`WAIT`] of its outbuf emptying even with no socket events.
fn drain_sweep(poller: &mut Poller, state: &mut ReactorState, shared: &ReactorShared) {
    for token in 0..state.conns.len() {
        let Some(conn) = state.conns[token].as_mut() else {
            continue;
        };
        // Drain semantics: answer what is already rendered, accept
        // nothing more. Unconsumed request bytes are dead.
        conn.closing = true;
        conn.inbuf.clear();
        conn.pos = 0;
        let before = conn.out_pending();
        let flush_ok = conn.flush().is_ok();
        let after = conn.out_pending();
        adjust_gauge(&shared.buffered_out, before, after);
        if flush_ok && after > 0 {
            let _ = conn.rearm(poller);
            continue;
        }
        let conn = state.conns[token].take().expect("conn checked above");
        let _ = poller.deregister(conn.stream.as_raw_fd());
        state.free.push(token);
        adjust_gauge(&shared.buffered_out, after, 0);
        shared.obs.closed_connections.inc();
        // ord: AcqRel connection gauge; Acquire counterpart:
        // Server::curr_conns observers.
        shared.curr_conns.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Accept until `WouldBlock`; each new socket becomes a registered
/// connection on *this* reactor — unless the admission cap sheds it.
fn accept_ready(
    listener: &TcpListener,
    poller: &mut Poller,
    state: &mut ReactorState,
    shared: &ReactorShared,
    now: Instant,
) {
    loop {
        // Failpoint `accept`: an injected failure takes the transient-
        // error path below (back off, keep serving).
        if faults::fail("accept") {
            std::thread::sleep(Duration::from_millis(10));
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Admission control: past the cap, shed at the edge with
                // an explicit reply instead of marching into EMFILE.
                if shared.max_conns != 0
                    // ord: Acquire connection gauge (pairs with the
                    // AcqRel increments/decrements); an approximate read
                    // is fine — the cap is advisory by a connection or
                    // two under races, never unbounded.
                    && shared.curr_conns.load(Ordering::Acquire) >= shared.max_conns
                {
                    super::shed_stream(stream, &shared.obs);
                    continue;
                }
                let _ = stream.set_nodelay(shared.nodelay);
                if stream.set_nonblocking(true).is_err() {
                    continue; // drop the socket; the peer sees a reset
                }
                let token = state.free.pop().unwrap_or_else(|| {
                    state.conns.push(None);
                    state.conns.len() - 1
                });
                let mut conn = Conn::new(
                    stream,
                    token,
                    shared.max_outbuf,
                    shared
                        .tenants
                        .clone()
                        .map(crate::cache::tenant::TenantConn::new),
                );
                conn.last_active = now;
                if poller
                    .register(conn.stream.as_raw_fd(), token, Interest::READ)
                    .is_err()
                {
                    state.free.push(token);
                    continue;
                }
                state.conns[token] = Some(conn);
                shared.obs.total_connections.inc();
                // ord: AcqRel connection gauge; Acquire counterpart:
                // Server::curr_conns observers.
                shared.curr_conns.fetch_add(1, Ordering::AcqRel);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            // Transient accept failures (EMFILE, aborted handshake): the
            // un-accepted connection stays in the backlog keeping the
            // level-triggered listener readable, so returning straight to
            // the poller would spin hot. Sleep a beat first — blocking
            // this reactor briefly under fd exhaustion is the least-bad
            // option (its own connections resume right after).
            Err(_) => {
                std::thread::sleep(Duration::from_millis(10));
                return;
            }
        }
    }
}

/// Move the shared pending-reply gauge by the delta one connection
/// produced this wakeup.
fn adjust_gauge(gauge: &AtomicUsize, before: usize, after: usize) {
    if after > before {
        gauge.fetch_add(after - before, Ordering::Relaxed);
    } else if before > after {
        gauge.fetch_sub(before - after, Ordering::Relaxed);
    }
}

/// One non-blocking connection: buffers, batch arenas, and the flags the
/// state machine steers by.
pub(super) struct Conn {
    stream: TcpStream,
    token: usize,
    /// Raw request bytes; `pos..` is unconsumed.
    inbuf: Vec<u8>,
    pos: usize,
    /// Rendered reply bytes; `out_pos..` is unwritten.
    outbuf: Vec<u8>,
    out_pos: usize,
    /// Reusable op/action arenas — the depth-1 steady state performs no
    /// allocation per read.
    arena: BatchArena,
    /// Interest currently registered with the poller.
    interest: Interest,
    max_outbuf: usize,
    /// `quit` executed (or the reply stream turned fatal): flush
    /// remaining replies, then close.
    closing: bool,
    /// Peer closed its write half (read returned 0).
    read_closed: bool,
    /// The pump stopped for lack of a complete command (vs. budget).
    need_input: bool,
    /// Coarse last-activity stamp (refreshed per wakeup, not per
    /// syscall) — the idle-reap sweep's input.
    last_active: Instant,
    /// Tenant state when the server runs a multi-tenant plane. Lives on
    /// the connection, so it survives re-homing to another reactor.
    tenant: Option<crate::cache::tenant::TenantConn>,
}

impl Conn {
    fn new(
        stream: TcpStream,
        token: usize,
        max_outbuf: usize,
        tenant: Option<crate::cache::tenant::TenantConn>,
    ) -> Conn {
        Conn {
            stream,
            token,
            inbuf: Vec::with_capacity(16 * 1024),
            pos: 0,
            outbuf: Vec::with_capacity(16 * 1024),
            out_pos: 0,
            arena: BatchArena::default(),
            interest: Interest::READ,
            max_outbuf,
            closing: false,
            read_closed: false,
            need_input: true,
            last_active: Instant::now(),
            tenant,
        }
    }

    fn out_pending(&self) -> usize {
        self.outbuf.len() - self.out_pos
    }

    fn backpressured(&self) -> bool {
        self.out_pending() >= self.max_outbuf
    }

    /// Readiness entry point. `Ok(false)` means the connection is done
    /// (close it); `Err` means it failed (close it).
    fn on_ready(
        &mut self,
        readable: bool,
        writable: bool,
        shared: &ReactorShared,
    ) -> io::Result<bool> {
        if writable || self.out_pending() > 0 {
            self.flush()?;
        }
        // Resume work an earlier budget stop left buffered (this is how a
        // connection leaves backpressure: the writable event lands here).
        self.pump(shared)?;
        if readable {
            self.fill(shared)?;
        }
        if self.out_pending() == 0 {
            if self.closing {
                return Ok(false);
            }
            // Peer EOF: once every complete buffered command has been
            // answered, trailing bytes can only be an unfinished command.
            if self.read_closed && (self.need_input || self.pos == self.inbuf.len()) {
                return Ok(false);
            }
        }
        Ok(true)
    }

    /// Write `outbuf` to the socket until drained or `WouldBlock`.
    fn flush(&mut self) -> io::Result<()> {
        while self.out_pos < self.outbuf.len() {
            // Failpoint `conn.write`: injected short writes exercise the
            // partial-write resumption below; injected errors close the
            // connection like any real socket error.
            let pending = self.outbuf.len() - self.out_pos;
            let end = self.out_pos + faults::write_len("conn.write", pending)?;
            match self.stream.write(&self.outbuf[self.out_pos..end]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.out_pos > 0 && self.out_pos == self.outbuf.len() {
            self.outbuf.clear();
            self.out_pos = 0;
        } else if self.out_pos >= COMPACT_AT {
            // Reclaim the written prefix even when the buffer never fully
            // drains (a peer that reads steadily but slower than we
            // produce would otherwise grow `outbuf` by everything ever
            // sent); the memmove moves only the < max_outbuf pending
            // tail.
            self.outbuf.drain(..self.out_pos);
            self.out_pos = 0;
        }
        Ok(())
    }

    /// Run [`batch::drain`] rounds over the buffered input until it needs
    /// more bytes, the connection backpressures, or a `quit` lands.
    fn pump(&mut self, shared: &ReactorShared) -> io::Result<()> {
        while !self.closing && !self.need_input && !self.backpressured() {
            // Failpoint `batch.drain`: a delay models a slow engine; an
            // error closes this connection; a panic is the forced-panic
            // site the per-connection `catch_unwind` is tested with.
            faults::io("batch.drain")?;
            let budget = self.out_pos.saturating_add(self.max_outbuf);
            let d = batch::drain(
                shared.cache.as_ref(),
                shared.curr_conns.load(Ordering::Acquire),
                &self.inbuf[self.pos..],
                &mut self.outbuf,
                &mut self.arena,
                budget,
                Some(shared.obs.as_ref()),
                self.tenant.as_mut(),
            );
            self.pos += d.consumed;
            shared.obs.note_outbuf(self.out_pending());
            if d.fatal {
                // The reply stream is no longer trustworthy (batch
                // result mismatch): flush what was rendered, then close
                // — same policy as the thread model.
                self.closing = true;
            }
            match d.stop {
                DrainStop::Quit => self.closing = true,
                DrainStop::NeedMoreInput => self.need_input = true,
                DrainStop::Budget => {}
            }
            self.compact();
            // Push replies out eagerly; if the socket absorbs them the
            // budget check above un-backpressures and the loop continues.
            self.flush()?;
        }
        if self.closing {
            // Commands pipelined after `quit` are dead; drop their bytes.
            self.inbuf.clear();
            self.pos = 0;
        }
        Ok(())
    }

    /// Read until `WouldBlock`/EOF, pumping after every chunk so `inbuf`
    /// holds at most one chunk plus an incomplete command tail.
    fn fill(&mut self, shared: &ReactorShared) -> io::Result<()> {
        let mut chunk = [0u8; 16 * 1024];
        while !self.read_closed && !self.closing && !self.backpressured() {
            // Failpoint `conn.read`: an injected error closes this
            // connection like a real peer reset.
            faults::io("conn.read")?;
            match self.stream.read(&mut chunk) {
                Ok(0) => self.read_closed = true,
                Ok(n) => {
                    self.inbuf.extend_from_slice(&chunk[..n]);
                    self.need_input = false;
                    self.pump(shared)?;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    fn compact(&mut self) {
        if self.pos == self.inbuf.len() {
            self.inbuf.clear();
            self.pos = 0;
        } else if self.pos >= COMPACT_AT {
            self.inbuf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Recompute and (when changed) re-register poller interest.
    ///
    /// Liveness invariant: an open connection always wants at least one
    /// readiness class. READ is dropped only while closing, past EOF, or
    /// backpressured; the first is closed once `outbuf` drains, and the
    /// latter two imply pending output — hence WRITE interest.
    fn rearm(&mut self, poller: &mut Poller) -> io::Result<()> {
        let want = Interest {
            read: !self.read_closed && !self.closing && !self.backpressured(),
            write: self.out_pending() > 0,
        };
        if want != self.interest {
            // Failpoint `poller.arm`: a failed re-arm closes this
            // connection (same as a real epoll_ctl failure).
            faults::io("poller.arm")?;
            poller.modify(self.stream.as_raw_fd(), self.token, want)?;
            self.interest = want;
        }
        Ok(())
    }
}
