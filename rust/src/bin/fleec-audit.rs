//! `fleec-audit` — CLI for the in-repo lock-free-discipline analyzer.
//!
//! Walks a Rust source tree (default: this crate's `src/`) and enforces
//! the repo's lock-free conventions (see [`fleec::audit`] and
//! `rust/docs/concurrency.md`): `SAFETY:` on every `unsafe` site,
//! `ord:` tags on every release-side memory ordering, `guard-stable:`
//! on guard-lending public APIs, and no lone `/` where a `//` comment
//! was meant (the desk-check-era compile nit).
//!
//! ```text
//! fleec-audit [--root DIR] [--json PATH|-] [--deny-warnings] [--quiet]
//! ```
//!
//! Exit status: 0 clean, 1 findings (errors, or warnings under
//! `--deny-warnings`), 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use fleec::audit;

struct Opts {
    root: PathBuf,
    json: Option<String>,
    deny_warnings: bool,
    quiet: bool,
}

fn usage() -> ! {
    eprintln!(
        "usage: fleec-audit [--root DIR] [--json PATH|-] [--deny-warnings] [--quiet]\n\
         \n\
         Audits a Rust tree for FLeeC's lock-free discipline:\n\
           safety  `unsafe` sites must carry a SAFETY: comment\n\
           ord     Release/AcqRel/SeqCst must carry an ord: pairing tag;\n\
                   Relaxed in the lock-free core must carry ord: relaxed-ok\n\
           guard   guard-lending pub fns must carry a guard-stable: tag\n\
           comment lone `/` in comment position (malformed `//`) is an error\n\
         Waive in place with `audit:allow(<rule>) <reason>`."
    );
    std::process::exit(2);
}

fn parse_args() -> Opts {
    let mut opts = Opts {
        root: PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src")),
        json: None,
        deny_warnings: false,
        quiet: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(d) => opts.root = PathBuf::from(d),
                None => usage(),
            },
            "--json" => match args.next() {
                Some(p) => opts.json = Some(p),
                None => usage(),
            },
            "--deny-warnings" => opts.deny_warnings = true,
            "--quiet" | "-q" => opts.quiet = true,
            "--help" | "-h" => usage(),
            _ => usage(),
        }
    }
    opts
}

fn main() -> ExitCode {
    let opts = parse_args();
    let report = match audit::audit_tree(&opts.root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fleec-audit: cannot walk {}: {e}", opts.root.display());
            return ExitCode::from(2);
        }
    };
    if let Some(path) = &opts.json {
        let json = report.to_json();
        if path == "-" {
            print!("{json}");
        } else if let Err(e) = std::fs::write(path, json) {
            eprintln!("fleec-audit: cannot write {path}: {e}");
            return ExitCode::from(2);
        }
    }
    if !opts.quiet || report.errors() > 0 || report.warnings() > 0 {
        eprint!("{}", report.render());
    }
    let failed = report.errors() > 0 || (opts.deny_warnings && report.warnings() > 0);
    if failed {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}
