//! Minimal property-testing harness (the offline crate set has no
//! proptest/quickcheck).
//!
//! [`run_prop`] drives a seeded [`Xoshiro256`] through `CASES` random
//! cases; a failing case panics with the *seed* so the exact case can be
//! replayed with `FLEEC_PROP_SEED=<seed>`. [`Shrinker`]-style minimization
//! is approximated by re-running failures with progressively truncated
//! operation sequences when the property works on `Vec<T>`.

use crate::sync::Xoshiro256;

/// Number of cases per property (override with `FLEEC_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("FLEEC_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Suite-level RNG seed: `FLEEC_SEED` overrides `default` (decimal or
/// `0x`-prefixed hex), and the effective value is announced on stderr
/// (`FLEEC_SEED=<n>`) so any failing randomized run — local or CI — can
/// be replayed bit-exactly by exporting the printed value. Call once per
/// test, before spawning workers; derive per-thread streams by
/// xor/offset so threads stay decorrelated.
pub fn suite_seed(default: u64) -> u64 {
    let seed = std::env::var("FLEEC_SEED")
        .ok()
        .and_then(|s| parse_seed(&s))
        .unwrap_or(default);
    eprintln!("FLEEC_SEED={seed}");
    seed
}

fn parse_seed(s: &str) -> Option<u64> {
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

/// Run `prop` on `cases` random streams. On panic, reports the failing
/// seed. Set `FLEEC_PROP_SEED` to replay a single seed.
pub fn run_prop(name: &str, base_seed: u64, prop: impl Fn(&mut Xoshiro256)) {
    if let Ok(seed) = std::env::var("FLEEC_PROP_SEED") {
        let seed: u64 = seed.parse().expect("FLEEC_PROP_SEED must be a u64");
        let mut rng = Xoshiro256::seeded(seed);
        prop(&mut rng);
        return;
    }
    // `FLEEC_SEED` shifts the whole case stream (fresh schedules in CI);
    // `FLEEC_PROP_SEED` above replays one exact case.
    let base_seed = suite_seed(base_seed);
    for case in 0..default_cases() {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Xoshiro256::seeded(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case}; replay with FLEEC_PROP_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Generate a random operation sequence for model-based tests: each item
/// is `(key_index, op_selector, size_selector)`.
pub fn op_sequence(rng: &mut Xoshiro256, len: usize, key_space: u64) -> Vec<(u64, u32, u32)> {
    (0..len)
        .map(|_| {
            (
                rng.next_below(key_space),
                rng.next_u64() as u32,
                rng.next_u64() as u32,
            )
        })
        .collect()
}

/// Shrink a failing op-sequence: find the shortest prefix (by bisection)
/// that still fails `check`, returning it for the panic message.
pub fn shrink_prefix<T: Clone>(ops: &[T], check: impl Fn(&[T]) -> bool) -> Vec<T> {
    // `check` returns true when the property HOLDS.
    debug_assert!(!check(ops), "shrink called on a passing sequence");
    let mut lo = 0usize; // longest known-passing prefix length
    let mut hi = ops.len(); // shortest known-failing prefix length
    while lo + 1 < hi {
        let mid = lo + (hi - lo) / 2;
        if check(&ops[..mid]) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    ops[..hi].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_prop_executes_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static RUNS: AtomicU64 = AtomicU64::new(0);
        RUNS.store(0, Ordering::SeqCst);
        run_prop("counter", 1, |_rng| {
            RUNS.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(RUNS.load(Ordering::SeqCst), default_cases());
    }

    #[test]
    fn suite_seed_defaults_without_env() {
        // Only meaningful when the override is absent (the usual case);
        // under FLEEC_SEED=<n> the env value wins by design.
        if std::env::var("FLEEC_SEED").is_err() {
            assert_eq!(suite_seed(42), 42);
        }
    }

    #[test]
    fn seed_parses_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed("0xC4A05EED"), Some(0xC4A0_5EED));
        assert_eq!(parse_seed("0Xff"), Some(255));
        assert_eq!(parse_seed("nope"), None);
        assert_eq!(parse_seed("0xg"), None);
    }

    #[test]
    fn op_sequence_is_deterministic_per_seed() {
        let mut a = Xoshiro256::seeded(9);
        let mut b = Xoshiro256::seeded(9);
        assert_eq!(op_sequence(&mut a, 100, 10), op_sequence(&mut b, 100, 10));
    }

    #[test]
    fn shrink_finds_minimal_failing_prefix() {
        // Fails as soon as the prefix contains the value 7.
        let ops: Vec<u64> = vec![1, 2, 3, 7, 4, 5];
        let minimal = shrink_prefix(&ops, |prefix| !prefix.contains(&7));
        assert_eq!(minimal, vec![1, 2, 3, 7]);
    }
}

/// A deliberately contract-violating [`crate::cache::Cache`]: every
/// batched op is answered with the **wrong result variant** (exactly
/// once, so the sink's exactly-once accounting stays clean). This is the
/// regression fixture for the batch-result-mismatch path — the emitter
/// must render a framed `SERVER_ERROR batch result mismatch` and flag
/// the stream fatal so the serving front-ends close the connection
/// instead of serving desynced replies forever.
pub struct MismatchCache;

impl crate::cache::Cache for MismatchCache {
    fn engine_name(&self) -> &'static str {
        "mismatch-stub"
    }

    fn execute_batch_into(
        &self,
        ops: &[crate::cache::Op<'_>],
        sink: &mut dyn crate::cache::BatchSink,
    ) {
        for (idx, op) in ops.iter().enumerate() {
            // Touch expects Touched — hand it a Store; everything else
            // gets Touched. Either way the variant is wrong.
            match op {
                crate::cache::Op::Touch { .. } => {
                    sink.store(idx, crate::cache::StoreOutcome::Stored)
                }
                _ => sink.touched(idx, true),
            }
        }
    }

    fn get(&self, _key: &[u8]) -> Option<crate::cache::GetResult> {
        None
    }
    fn set(&self, _k: &[u8], _v: &[u8], _f: u32, _e: u32) -> crate::cache::StoreOutcome {
        crate::cache::StoreOutcome::Stored
    }
    fn add(&self, _k: &[u8], _v: &[u8], _f: u32, _e: u32) -> crate::cache::StoreOutcome {
        crate::cache::StoreOutcome::Stored
    }
    fn replace(&self, _k: &[u8], _v: &[u8], _f: u32, _e: u32) -> crate::cache::StoreOutcome {
        crate::cache::StoreOutcome::Stored
    }
    fn append(&self, _k: &[u8], _s: &[u8]) -> crate::cache::StoreOutcome {
        crate::cache::StoreOutcome::Stored
    }
    fn prepend(&self, _k: &[u8], _p: &[u8]) -> crate::cache::StoreOutcome {
        crate::cache::StoreOutcome::Stored
    }
    fn cas(&self, _k: &[u8], _v: &[u8], _f: u32, _e: u32, _c: u64) -> crate::cache::StoreOutcome {
        crate::cache::StoreOutcome::Stored
    }
    fn delete(&self, _key: &[u8]) -> bool {
        false
    }
    fn incr(&self, _key: &[u8], _delta: u64) -> Option<u64> {
        None
    }
    fn decr(&self, _key: &[u8], _delta: u64) -> Option<u64> {
        None
    }
    fn touch(&self, _key: &[u8], _exptime: u32) -> bool {
        false
    }
    fn flush_all(&self) {}
    fn item_count(&self) -> usize {
        0
    }
    fn bucket_count(&self) -> usize {
        0
    }
    fn mem_used(&self) -> usize {
        0
    }
    fn mem_limit(&self) -> usize {
        0
    }
    fn stats(&self) -> crate::cache::StatsSnapshot {
        crate::cache::StatsSnapshot::default()
    }
}
