//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time,
//! lowering the L2 JAX functions (which call the L1 Pallas kernels) to
//! **HLO text** in `artifacts/`. This module loads that text with the
//! `xla` crate (`HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile`) and exposes typed wrappers:
//!
//! * [`PlannerModule`] — the eviction planner: CLOCK snapshot + memory
//!   pressure → (decay, sweep batch, eviction target, histogram).
//! * [`HitRatioModule`] — the analytic hit-ratio model (Che approximation
//!   for LRU, fixed-point for FIFO/CLOCK) used by the hit-ratio bench to
//!   print model-vs-measured columns.
//!
//! Python never runs at serve time: the artifacts are self-contained and
//! executed on the PJRT CPU client from the coordinator thread — off the
//! request path by construction.

use std::path::{Path, PathBuf};

use crate::Result;

/// Fixed CLOCK-snapshot length the planner artifact was lowered for;
/// [`resample_clocks`] maps any live table size onto it.
pub const PLANNER_SNAPSHOT: usize = 4096;

/// Number of histogram bins the planner reports (CLOCK values 0..=7).
pub const PLANNER_BINS: usize = 8;

/// Shared PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create the CPU PJRT client.
    pub fn new() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
        })
    }

    /// Backend platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-UTF8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

/// API-compatible stub used when the crate is built without the `pjrt`
/// feature (the default in offline builds): every load fails cleanly, so
/// the coordinator and CLI fall back to the pure-Rust planner logic.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always fails: no PJRT client is linked in.
    pub fn new() -> Result<Runtime> {
        anyhow::bail!("built without the `pjrt` feature; PJRT artifacts unavailable")
    }

    /// Backend platform name (diagnostics).
    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }
}

/// Default artifacts directory (`$FLEEC_ARTIFACTS` overrides).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("FLEEC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Planner decision decoded from the artifact's outputs.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannerDecision {
    /// CLOCK decrement per sweep step (≥1; 2 under high pressure with a
    /// warm table — the multi-bit CLOCK drains faster).
    pub decay: u8,
    /// Items to evict per allocation-pressure round.
    pub batch: u32,
    /// Fraction of buckets currently evictable (CLOCK == 0).
    pub evictable_frac: f32,
    /// Histogram of CLOCK values over the snapshot.
    pub histogram: [u32; PLANNER_BINS],
}

/// The compiled eviction planner.
#[cfg(feature = "pjrt")]
pub struct PlannerModule {
    exe: xla::PjRtLoadedExecutable,
}

/// Stub planner handle for builds without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub struct PlannerModule {
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl PlannerModule {
    /// Always fails: artifacts cannot be executed without PJRT.
    pub fn load(_rt: &Runtime, _dir: &Path) -> Result<PlannerModule> {
        anyhow::bail!("built without the `pjrt` feature; planner artifact unavailable")
    }

    /// Unreachable in practice ([`PlannerModule::load`] never succeeds).
    pub fn run(&self, _clocks: &[i32; PLANNER_SNAPSHOT], _pressure: f32) -> Result<PlannerDecision> {
        anyhow::bail!("built without the `pjrt` feature")
    }
}

#[cfg(feature = "pjrt")]
impl PlannerModule {
    /// Load `planner.hlo.txt` from `dir`.
    pub fn load(rt: &Runtime, dir: &Path) -> Result<PlannerModule> {
        Ok(PlannerModule {
            exe: rt.load(&dir.join("planner.hlo.txt"))?,
        })
    }

    /// Run the planner on a fixed-size snapshot.
    /// `pressure` ∈ [0,1]: fraction of recent allocations that stalled.
    pub fn run(&self, clocks: &[i32; PLANNER_SNAPSHOT], pressure: f32) -> Result<PlannerDecision> {
        let clocks_lit = xla::Literal::vec1(&clocks[..]);
        let pressure_lit = xla::Literal::scalar(pressure);
        let result = self.exe.execute::<xla::Literal>(&[clocks_lit, pressure_lit])?[0][0]
            .to_literal_sync()?;
        let outputs = result.to_tuple()?;
        anyhow::ensure!(outputs.len() == 4, "planner must emit 4 outputs");
        let decay = outputs[0].to_vec::<i32>()?[0];
        let batch = outputs[1].to_vec::<i32>()?[0];
        let evictable = outputs[2].to_vec::<f32>()?[0];
        let hist_raw = outputs[3].to_vec::<i32>()?;
        let mut histogram = [0u32; PLANNER_BINS];
        for (dst, src) in histogram.iter_mut().zip(hist_raw.iter()) {
            *dst = (*src).max(0) as u32;
        }
        Ok(PlannerDecision {
            decay: decay.clamp(1, 255) as u8,
            batch: batch.clamp(1, 1 << 20) as u32,
            evictable_frac: evictable,
            histogram,
        })
    }
}

/// The compiled analytic hit-ratio model.
#[cfg(feature = "pjrt")]
pub struct HitRatioModule {
    exe: xla::PjRtLoadedExecutable,
}

/// Stub hit-ratio model handle for builds without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub struct HitRatioModule {
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl HitRatioModule {
    /// Always fails: artifacts cannot be executed without PJRT.
    pub fn load(_rt: &Runtime, _dir: &Path) -> Result<HitRatioModule> {
        anyhow::bail!("built without the `pjrt` feature; hit-ratio artifact unavailable")
    }

    /// Unreachable in practice ([`HitRatioModule::load`] never succeeds).
    pub fn run(&self, _alpha: f32, _capacity_items: f32) -> Result<HitRatioEstimate> {
        anyhow::bail!("built without the `pjrt` feature")
    }
}

/// Model output: expected hit ratios under each policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HitRatioEstimate {
    /// Che's approximation for strict LRU.
    pub lru: f32,
    /// Fixed-point approximation for FIFO-like policies (CLOCK's lower
    /// bound; CLOCK with use-bits lands between `fifo` and `lru`).
    pub fifo: f32,
}

#[cfg(feature = "pjrt")]
impl HitRatioModule {
    /// Load `hit_ratio.hlo.txt` from `dir`. The artifact is lowered for a
    /// fixed catalog size (see `python/compile/model.py`).
    pub fn load(rt: &Runtime, dir: &Path) -> Result<HitRatioModule> {
        Ok(HitRatioModule {
            exe: rt.load(&dir.join("hit_ratio.hlo.txt"))?,
        })
    }

    /// Estimate hit ratios for zipf(`alpha`) over the lowered catalog with
    /// a cache of `capacity_items`.
    pub fn run(&self, alpha: f32, capacity_items: f32) -> Result<HitRatioEstimate> {
        let a = xla::Literal::scalar(alpha);
        let c = xla::Literal::scalar(capacity_items);
        let result = self.exe.execute::<xla::Literal>(&[a, c])?[0][0].to_literal_sync()?;
        let outputs = result.to_tuple()?;
        anyhow::ensure!(outputs.len() == 2, "hit-ratio model must emit 2 outputs");
        Ok(HitRatioEstimate {
            lru: outputs[0].to_vec::<f32>()?[0],
            fifo: outputs[1].to_vec::<f32>()?[0],
        })
    }
}

/// Resample a live CLOCK snapshot (any length) onto the planner's fixed
/// input size by strided averaging (length ≥ snapshot) or tiling
/// (length < snapshot).
pub fn resample_clocks(live: &[u8]) -> [i32; PLANNER_SNAPSHOT] {
    let mut out = [0i32; PLANNER_SNAPSHOT];
    if live.is_empty() {
        return out;
    }
    if live.len() >= PLANNER_SNAPSHOT {
        // Strided pick: preserves the distribution the histogram needs.
        let stride = live.len() as f64 / PLANNER_SNAPSHOT as f64;
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = live[(i as f64 * stride) as usize % live.len()] as i32;
        }
    } else {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = live[i % live.len()] as i32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resample_preserves_distribution_shape() {
        // Half zeros, half threes.
        let live: Vec<u8> = (0..10_000).map(|i| if i % 2 == 0 { 0 } else { 3 }).collect();
        let sampled = resample_clocks(&live);
        let zeros = sampled.iter().filter(|&&v| v == 0).count();
        let threes = sampled.iter().filter(|&&v| v == 3).count();
        assert_eq!(zeros + threes, PLANNER_SNAPSHOT);
        let frac = zeros as f64 / PLANNER_SNAPSHOT as f64;
        assert!((frac - 0.5).abs() < 0.05, "zero fraction {frac}");
    }

    #[test]
    fn resample_small_input_tiles() {
        let live = [2u8, 0, 1];
        let sampled = resample_clocks(&live);
        assert_eq!(sampled[0], 2);
        assert_eq!(sampled[1], 0);
        assert_eq!(sampled[2], 1);
        assert_eq!(sampled[3], 2);
    }

    #[test]
    fn resample_empty_is_zeroed() {
        let sampled = resample_clocks(&[]);
        assert!(sampled.iter().all(|&v| v == 0));
    }

    // Artifact-dependent tests live in rust/tests/runtime_artifacts.rs
    // (they require `make artifacts` to have run).
}
