//! Treiber stack over raw blocks with a version-tagged head (ABA-safe).
//!
//! Used for the slab allocator's per-class free lists: the stack's nodes
//! *are* the free blocks (the successor pointer is written into the first
//! word of each block), so pushing/popping allocates nothing.
//!
//! The head packs a 48-bit pointer with a 16-bit version counter; every
//! successful pop increments the version so a concurrent pop that read a
//! stale head/next pair cannot CAS successfully (the classic ABA defence
//! for free-list stacks, where blocks get reused immediately).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sync::Backoff;

const PTR_BITS: u32 = 48;
const PTR_MASK: u64 = (1 << PTR_BITS) - 1;

#[inline]
fn pack(ptr: u64, ver: u64) -> u64 {
    debug_assert_eq!(ptr & !PTR_MASK, 0, "pointer exceeds 48 bits");
    (ver << PTR_BITS) | ptr
}

#[inline]
fn unpack(word: u64) -> (u64, u64) {
    (word & PTR_MASK, word >> PTR_BITS)
}

/// Intrusive lock-free stack of raw blocks (each ≥ 8 bytes, 8-aligned).
#[derive(Default)]
pub struct TaggedStack {
    head: AtomicU64,
}

impl TaggedStack {
    /// Empty stack.
    pub fn new() -> Self {
        TaggedStack {
            head: AtomicU64::new(0),
        }
    }

    /// Push a free block.
    ///
    /// # Safety
    /// `block` must be valid for writes of 8 bytes, 8-aligned, below
    /// 2^48, and owned by the caller (not reachable elsewhere).
    pub unsafe fn push(&self, block: *mut u8) {
        let mut backoff = Backoff::new();
        let block_word = block as u64;
        loop {
            let head = self.head.load(Ordering::Acquire);
            let (top, ver) = unpack(head);
            // Link the current top into the block's first word.
            (block as *mut u64).write(top);
            if self
                .head
                .compare_exchange_weak(
                    head,
                    pack(block_word, ver.wrapping_add(1)),
                    // ord: Release publishes the block's next-link write
                    // above; Acquire counterpart: head.load in push/pop.
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return;
            }
            backoff.spin();
        }
    }

    /// Pop a free block, or `None` if empty.
    ///
    /// # Safety
    /// All blocks in the stack must remain readable while the stack is in
    /// use (slab pages are never unmapped, so this holds by construction).
    pub unsafe fn pop(&self) -> Option<*mut u8> {
        let mut backoff = Backoff::new();
        loop {
            let head = self.head.load(Ordering::Acquire);
            let (top, ver) = unpack(head);
            if top == 0 {
                return None;
            }
            // Reading `next` from a block that another thread may have
            // popped and reused is tolerated: the version tag makes our
            // subsequent CAS fail, and slab pages are never unmapped so
            // the read itself stays in-bounds. Volatile keeps the compiler
            // from caching it across the CAS.
            let next = (top as *const u64).read_volatile();
            if self
                .head
                .compare_exchange_weak(
                    head,
                    pack(next, ver.wrapping_add(1)),
                    // ord: Release hands the popped block to the next
                    // pusher; Acquire counterpart: head.load in push/pop.
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return Some(top as *mut u8);
            }
            backoff.spin();
        }
    }

    /// Whether the stack currently looks empty (racy; stats only).
    pub fn is_empty(&self) -> bool {
        unpack(self.head.load(Ordering::Acquire)).0 == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    /// Arena of fake blocks so tests control lifetimes.
    fn arena(n: usize) -> Vec<Box<[u8; 64]>> {
        (0..n).map(|_| Box::new([0u8; 64])).collect()
    }

    #[test]
    fn lifo_order_single_thread() {
        let mut blocks = arena(3);
        let s = TaggedStack::new();
        let ptrs: Vec<*mut u8> = blocks.iter_mut().map(|b| b.as_mut_ptr()).collect();
        unsafe {
            for &p in &ptrs {
                s.push(p);
            }
            assert_eq!(s.pop(), Some(ptrs[2]));
            assert_eq!(s.pop(), Some(ptrs[1]));
            assert_eq!(s.pop(), Some(ptrs[0]));
            assert_eq!(s.pop(), None);
        }
    }

    #[test]
    fn empty_pop_is_none() {
        let s = TaggedStack::new();
        assert!(s.is_empty());
        assert_eq!(unsafe { s.pop() }, None);
    }

    #[test]
    fn concurrent_push_pop_conserves_blocks() {
        // N producers push unique blocks, N consumers pop; total popped set
        // must equal the pushed set (no loss, no duplication).
        let mut blocks = arena(4 * 256);
        let ptrs: Vec<usize> = blocks.iter_mut().map(|b| b.as_mut_ptr() as usize).collect();
        let s = Arc::new(TaggedStack::new());

        let mut handles = Vec::new();
        for chunk in ptrs.chunks(256) {
            let s = Arc::clone(&s);
            let chunk = chunk.to_vec();
            handles.push(std::thread::spawn(move || {
                for p in chunk {
                    unsafe { s.push(p as *mut u8) };
                }
            }));
        }
        let popped: Vec<std::thread::JoinHandle<Vec<usize>>> = (0..4)
            .map(|_| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    let mut misses = 0;
                    while misses < 10_000 && got.len() < 4 * 256 {
                        match unsafe { s.pop() } {
                            Some(p) => got.push(p as usize),
                            None => misses += 1,
                        }
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut all: Vec<usize> = Vec::new();
        for h in popped {
            all.extend(h.join().unwrap());
        }
        // Drain stragglers.
        while let Some(p) = unsafe { s.pop() } {
            all.push(p as usize);
        }
        assert_eq!(all.len(), ptrs.len(), "every block popped exactly once");
        let set: HashSet<usize> = all.iter().copied().collect();
        assert_eq!(set.len(), ptrs.len(), "no duplicates");
        assert_eq!(set, ptrs.iter().copied().collect::<HashSet<_>>());
    }

    #[test]
    fn reuse_after_pop_does_not_corrupt() {
        // Push/pop the same two blocks repeatedly from several threads —
        // the version tag must prevent ABA corruption (losing a block or
        // double-popping).
        let mut blocks = arena(2);
        let p0 = blocks[0].as_mut_ptr() as usize;
        let p1 = blocks[1].as_mut_ptr() as usize;
        let s = Arc::new(TaggedStack::new());
        unsafe {
            s.push(p0 as *mut u8);
            s.push(p1 as *mut u8);
        }
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for _ in 0..5_000 {
                        if let Some(p) = unsafe { s.pop() } {
                            std::hint::spin_loop();
                            unsafe { s.push(p) };
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let a = unsafe { s.pop() }.map(|p| p as usize);
        let b = unsafe { s.pop() }.map(|p| p as usize);
        let c = unsafe { s.pop() };
        assert_eq!(c, None, "exactly two blocks must remain");
        let got: HashSet<usize> = [a.unwrap(), b.unwrap()].into_iter().collect();
        assert_eq!(got, [p0, p1].into_iter().collect());
    }
}
