//! Lock-free building blocks.
//!
//! * [`list`] — Harris' pragmatic non-blocking linked list (reference \[3\]
//!   in the paper): the algorithm FLeeC's hash-table buckets are built on.
//!   The standalone generic version here backs the component micro-bench
//!   (experiment E4, locked vs lock-free list) and the property tests; the
//!   FLeeC table embeds a specialized intrusive variant with value-state
//!   words (see [`crate::cache::fleec`]).
//! * [`stack`] — Treiber stack with version-tagged heads (ABA-safe),
//!   used for the slab allocator's per-class free lists.

pub mod list;
pub mod stack;

pub use list::HarrisList;
pub use stack::TaggedStack;
