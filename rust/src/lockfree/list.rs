//! Harris' pragmatic non-blocking linked list.
//!
//! Sorted singly-linked list supporting lock-free `insert` / `remove` /
//! `get`. Deletion is two-phase: a node is *logically* deleted by setting
//! the mark bit of its `next` word (the CAS that linearizes removal), and
//! *physically* unlinked by any later traversal that finds the mark. The
//! unlinking CAS winner retires the node through [`crate::ebr`], so memory
//! is reclaimed only after a grace period.
//!
//! The list is ordered by `K: Ord`; duplicate keys are rejected on insert,
//! which is exactly the discipline the hash-table buckets need.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::ebr::{Collector, Guard};
use crate::sync::tagged::{tag_of, untagged, with_tag};
use crate::sync::Backoff;

/// List node. `next` packs the successor pointer with the deletion mark
/// in bit 0.
struct Node<K, V> {
    key: K,
    value: V,
    next: AtomicUsize,
}

/// A lock-free sorted linked list (Harris 2001).
pub struct HarrisList<K, V> {
    head: AtomicUsize,
    collector: Arc<Collector>,
    /// Approximate length, maintained with relaxed counters.
    len: AtomicUsize,
    _marker: std::marker::PhantomData<Box<Node<K, V>>>,
}

// SAFETY: nodes are shared across threads; K/V must therefore be Send+Sync.
unsafe impl<K: Send + Sync, V: Send + Sync> Send for HarrisList<K, V> {}
// SAFETY: same argument as Send — all shared state is atomics plus
// Send+Sync K/V reached through guard-protected pointers.
unsafe impl<K: Send + Sync, V: Send + Sync> Sync for HarrisList<K, V> {}

/// Result of an internal `search`: the predecessor link to CAS and the
/// packed word of the current node (0 when past the end).
struct Position {
    pred: *const AtomicUsize,
    curr: usize,
}

impl<K: Ord, V> HarrisList<K, V> {
    /// Empty list reclaiming through `collector`.
    pub fn new(collector: Arc<Collector>) -> Self {
        HarrisList {
            head: AtomicUsize::new(0),
            collector,
            len: AtomicUsize::new(0),
            _marker: std::marker::PhantomData,
        }
    }

    /// The collector this list retires into.
    pub fn collector(&self) -> &Arc<Collector> {
        &self.collector
    }

    /// Approximate number of live nodes.
    pub fn len(&self) -> usize {
        // ord: relaxed-ok — approximate counter by contract; no memory is
        // accessed based on the value.
        self.len.load(Ordering::Relaxed)
    }

    /// Whether the list is (approximately) empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Harris `search`: returns the first position whose key is ≥ `key`,
    /// physically unlinking every marked node it passes.
    fn search(&self, key: &K, guard: &Guard) -> Position {
        'retry: loop {
            let mut pred: *const AtomicUsize = &self.head;
            // SAFETY: pred always points into a live node (or the head)
            // protected by the guard.
            let mut curr = unsafe { (*pred).load(Ordering::Acquire) };
            debug_assert_eq!(tag_of(curr), 0, "head/pred link is never marked");
            loop {
                if untagged(curr) == 0 {
                    return Position { pred, curr: 0 };
                }
                // SAFETY: `curr` was read from a live link under the
                // guard, so the node cannot be reclaimed while we hold it.
                let node = unsafe { &*(untagged(curr) as *const Node<K, V>) };
                let next = node.next.load(Ordering::Acquire);
                if tag_of(next) == 1 {
                    // Logically deleted: attempt the physical unlink.
                    let clean_next = untagged(next);
                    // SAFETY: `pred` points into a guard-protected node
                    // (or the list head), so the link word is live.
                    match unsafe {
                        (*pred).compare_exchange(
                            curr,
                            clean_next,
                            // ord: Release publishes the shortened chain;
                            // Acquire counterpart: the link loads in
                            // search/keys (and Acquire here orders the
                            // re-read of pred's word).
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                    } {
                        Ok(_) => {
                            // We unlinked it; we retire it.
                            // SAFETY: winning the unlink CAS makes us the
                            // sole retirer; the node was Box-allocated by
                            // insert and is now unreachable from the list.
                            unsafe {
                                guard.defer_drop_box(untagged(curr) as *mut Node<K, V>);
                            }
                            curr = clean_next;
                            continue;
                        }
                        Err(_) => continue 'retry,
                    }
                }
                if node.key >= *key {
                    return Position { pred, curr };
                }
                pred = &node.next;
                curr = next;
            }
        }
    }

    /// Insert `key → value`; returns `false` (dropping nothing — the value
    /// is returned in `Err`) if the key is already present.
    pub fn insert(&self, key: K, value: V) -> Result<(), (K, V)> {
        let guard = self.collector.pin();
        let mut node = Box::new(Node {
            key,
            value,
            next: AtomicUsize::new(0),
        });
        let mut backoff = Backoff::new();
        loop {
            let pos = self.search(&node.key, &guard);
            if pos.curr != 0 {
                // SAFETY: `pos.curr` came from search under our guard.
                let curr = unsafe { &*(untagged(pos.curr) as *const Node<K, V>) };
                if curr.key == node.key {
                    return Err((node.key, node.value));
                }
            }
            // ord: relaxed-ok — pre-publication store to our own node; the
            // Release CAS below is what makes it (and the key/value
            // writes) visible.
            node.next.store(pos.curr, Ordering::Relaxed);
            let node_ptr = Box::into_raw(node);
            // SAFETY: `pos.pred` points into a guard-protected node (or
            // the head) returned by search.
            match unsafe {
                (*pos.pred).compare_exchange(
                    pos.curr,
                    node_ptr as usize,
                    // ord: Release publishes the node's key/value/next
                    // writes; Acquire counterpart: link loads in
                    // search/keys.
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
            } {
                Ok(_) => {
                    // ord: relaxed-ok — approximate length counter only.
                    self.len.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                Err(_) => {
                    // Reclaim the box and retry.
                    // SAFETY: the CAS failed, so `node_ptr` was never
                    // published — we still exclusively own the Box.
                    node = unsafe { Box::from_raw(node_ptr) };
                    backoff.spin();
                }
            }
        }
    }

    /// Remove `key`; returns whether it was present. Linearizes at the
    /// mark CAS.
    pub fn remove(&self, key: &K) -> bool {
        let guard = self.collector.pin();
        let mut backoff = Backoff::new();
        loop {
            let pos = self.search(key, &guard);
            if pos.curr == 0 {
                return false;
            }
            // SAFETY: `pos.curr` came from search under our guard.
            let node = unsafe { &*(untagged(pos.curr) as *const Node<K, V>) };
            if node.key != *key {
                return false;
            }
            let next = node.next.load(Ordering::Acquire);
            if tag_of(next) == 1 {
                // Someone else is deleting it right now; help via search.
                backoff.spin();
                continue;
            }
            // Logical deletion (the linearization point).
            if node
                .next
                // ord: Release seals the node's final successor under the
                // mark; Acquire counterpart: next-loads in search/remove
                // that observe the mark before unlinking.
                .compare_exchange(next, with_tag(untagged(next), 1), Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                backoff.spin();
                continue;
            }
            // ord: relaxed-ok — approximate length counter only.
            self.len.fetch_sub(1, Ordering::Relaxed);
            // Physical unlink (best effort; search will finish otherwise).
            // SAFETY: `pos.pred` points into a guard-protected node (or
            // the head) returned by search.
            if unsafe {
                (*pos.pred).compare_exchange(
                    pos.curr,
                    untagged(next),
                    // ord: Release publishes the shortened chain; Acquire
                    // counterpart: link loads in search/keys.
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
            }
            .is_ok()
            {
                // SAFETY: we won both the mark and the unlink CAS, so we
                // are the sole retirer of this Box-allocated node.
                unsafe {
                    guard.defer_drop_box(untagged(pos.curr) as *mut Node<K, V>);
                }
            } else {
                // Leave it for the next traversal to unlink + retire.
                let _ = self.search(key, &guard);
            }
            return true;
        }
    }

    /// Apply `f` to the value of `key` under the guard; `None` on miss.
    pub fn get<R>(&self, key: &K, f: impl FnOnce(&V) -> R) -> Option<R> {
        let guard = self.collector.pin();
        let pos = self.search(key, &guard);
        if pos.curr == 0 {
            return None;
        }
        // SAFETY: `pos.curr` came from search under the guard pinned above.
        let node = unsafe { &*(untagged(pos.curr) as *const Node<K, V>) };
        if node.key == *key {
            Some(f(&node.value))
        } else {
            None
        }
    }

    /// Whether `key` is present.
    pub fn contains(&self, key: &K) -> bool {
        self.get(key, |_| ()).is_some()
    }

    /// Snapshot of live keys (tests / debugging; not linearizable).
    pub fn keys(&self) -> Vec<K>
    where
        K: Clone,
    {
        let _guard = self.collector.pin();
        let mut out = Vec::new();
        let mut curr = self.head.load(Ordering::Acquire);
        while untagged(curr) != 0 {
            // SAFETY: `curr` was read from a live link under `_guard`.
            let node = unsafe { &*(untagged(curr) as *const Node<K, V>) };
            let next = node.next.load(Ordering::Acquire);
            if tag_of(next) == 0 {
                out.push(node.key.clone());
            }
            curr = next;
        }
        out
    }
}

impl<K, V> Drop for HarrisList<K, V> {
    fn drop(&mut self) {
        // Exclusive access: free the remaining chain directly.
        let mut curr = untagged(*self.head.get_mut());
        while curr != 0 {
            // SAFETY: `&mut self` in drop — every reachable node is a
            // published Box nobody else can touch anymore.
            let node = unsafe { Box::from_raw(curr as *mut Node<K, V>) };
            // ord: relaxed-ok — exclusive access in drop; no concurrent
            // writers exist.
            curr = untagged(node.next.load(Ordering::Relaxed));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as Counter;

    fn list() -> HarrisList<u64, u64> {
        HarrisList::new(Collector::default())
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let l = list();
        assert!(l.insert(3, 30).is_ok());
        assert!(l.insert(1, 10).is_ok());
        assert!(l.insert(2, 20).is_ok());
        assert_eq!(l.insert(2, 99).unwrap_err(), (2, 99));
        assert_eq!(l.get(&1, |v| *v), Some(10));
        assert_eq!(l.get(&2, |v| *v), Some(20));
        assert_eq!(l.get(&3, |v| *v), Some(30));
        assert_eq!(l.keys(), vec![1, 2, 3], "list must stay sorted");
        assert!(l.remove(&2));
        assert!(!l.remove(&2));
        assert_eq!(l.get(&2, |v| *v), None);
        assert_eq!(l.len(), 2);
    }

    #[test]
    fn remove_missing_is_noop() {
        let l = list();
        assert!(!l.remove(&42));
        assert!(l.insert(42, 1).is_ok());
        assert!(l.remove(&42));
        assert!(l.is_empty());
    }

    #[test]
    fn concurrent_disjoint_inserts_all_land() {
        let l = Arc::new(list());
        let threads = 8;
        let per = 200;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let l = Arc::clone(&l);
                std::thread::spawn(move || {
                    for i in 0..per {
                        l.insert(t * per + i, i).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(l.len(), (threads * per) as usize);
        let keys = l.keys();
        assert_eq!(keys.len(), (threads * per) as usize);
        assert!(keys.windows(2).all(|w| w[0] < w[1]), "sorted, no dups");
    }

    #[test]
    fn concurrent_same_key_insert_exactly_one_wins() {
        for _round in 0..20 {
            let l = Arc::new(list());
            let wins = Arc::new(Counter::new(0));
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    let l = Arc::clone(&l);
                    let wins = Arc::clone(&wins);
                    std::thread::spawn(move || {
                        if l.insert(7, t).is_ok() {
                            wins.fetch_add(1, Ordering::SeqCst);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(wins.load(Ordering::SeqCst), 1);
            assert_eq!(l.len(), 1);
        }
    }

    #[test]
    fn concurrent_remove_exactly_one_wins() {
        for _round in 0..20 {
            let l = Arc::new(list());
            l.insert(5, 50).unwrap();
            let wins = Arc::new(Counter::new(0));
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let l = Arc::clone(&l);
                    let wins = Arc::clone(&wins);
                    std::thread::spawn(move || {
                        if l.remove(&5) {
                            wins.fetch_add(1, Ordering::SeqCst);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(wins.load(Ordering::SeqCst), 1);
            assert!(l.is_empty());
        }
    }

    #[test]
    fn mixed_storm_keeps_list_consistent() {
        let collector = Collector::default();
        let l = Arc::new(HarrisList::<u64, u64>::new(Arc::clone(&collector)));
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let l = Arc::clone(&l);
                std::thread::spawn(move || {
                    let mut rng = crate::sync::Xoshiro256::seeded(t);
                    for _ in 0..2_000 {
                        let k = rng.next_below(64);
                        match rng.next_below(3) {
                            0 => {
                                let _ = l.insert(k, t);
                            }
                            1 => {
                                let _ = l.remove(&k);
                            }
                            _ => {
                                let _ = l.get(&k, |v| *v);
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let keys = l.keys();
        assert!(
            keys.windows(2).all(|w| w[0] < w[1]),
            "sorted and duplicate-free after the storm"
        );
        collector.force_reclaim(4);
    }
}
