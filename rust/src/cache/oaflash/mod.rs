//! `oaflash` — a lock-free **open-addressing** cache engine.
//!
//! The fourth engine: FLeeC's item/EBR/slab substrate under a
//! cache-line-dense linear-probe table instead of chained Harris lists.
//! A GET probe walks consecutive slot words (one cache line covers 8
//! slots) instead of chasing node pointers, which is the whole point at
//! the read-heavy corner the read-path sweep measures.
//!
//! Design in one paragraph (full argument in
//! `rust/docs/concurrency.md`): **claim-only linear probing with
//! generation-time relocation**. Within one table generation, a key's
//! entry is installed exactly once, by a CAS on the first empty slot of
//! its probe window, and never moves; deletion tombstones the *item
//! word* (entry stays, claim is reusable via revival); relocation — the
//! open-addressing analog of Robin-Hood/Hopscotch displacement — happens
//! only when a generation migrates into its successor, entry pointers
//! re-inserted one CAS at a time while readers keep resolving through
//! the frozen old generation. We deliberately rejected in-generation
//! displacement (both Robin Hood stealing and Hopscotch hops): moving a
//! key between slots while racing an insert of the *same key* can leave
//! two entries whose shadowing order flips as later displacements pass
//! each other — the published fixes (Kelly & Pearlmutter's timestamped
//! buckets, K-CAS) buy back linearizability at the cost of the simple
//! single-word commit that FLeeC's item semantics give us for free.
//!
//! The PR-5 invariant is structural here: relocation moves *entry
//! pointers between slot words*; item bytes live in slab chunks that
//! only ever retire through EBR, so a lent GET slice stays byte-stable
//! for the whole batch even while migration shuffles every entry.

pub mod table;

use std::sync::atomic::{
    AtomicBool, AtomicPtr, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
};
use std::sync::Arc;

use crate::cache::{
    deadline_from_exptime, hash_key, is_expired, BatchSink, Cache, CacheConfig, GetResult, Op,
    StatsSnapshot, StoreOutcome, MAX_KEY_LEN,
};
use crate::ebr::{Collector, Guard};
use crate::metrics::{EngineMetrics, LatencyHistogram, LatencyMetrics};
use crate::slab::{Slab, SlabConfig};

use crate::cache::fleec::node::{
    decode_item, live_word, Item, ItemState, ITEM_HEADER, MOVED_WORD, TOMB_WORD,
};
use table::{decode_slot, Entry, OaTable, SlotState, FWD_WORD, MIGRATE_SPAN, PROBE_WINDOW, SLOT_FRZ};

/// Allocation-retry rounds before a store reports `OutOfMemory`.
const OOM_ROUNDS: usize = 8;

/// Result of scanning one generation's probe window for a key.
enum Probe<'g> {
    /// The generation's unique entry for the key (its item word decides
    /// liveness; the slot may or may not be frozen — both are writable).
    Found { idx: usize, entry: &'g Entry },
    /// First empty slot in the window — the claim target, and an
    /// authoritative "key absent in this generation".
    Empty { idx: usize },
    /// A forwarded-empty slot before any match: the generation is closed
    /// for this key (the key was provably never here — the slot was
    /// empty from generation start until freeze).
    Closed,
    /// Window exhausted on occupied non-matching slots.
    Full,
}

/// Write-path location, after generation descent is resolved.
enum Spot<'g> {
    Found { idx: usize, entry: &'g Entry },
    Empty { idx: usize },
    /// Window full in the deepest generation (no successor installed):
    /// the key is absent; a store must expand before it can claim.
    Full,
}

/// Phase-A staging state for one batch op, consumed in phase B.
#[derive(Clone, Copy)]
enum Stage {
    /// Op stages nothing.
    Pass,
    /// Plain storage op: the ready item or the terminal staging failure.
    Store(Result<*mut Item, StoreOutcome>),
}

/// Store precondition selector.
#[derive(Clone, Copy, PartialEq)]
enum StoreMode {
    Set,
    Add,
    Replace,
    Cas(u64),
}

/// Outcome of [`OaFlashCache::rmw`].
enum RmwResult {
    Done(Vec<u8>),
    NotFound,
    Aborted,
    Failed(StoreOutcome),
}

/// The numeric-value parse `incr`/`decr` apply (protocol semantics:
/// UTF-8, surrounding whitespace tolerated).
#[inline]
fn parse_counter(data: &[u8]) -> Option<u64> {
    std::str::from_utf8(data).ok()?.trim().parse().ok()
}

/// Scan one generation's probe window for `key`. Readers and writers
/// share this scan, so both stop at the same authoritative boundaries —
/// the per-key uniqueness proof depends on a writer never claiming past
/// a slot a reader would have trusted as a miss.
fn probe<'g>(t: &'g OaTable, hash: u64, key: &[u8]) -> Probe<'g> {
    let home = t.home(hash);
    let window = PROBE_WINDOW.min(t.len());
    for d in 0..window {
        let i = (home + d) & t.mask;
        let w = t.slots[i].load(Ordering::Acquire);
        match decode_slot(w) {
            SlotState::Empty => return Probe::Empty { idx: i },
            SlotState::Fwd => return Probe::Closed,
            SlotState::Resident { entry, .. } => {
                // SAFETY: a resident entry is only freed with its table
                // generation through EBR retirement; every caller holds a
                // guard, and the slot never changes entries (monotonicity).
                let e = unsafe { &*entry };
                if e.hash == hash && *e.key == *key {
                    return Probe::Found { idx: i, entry: e };
                }
            }
        }
    }
    Probe::Full
}

/// The open-addressing lock-free cache engine.
pub struct OaFlashCache {
    collector: Arc<Collector>,
    slab: Arc<Slab>,
    /// Root of the generation chain (EBR-protected).
    table: AtomicPtr<OaTable>,
    /// Live entries across the chain.
    items: AtomicUsize,
    /// Monotonic CAS-token source (also the RMW race detector).
    cas_counter: AtomicU64,
    /// Entries relocated into a successor generation — the engine's
    /// displacement count, read by the guard-stability stress.
    displacements: AtomicU64,
    /// Generation promotions completed (an old root fully migrated and
    /// retired) — `stats internals` reports this as `oa_migrations`.
    migrations: AtomicU64,
    metrics: EngineMetrics,
    /// Sampled per-op-class latency histograms (`stats latency`).
    latency: LatencyMetrics,
    /// Probe lengths (slots examined per terminal lookup — distance
    /// units, not nanoseconds), recorded only while `probe_sample` is up.
    oa_probe: LatencyHistogram,
    /// Raised while a sampled batch runs so lookup cores record probe
    /// lengths. Shared across threads: a racing non-sampled batch can
    /// lower it early, dropping a few samples — stats-grade, tolerated.
    probe_sample: AtomicBool,
    config: CacheConfig,
    /// Planner-tunable eviction parameters.
    evict_decay: AtomicU8,
    evict_batch: AtomicU32,
}

impl OaFlashCache {
    /// Build an engine from `config`.
    pub fn new(config: CacheConfig) -> Self {
        // Capacity floor keeps the probe window meaningful relative to
        // the table (PROBE_WINDOW slots = the whole smallest table).
        let slots = config.initial_buckets.next_power_of_two().max(64);
        let slab = Slab::new(SlabConfig {
            mem_limit: config.mem_limit,
            ..SlabConfig::default()
        });
        OaFlashCache {
            collector: Collector::default(),
            slab,
            table: AtomicPtr::new(OaTable::alloc(slots)),
            items: AtomicUsize::new(0),
            cas_counter: AtomicU64::new(0),
            displacements: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            metrics: EngineMetrics::default(),
            latency: LatencyMetrics::default(),
            oa_probe: LatencyHistogram::new(),
            probe_sample: AtomicBool::new(false),
            evict_batch: AtomicU32::new(config.evict_batch),
            evict_decay: AtomicU8::new(1),
            config,
        }
    }

    /// The EBR collector (shared with the coordinator).
    pub fn collector(&self) -> &Arc<Collector> {
        &self.collector
    }

    /// The engine's live request-path counters (see
    /// [`crate::cache::fleec::FleecCache::metrics`] for why inherent).
    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// The slab allocator (stats).
    pub fn slab(&self) -> &Arc<Slab> {
        &self.slab
    }

    /// Entries relocated across generations since creation. The
    /// guard-stability stress asserts this is non-zero while its lent
    /// slices stay byte-identical.
    pub fn displacements(&self) -> u64 {
        // ord: relaxed-ok — accounting counter; stats tolerate racy
        // snapshots.
        self.displacements.load(Ordering::Relaxed)
    }

    #[inline]
    fn root<'g>(&self, _guard: &'g Guard) -> &'g OaTable {
        // SAFETY: the root table is only retired after being unlinked by
        // try_promote, and we hold a guard.
        unsafe { &*self.table.load(Ordering::Acquire) }
    }

    /// Bump a slot's CLOCK to the maximum (recently used). Load-first so
    /// hot slots don't redirty the cache line on every hit.
    #[inline]
    fn touch_clock(&self, t: &OaTable, idx: usize) {
        let c = &t.clocks[idx];
        let max = self.config.clock_max;
        // ord: relaxed-ok — CLOCK eviction heuristic (load + store below);
        // racy reads/writes only skew victim choice.
        if c.load(Ordering::Relaxed) != max {
            // ord: relaxed-ok — CLOCK heuristic, as above.
            c.store(max, Ordering::Relaxed);
        }
    }

    /// Mark a slot mildly used (fresh insert: CLOCK 1 if previously 0 —
    /// one sweep of protection without outranking hot slots).
    #[inline]
    fn seed_clock(&self, t: &OaTable, idx: usize) {
        // ord: relaxed-ok — CLOCK eviction heuristic; a lost race only
        // skews victim choice.
        let _ = t.clocks[idx].compare_exchange(0, 1, Ordering::Relaxed, Ordering::Relaxed);
    }

    /// Descend to `t`'s successor, helping migration along the way.
    fn descend<'g>(&self, t: &'g OaTable, guard: &'g Guard) -> &'g OaTable {
        let next = t.next.load(Ordering::Acquire);
        debug_assert!(!next.is_null(), "descend without a successor");
        // SAFETY: chain tables are retired only through EBR after the
        // root swings past them; the guard keeps `next` live.
        let next_ref = unsafe { &*next };
        self.migrate_span(t, next_ref, guard);
        self.try_promote(guard);
        next_ref
    }

    /// Walk the generation chain until a write-relevant location lands:
    /// the key's entry with a non-`Moved` item word, the first empty slot
    /// of the deepest reachable window, or `Full`.
    fn locate_for_write<'g>(&self, hash: u64, key: &[u8], guard: &'g Guard) -> (&'g OaTable, Spot<'g>) {
        let mut t = self.root(guard);
        loop {
            match probe(t, hash, key) {
                Probe::Found { idx, entry } => {
                    if matches!(
                        decode_item(entry.item.load(Ordering::Acquire)),
                        ItemState::Moved
                    ) {
                        // Entry already transferred: its current home is a
                        // deeper generation.
                        t = self.descend(t, guard);
                        continue;
                    }
                    return (t, Spot::Found { idx, entry });
                }
                Probe::Empty { idx } => return (t, Spot::Empty { idx }),
                Probe::Closed => t = self.descend(t, guard),
                Probe::Full => {
                    if t.next.load(Ordering::Acquire).is_null() {
                        return (t, Spot::Full);
                    }
                    t = self.descend(t, guard);
                }
            }
        }
    }

    /// If the root table is fully migrated, swing the root to its
    /// successor and retire the old generation.
    fn try_promote(&self, guard: &Guard) {
        let root = self.table.load(Ordering::Acquire);
        // SAFETY: the root table is only retired after being unlinked by
        // the CAS below, and we hold a guard.
        let t = unsafe { &*root };
        if !t.fully_migrated() {
            return;
        }
        let next = t.next.load(Ordering::Acquire);
        if next.is_null() {
            return;
        }
        if self
            .table
            // ord: AcqRel — Release publishes the promotion so later root
            // loads start at the new generation; Acquire counterpart: the
            // root loads in root() and here.
            .compare_exchange(root, next, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            // SAFETY: we won the root swing — sole retirer of the old
            // generation; stragglers still reading it hold guards. The
            // generation's Drop frees its entries (items were already
            // transferred or retired).
            unsafe { guard.defer_drop_box(root) };
            // ord: relaxed-ok — accounting counter; stats tolerate racy
            // snapshots.
            self.migrations.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Install (or return) `t`'s successor generation. Same-size when the
    /// live count says the pressure is tombstones (rehash purges them),
    /// double otherwise. `config.load_factor` is a chaining knob (items
    /// per bucket > 1); open addressing expands on *claimed slots*
    /// instead, so it is deliberately unused here.
    fn install_successor<'g>(&self, t: &'g OaTable, guard: &'g Guard) -> &'g OaTable {
        let next = t.next.load(Ordering::Acquire);
        if !next.is_null() {
            // SAFETY: guard-protected successor; chain tables retire only
            // through EBR.
            return unsafe { &*next };
        }
        // ord: relaxed-ok — sizing heuristic; an approximate live count
        // only shifts the growth decision.
        let live = self.items.load(Ordering::Relaxed);
        let cap = if live + live / 2 >= t.len() {
            t.len() * 2
        } else {
            t.len()
        };
        let new = OaTable::alloc(cap.max(64));
        match t.next.compare_exchange(
            std::ptr::null_mut(),
            new,
            // ord: AcqRel — Release publishes the new table's initialized
            // slots; Acquire counterpart: the `next` loads in descend,
            // locate_for_write, migration and the read paths.
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => {
                self.metrics.expansions.inc();
                let _ = guard;
                // SAFETY: just published; retired only through EBR.
                unsafe { &*new }
            }
            Err(_) => {
                // SAFETY: the CAS failed — `new` was never published and
                // we still exclusively own the Box.
                unsafe { drop(Box::from_raw(new)) };
                // SAFETY: non-null was just observed by the failed CAS;
                // guard-protected as above.
                unsafe { &*t.next.load(Ordering::Acquire) }
            }
        }
    }

    /// Trigger/help expansion when claimed slots cross ~0.7 of capacity.
    /// Claimed (not live) is the right load measure for open addressing:
    /// tombstoned entries still lengthen probes.
    fn maybe_expand(&self, guard: &Guard) {
        let t = self.root(guard);
        // ord: relaxed-ok — load-factor heuristic; an approximate count
        // only shifts when expansion triggers.
        let claimed = t.claimed.load(Ordering::Relaxed);
        if claimed * 10 <= t.len() * 7 {
            return;
        }
        let next = t.next.load(Ordering::Acquire);
        if next.is_null() {
            self.install_successor(t, guard);
            return;
        }
        // An expansion is already in flight: keep it moving and promote
        // when done, so chained expansions never stall waiting for the
        // maintenance thread.
        // SAFETY: non-null was just checked; successor tables are retired
        // only through EBR and we hold a guard.
        let next_ref = unsafe { &*next };
        self.migrate_span(t, next_ref, guard);
        self.try_promote(guard);
    }

    /// Claim and transfer one span of `t`'s slots. When every span is
    /// claimed but the table is not yet fully migrated (a claimant may be
    /// descheduled mid-span), sweep the whole table — transfers are
    /// idempotent, so helping twice is merely redundant.
    fn migrate_span(&self, t: &OaTable, next: &OaTable, guard: &Guard) {
        // ord: relaxed-ok — work-partitioning counter; fetch_add is
        // atomic regardless of ordering, and each slot transfer carries
        // its own publish/consume edges.
        let start = t.cursor.fetch_add(MIGRATE_SPAN, Ordering::Relaxed);
        if start >= t.len() {
            if !t.fully_migrated() {
                for idx in 0..t.len() {
                    self.migrate_slot(t, idx, next, guard);
                }
            }
            return;
        }
        let end = (start + MIGRATE_SPAN).min(t.len());
        for idx in start..end {
            self.migrate_slot(t, idx, next, guard);
        }
    }

    /// Drive one slot of `t` to its terminal migrated state: forwarded
    /// (was empty) or frozen with its item transferred. Exactly one
    /// helper performs each terminal transition and counts it.
    fn migrate_slot(&self, t: &OaTable, idx: usize, next: &OaTable, guard: &Guard) {
        loop {
            let w = t.slots[idx].load(Ordering::Acquire);
            match decode_slot(w) {
                SlotState::Empty => {
                    if t.slots[idx]
                        // ord: AcqRel — Release publishes the forwarded
                        // state (probes now treat the slot as terminal);
                        // Acquire orders our re-read against a racing
                        // claim's Release.
                        .compare_exchange(0, FWD_WORD, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        // ord: AcqRel — pairs with fully_migrated()'s
                        // Acquire: promotion proves every transfer
                        // happened-before it.
                        t.migrated.fetch_add(1, Ordering::AcqRel);
                        return;
                    }
                    // Lost to a late claim: re-read and freeze the entry.
                }
                SlotState::Fwd => return,
                SlotState::Resident { entry, frozen } => {
                    if !frozen
                        && t.slots[idx]
                            // ord: AcqRel — Release publishes the frozen
                            // tag; Acquire orders the entry reads below
                            // after the claim that published it.
                            .compare_exchange(w, w | SLOT_FRZ, Ordering::AcqRel, Ordering::Acquire)
                            .is_err()
                    {
                        continue; // slot word changed under us: re-read
                    }
                    // SAFETY: resident entries are freed only with their
                    // generation through EBR; we hold a guard.
                    let e = unsafe { &*entry };
                    loop {
                        let iw = e.item.load(Ordering::Acquire);
                        match decode_item(iw) {
                            // Another helper completed (and counted) it.
                            ItemState::Moved => return,
                            ItemState::Tomb => {
                                if e.item
                                    .compare_exchange(
                                        iw,
                                        MOVED_WORD,
                                        // ord: AcqRel — Release publishes the
                                        // moved state to writers (their CAS
                                        // fails and they descend); Acquire
                                        // pairs with the tombstoning CAS.
                                        Ordering::AcqRel,
                                        Ordering::Acquire,
                                    )
                                    .is_ok()
                                {
                                    // Nothing to relocate.
                                    // ord: AcqRel — see the forward case.
                                    t.migrated.fetch_add(1, Ordering::AcqRel);
                                    return;
                                }
                            }
                            ItemState::Live(item) => {
                                if e.item
                                    .compare_exchange(
                                        iw,
                                        MOVED_WORD,
                                        // ord: AcqRel — Acquire pairs with the
                                        // Release that published `item` (we
                                        // become its sole relocator); Release
                                        // publishes the moved state to racing
                                        // writers.
                                        Ordering::AcqRel,
                                        Ordering::Acquire,
                                    )
                                    .is_ok()
                                {
                                    self.install_migrated(next, e.hash, &e.key, item, guard);
                                    // ord: AcqRel — see the forward case.
                                    t.migrated.fetch_add(1, Ordering::AcqRel);
                                    return;
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// Re-insert a transferred item pointer into `start` (or deeper).
    /// This is the engine's *displacement*: the entry relocates, the item
    /// bytes do not move — the invariant lent GET slices rely on.
    fn install_migrated(
        &self,
        start: &OaTable,
        hash: u64,
        key: &[u8],
        item: *mut Item,
        guard: &Guard,
    ) {
        let mut t = start;
        let mut shell: *mut Entry = std::ptr::null_mut();
        loop {
            match probe(t, hash, key) {
                Probe::Found { entry, .. } => {
                    // A same-key entry already lives here. Within one hop
                    // this cannot happen (a writer only reaches the next
                    // generation after helping this very transfer to
                    // completion), so treat it defensively as a deeper
                    // newer value: the migrated item lost.
                    match decode_item(entry.item.load(Ordering::Acquire)) {
                        ItemState::Moved => {
                            t = self.descend(t, guard);
                            continue;
                        }
                        _ => {
                            Item::retire(guard, &self.slab, item);
                            // ord: relaxed-ok — accounting counter.
                            self.items.fetch_sub(1, Ordering::Relaxed);
                            break;
                        }
                    }
                }
                Probe::Empty { idx } => {
                    if shell.is_null() {
                        shell = Entry::alloc(hash, key, live_word(item));
                    }
                    match t.slots[idx].compare_exchange(
                        0,
                        shell as usize,
                        // ord: AcqRel — Release publishes the entry's
                        // hash/key/item fields; Acquire counterpart: the
                        // slot loads in probe.
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => {
                            shell = std::ptr::null_mut();
                            // ord: relaxed-ok — load heuristic counter.
                            t.claimed.fetch_add(1, Ordering::Relaxed);
                            // ord: relaxed-ok — accounting counter.
                            self.displacements.fetch_add(1, Ordering::Relaxed);
                            self.seed_clock(t, idx);
                            break;
                        }
                        Err(_) => continue, // slot changed: re-probe
                    }
                }
                Probe::Closed | Probe::Full => {
                    // This generation is closed/full for the key: push
                    // one level deeper (installing a deeper successor if
                    // migration outran expansion).
                    let next = t.next.load(Ordering::Acquire);
                    t = if next.is_null() {
                        self.install_successor(t, guard)
                    } else {
                        // SAFETY: guard-protected successor, as above.
                        unsafe { &*next }
                    };
                }
            }
        }
        if !shell.is_null() {
            // SAFETY: the shell was never published — we still
            // exclusively own the Box.
            unsafe { drop(Box::from_raw(shell)) };
        }
    }

    /// Allocate an item, driving reclamation and eviction on pressure.
    /// Runs UNPINNED (reclamation needs quiescence).
    fn alloc_item_pressured(
        &self,
        value: &[u8],
        flags: u32,
        deadline: u32,
        cas: u64,
    ) -> Result<*mut Item, StoreOutcome> {
        if ITEM_HEADER + value.len() > self.slab.chunk_size((self.slab.class_count() - 1) as u8) {
            return Err(StoreOutcome::TooLarge);
        }
        // Multi-tenant soft limits (mirrors FLeeC): an over-budget
        // tenant evicts from itself before touching the shared pool; if
        // the budget still refuses the allocation afterwards it gets
        // per-tenant OOM while other tenants keep storing.
        let tenant = crate::slab::tenant::current();
        let need = ITEM_HEADER + value.len();
        if self.slab.tenant_must_yield(tenant, need) {
            // ord: relaxed-ok — tuning knob; any recent value works.
            let batch = self.evict_batch.load(Ordering::Relaxed) as usize;
            for round in 0..OOM_ROUNDS {
                {
                    let guard = self.collector.pin();
                    self.evict_some_filtered(batch * (round + 1), &guard, Some(tenant));
                }
                // Attribution unwinds in the EBR reclaimer; drain limbo
                // before re-checking the budget.
                self.collector.force_reclaim(2);
                if !self.slab.tenant_must_yield(tenant, need) {
                    break;
                }
            }
            if self.slab.tenant_must_yield(tenant, need) {
                self.metrics.oom_stalls.inc();
                return Err(StoreOutcome::OutOfMemory);
            }
        }
        for round in 0..OOM_ROUNDS {
            if let Some(item) = Item::alloc(&self.slab, value, flags, deadline, cas) {
                return Ok(item);
            }
            self.metrics.oom_stalls.inc();
            // Publish this thread's parked chunks, then ask every other
            // registered thread to do the same at its next slab touch —
            // the flush-request flag closes the idle-magazine blind spot.
            self.slab.flush_local_magazines();
            self.slab.request_magazine_flush();
            // Paper order: reclaim limbo memory first (it is free memory
            // merely awaiting a grace period), evict only if that fails.
            self.collector.request_reclaim();
            self.collector.force_reclaim(2);
            if let Some(item) = Item::alloc(&self.slab, value, flags, deadline, cas) {
                return Ok(item);
            }
            {
                let guard = self.collector.pin();
                // ord: relaxed-ok — tuning knob; any recent value works.
                let batch = self.evict_batch.load(Ordering::Relaxed) as usize;
                self.evict_some(batch * (round + 1), &guard);
            }
            self.collector.force_reclaim(2);
        }
        Err(StoreOutcome::OutOfMemory)
    }

    /// Advance the CLOCK hand, decaying per-slot values and evicting
    /// zero-valued live slots, until `want` items were freed or two full
    /// revolutions found nothing. Sweeps the chain tail-first during
    /// expansion, like FLeeC, so memory in the successor is reachable.
    fn evict_some(&self, want: usize, guard: &Guard) -> usize {
        self.evict_some_filtered(want, guard, None)
    }

    /// [`Self::evict_some`] with an optional tenant filter: when set,
    /// only items stamped with that tenant are victims — the
    /// self-eviction half of per-tenant soft limits.
    fn evict_some_filtered(&self, want: usize, guard: &Guard, tenant: Option<u8>) -> usize {
        let mut chain: Vec<&OaTable> = Vec::with_capacity(2);
        let mut t = self.root(guard);
        loop {
            chain.push(t);
            let next = t.next.load(Ordering::Acquire);
            if next.is_null() {
                break;
            }
            // SAFETY: chain tables are retired only through EBR after the
            // root swings past them; the guard keeps `next` live.
            t = unsafe { &*next };
        }
        // ord: relaxed-ok — tuning knob; any recent value works.
        let decay = self.evict_decay.load(Ordering::Relaxed).max(1);
        let mut freed = 0usize;
        for t in chain.iter().rev() {
            let size = t.len();
            let mut scanned = 0usize;
            while freed < want && scanned < 2 * size {
                // ord: relaxed-ok — CLOCK-hand position; any interleaving
                // of increments is a valid sweep order.
                let idx = t.hand.fetch_add(1, Ordering::Relaxed) & t.mask;
                scanned += 1;
                // ord: relaxed-ok — CLOCK eviction heuristic; a stale
                // value only skews victim choice.
                let c = t.clocks[idx].load(Ordering::Relaxed);
                if c > 0 {
                    // Racy decrement is fine: losing a race just means
                    // another sweeper already decremented.
                    let _ = t.clocks[idx].compare_exchange(
                        c,
                        c.saturating_sub(decay),
                        // ord: relaxed-ok — CLOCK heuristic (both
                        // orderings); a lost race only skews victims.
                        Ordering::Relaxed,
                        // ord: relaxed-ok — as above.
                        Ordering::Relaxed,
                    );
                    continue;
                }
                freed += self.evict_slot(t, idx, guard, tenant);
            }
            if freed >= want {
                break;
            }
        }
        freed
    }

    /// Tombstone one slot's live item (CLOCK victim). Frozen slots are
    /// skipped — migration owns them and the memory is seconds from being
    /// reachable in the successor anyway.
    fn evict_slot(&self, t: &OaTable, idx: usize, guard: &Guard, tenant: Option<u8>) -> usize {
        let w = t.slots[idx].load(Ordering::Acquire);
        if let SlotState::Resident {
            entry,
            frozen: false,
        } = decode_slot(w)
        {
            // SAFETY: resident entries are freed only with their
            // generation through EBR; we hold a guard.
            let e = unsafe { &*entry };
            let iw = e.item.load(Ordering::Acquire);
            if let ItemState::Live(item) = decode_item(iw) {
                // SAFETY: the guard keeps `item` live (retirement goes
                // through EBR) and headers are immutable — the tenant
                // stamp read cannot tear or dangle.
                if tenant.is_some_and(|want| unsafe { (*item).tenant } != want) {
                    return 0;
                }
                if e.item
                    // ord: AcqRel — Acquire pairs with the Release of the
                    // install CAS that published `item` (safe to retire);
                    // Release publishes the tombstone to writers whose
                    // item CAS now fails.
                    .compare_exchange(iw, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    Item::retire(guard, &self.slab, item);
                    // ord: relaxed-ok — accounting counter; stats
                    // tolerate racy snapshots.
                    self.items.fetch_sub(1, Ordering::Relaxed);
                    self.metrics.evictions.inc();
                    return 1;
                }
            }
        }
        0
    }

    /// Lazily expire an entry's item (tombstone + retire). Returns true
    /// if we won the race.
    fn expire_entry(&self, entry: &Entry, item_word: usize, item: *mut Item, guard: &Guard) -> bool {
        if entry
            .item
            // ord: AcqRel — Acquire pairs with the Release of the install
            // CAS that published `item`; Release publishes the tombstone
            // to writers whose item CAS now fails.
            .compare_exchange(item_word, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            Item::retire(guard, &self.slab, item);
            // ord: relaxed-ok — accounting counter; stats tolerate racy
            // snapshots.
            self.items.fetch_sub(1, Ordering::Relaxed);
            self.metrics.expired.inc();
            true
        } else {
            false
        }
    }

    /// Shared store path (see [`FleecCache::store`]'s precondition table —
    /// identical semantics).
    ///
    /// [`FleecCache::store`]: crate::cache::fleec::FleecCache
    fn store(
        &self,
        key: &[u8],
        value: &[u8],
        flags: u32,
        exptime: u32,
        mode: StoreMode,
    ) -> StoreOutcome {
        if key.len() > MAX_KEY_LEN || key.is_empty() {
            return StoreOutcome::NotStored;
        }
        self.metrics.sets.inc();
        let deadline = deadline_from_exptime(exptime);
        let item = match self.alloc_item_pressured(value, flags, deadline, 0) {
            Ok(i) => i,
            Err(e) => return e,
        };
        let hash = hash_key(key);
        let guard = self.collector.pin();
        self.store_prealloc(key, hash, item, mode, &guard)
    }

    /// Install a pre-allocated `item` under `key` (metrics-free; the
    /// caller counted the set and may hold a batch-wide guard). Owns
    /// `item`: frees it on any non-`Stored` outcome. The CAS token is
    /// stamped here — at install time — so batched runs hand out tokens
    /// in execution order, exactly like FLeeC.
    ///
    /// Three install shapes, all one CAS: overwrite a live entry's item
    /// word, **revive** a tombstoned entry (the claim is reused — this is
    /// what bounds slot consumption to distinct-keys-per-generation), or
    /// claim the window's first empty slot with a fresh entry.
    fn store_prealloc(
        &self,
        key: &[u8],
        hash: u64,
        item: *mut Item,
        mode: StoreMode,
        guard: &Guard,
    ) -> StoreOutcome {
        // ord: relaxed-ok — the counter only needs uniqueness; the
        // install CAS's Release publishes the stamped token.
        let cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
        // SAFETY: `item` is exclusively ours — unpublished until the
        // install CAS below.
        unsafe { (*item).cas = cas };
        let mut shell: *mut Entry = std::ptr::null_mut();
        let outcome = loop {
            let (t, spot) = self.locate_for_write(hash, key, guard);
            match spot {
                Spot::Found { idx, entry } => {
                    let w = entry.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(old) => {
                            // SAFETY: `old` was live under the guard;
                            // published items retire only through EBR, so
                            // the header outlives our pin.
                            let expired = is_expired(unsafe { (*old).deadline });
                            if expired && self.expire_entry(entry, w, old, guard) {
                                continue; // now tombstoned; loop decides
                            }
                            match mode {
                                StoreMode::Add => break StoreOutcome::NotStored,
                                // SAFETY: guard-protected live item, as
                                // above.
                                StoreMode::Cas(expect) if unsafe { (*old).cas } != expect => {
                                    break StoreOutcome::Exists;
                                }
                                _ => {}
                            }
                            if entry
                                .item
                                .compare_exchange(
                                    w,
                                    live_word(item),
                                    // ord: AcqRel — Release publishes the new
                                    // item's bytes and token (Acquire
                                    // counterpart: item loads in get_view /
                                    // rmw paths); Acquire pairs with the
                                    // Release that published `old`, so the
                                    // retire below is well-founded.
                                    Ordering::AcqRel,
                                    Ordering::Acquire,
                                )
                                .is_ok()
                            {
                                Item::retire(guard, &self.slab, old);
                                self.touch_clock(t, idx);
                                break StoreOutcome::Stored;
                            }
                            // Raced with another writer/evictor: retry.
                        }
                        ItemState::Tomb => {
                            // Absent. Revive the entry's claim for
                            // set/add; replace/cas miss.
                            match mode {
                                StoreMode::Replace | StoreMode::Cas(_) => {
                                    break StoreOutcome::NotFound;
                                }
                                _ => {}
                            }
                            if entry
                                .item
                                .compare_exchange(
                                    TOMB_WORD,
                                    live_word(item),
                                    // ord: AcqRel — Release publishes the
                                    // revived item's bytes and token; Acquire
                                    // pairs with the tombstoning CAS, so the
                                    // revival happens-after the delete it
                                    // overwrites.
                                    Ordering::AcqRel,
                                    Ordering::Acquire,
                                )
                                .is_ok()
                            {
                                // ord: relaxed-ok — accounting counter.
                                self.items.fetch_add(1, Ordering::Relaxed);
                                self.seed_clock(t, idx);
                                break StoreOutcome::Stored;
                            }
                            // Lost a revival/transfer race: retry.
                        }
                        ItemState::Moved => continue, // re-locate deeper
                    }
                }
                Spot::Empty { idx } => {
                    match mode {
                        StoreMode::Replace | StoreMode::Cas(_) => break StoreOutcome::NotFound,
                        _ => {}
                    }
                    if shell.is_null() {
                        shell = Entry::alloc(hash, key, live_word(item));
                    }
                    match t.slots[idx].compare_exchange(
                        0,
                        shell as usize,
                        // ord: AcqRel — Release publishes the entry's
                        // hash/key/item fields; Acquire counterpart: the
                        // slot loads in probe.
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => {
                            shell = std::ptr::null_mut(); // published
                            // ord: relaxed-ok — load heuristic counter.
                            t.claimed.fetch_add(1, Ordering::Relaxed);
                            // ord: relaxed-ok — accounting counter.
                            self.items.fetch_add(1, Ordering::Relaxed);
                            self.seed_clock(t, idx);
                            self.maybe_expand(guard);
                            break StoreOutcome::Stored;
                        }
                        Err(_) => {} // slot changed: re-locate
                    }
                }
                Spot::Full => {
                    // Window exhausted in the deepest generation: the key
                    // is authoritatively absent here.
                    match mode {
                        StoreMode::Replace | StoreMode::Cas(_) => break StoreOutcome::NotFound,
                        _ => {}
                    }
                    // Force an expansion round, then retry (the next
                    // locate descends into the successor).
                    self.install_successor(t, guard);
                }
            }
        };
        if !shell.is_null() {
            // SAFETY: the shell was never published — we still
            // exclusively own the Box.
            unsafe { drop(Box::from_raw(shell)) };
        }
        if outcome != StoreOutcome::Stored {
            // SAFETY: on every non-Stored outcome the item was never
            // published — no reader can hold it, free directly.
            unsafe { Item::dealloc(&self.slab, item) };
        }
        outcome
    }

    /// Resolve one staged storage op from the batch pre-allocation phase.
    fn finish_staged(
        &self,
        key: &[u8],
        hash: u64,
        stage: Stage,
        mode: StoreMode,
        guard: &Guard,
    ) -> StoreOutcome {
        match stage {
            Stage::Store(Ok(item)) => self.store_prealloc(key, hash, item, mode, guard),
            Stage::Store(Err(e)) => e,
            Stage::Pass => unreachable!("storage op was not staged in phase A"),
        }
    }

    /// Record one probe outcome's length (slots examined before the scan
    /// became authoritative) into the probe histogram. Distance units —
    /// a home-slot hit records 1. Called only on sampled batches.
    fn note_probe(&self, t: &OaTable, hash: u64, p: &Probe<'_>) {
        let len = match *p {
            Probe::Found { idx, .. } | Probe::Empty { idx } => {
                (idx.wrapping_sub(t.home(hash)) & t.mask) as u64 + 1
            }
            Probe::Full => PROBE_WINDOW.min(t.len()) as u64,
            // A forwarded slot ends the scan at an unknown depth.
            Probe::Closed => return,
        };
        self.oa_probe.record(len);
    }

    /// Guard-passing lookup core (metrics-free), shared by the single-key
    /// path and the batched fast path. Returns the hit's
    /// `(flags, cas, data)` with the value bytes **borrowed at the
    /// guard's lifetime** — zero copy.
    ///
    /// SOUNDNESS of the `'g` borrow: identical to FLeeC's
    /// (`FleecCache::get_view`) — every path that unpublishes a live item
    /// (overwrite, delete, eviction, expiry, migration's superseded-drop
    /// and `flush_all`) retires it through [`Item::retire`], i.e. through
    /// EBR; nothing frees a *published* item's chunk directly. Migration
    /// is the one new mechanic, and it moves the item *pointer* between
    /// entries — never the bytes — so a lent slice survives arbitrary
    /// concurrent relocation. Direct `slab.free` exists only for items
    /// that were never published (failed stores, lost RMW speculation).
    ///
    /// Miss authority: an `Empty` probe result is terminal — a key can
    /// only reach a deeper generation by its entry being frozen+moved or
    /// its window being closed (forwarded slot) or full, all of which
    /// this probe would have seen first. `Closed`/`Full` descend.
    fn get_view<'g>(&self, key: &[u8], hash: u64, guard: &'g Guard) -> Option<(u32, u64, &'g [u8])> {
        // ord: relaxed-ok — stats-grade sampling flag; reading it stale
        // merely drops or adds a few probe-length samples.
        let sampling = self.probe_sample.load(Ordering::Relaxed);
        let mut t = self.root(guard);
        loop {
            let p = probe(t, hash, key);
            if sampling {
                self.note_probe(t, hash, &p);
            }
            match p {
                Probe::Found { idx, entry } => {
                    let w = entry.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(item) => {
                            // SAFETY: live item observed under the guard;
                            // see the SOUNDNESS note in the fn doc.
                            let hdr = unsafe { &*item };
                            if is_expired(hdr.deadline) {
                                self.expire_entry(entry, w, item, guard);
                                return None;
                            }
                            // SAFETY: the `'g` borrow is sound per the
                            // SOUNDNESS note in the fn doc.
                            // guard-stable: the lent slice lives in the
                            // item's slab chunk; retirement is deferred
                            // past every pinned guard, and migration
                            // relocates pointers, not bytes.
                            let data: &'g [u8] = unsafe { Item::data(item) };
                            self.touch_clock(t, idx);
                            return Some((hdr.flags, hdr.cas, data));
                        }
                        // Tombstone is an authoritative miss: revival
                        // happens in place, never in a deeper generation
                        // while this entry is visible.
                        ItemState::Tomb => return None,
                        ItemState::Moved => {
                            let next = t.next.load(Ordering::Acquire);
                            if next.is_null() {
                                return None;
                            }
                            // SAFETY: guard-protected successor table —
                            // chain tables retire only through EBR.
                            t = unsafe { &*next };
                        }
                    }
                }
                Probe::Empty { .. } => return None,
                Probe::Closed | Probe::Full => {
                    let next = t.next.load(Ordering::Acquire);
                    if next.is_null() {
                        return None;
                    }
                    // SAFETY: guard-protected successor table, as above.
                    t = unsafe { &*next };
                }
            }
        }
    }

    /// Owning wrapper over [`OaFlashCache::get_view`].
    fn get_in(&self, key: &[u8], hash: u64, guard: &Guard) -> Option<GetResult> {
        self.get_view(key, hash, guard).map(|(flags, cas, data)| GetResult {
            data: data.to_vec(),
            flags,
            cas,
        })
    }

    /// Guard-passing delete core (metrics-free).
    fn delete_in(&self, key: &[u8], hash: u64, guard: &Guard) -> bool {
        loop {
            let (_, spot) = self.locate_for_write(hash, key, guard);
            match spot {
                Spot::Found { entry, .. } => {
                    let w = entry.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(item) => {
                            if entry
                                .item
                                // ord: AcqRel — Acquire pairs with the
                                // Release that published `item`; Release
                                // publishes the tombstone to racing
                                // writers.
                                .compare_exchange(w, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                                .is_ok()
                            {
                                Item::retire(guard, &self.slab, item);
                                // ord: relaxed-ok — accounting counter;
                                // stats tolerate racy snapshots.
                                self.items.fetch_sub(1, Ordering::Relaxed);
                                return true;
                            }
                        }
                        ItemState::Tomb => return false,
                        ItemState::Moved => continue,
                    }
                }
                Spot::Empty { .. } | Spot::Full => return false,
            }
        }
    }

    /// Phase-1 snapshot for [`OaFlashCache::rmw`]: the current token +
    /// header + value bytes, or `None` (lazy expiry applied).
    fn rmw_snapshot(
        &self,
        key: &[u8],
        hash: u64,
        guard: &Guard,
    ) -> Option<(u64, u32, u32, Vec<u8>)> {
        let mut t = self.root(guard);
        loop {
            match probe(t, hash, key) {
                Probe::Found { entry, .. } => {
                    let w = entry.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(item) => {
                            // SAFETY: live item observed under the guard;
                            // published items retire only through EBR.
                            let hdr = unsafe { &*item };
                            if is_expired(hdr.deadline) {
                                self.expire_entry(entry, w, item, guard);
                                return None;
                            }
                            return Some((
                                hdr.cas,
                                hdr.flags,
                                hdr.deadline,
                                // SAFETY: guard-protected live item, as
                                // above.
                                unsafe { Item::data(item) }.to_vec(),
                            ));
                        }
                        ItemState::Tomb => return None,
                        ItemState::Moved => {
                            let next = t.next.load(Ordering::Acquire);
                            if next.is_null() {
                                return None;
                            }
                            // SAFETY: guard-protected successor table.
                            t = unsafe { &*next };
                        }
                    }
                }
                Probe::Empty { .. } => return None,
                Probe::Closed | Probe::Full => {
                    let next = t.next.load(Ordering::Acquire);
                    if next.is_null() {
                        return None;
                    }
                    // SAFETY: guard-protected successor table, as above.
                    t = unsafe { &*next };
                }
            }
        }
    }

    /// Phase-3 token-guarded install for [`OaFlashCache::rmw`]: succeeds
    /// iff the key still holds the snapshotted token. Does **not** free
    /// `item` on failure — the caller owns the retry.
    fn install_rmw(&self, key: &[u8], hash: u64, token: u64, item: *mut Item, guard: &Guard) -> bool {
        loop {
            let (_, spot) = self.locate_for_write(hash, key, guard);
            match spot {
                Spot::Found { entry, .. } => {
                    let w = entry.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(old) => {
                            // SAFETY: live item observed under the guard;
                            // published items retire only through EBR.
                            if unsafe { (*old).cas } != token {
                                return false;
                            }
                            // Stamp the token at install time so batched
                            // runs hand out tokens in execution order.
                            // ord: relaxed-ok — uniqueness only; the
                            // install CAS's Release publishes the stamp.
                            let cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
                            // SAFETY: `item` is exclusively ours until the
                            // CAS below publishes it.
                            unsafe { (*item).cas = cas };
                            if entry
                                .item
                                .compare_exchange(
                                    w,
                                    live_word(item),
                                    // ord: AcqRel — Release publishes the new
                                    // item's bytes and token; Acquire pairs
                                    // with the Release that published `old`,
                                    // grounding the retire below.
                                    Ordering::AcqRel,
                                    Ordering::Acquire,
                                )
                                .is_ok()
                            {
                                Item::retire(guard, &self.slab, old);
                                return true;
                            }
                            // Raced with another writer: the token test
                            // decides next round.
                        }
                        ItemState::Tomb => return false,
                        ItemState::Moved => continue,
                    }
                }
                Spot::Empty { .. } | Spot::Full => return false,
            }
        }
    }

    /// Read-modify-write with the CAS-token race detector — the same
    /// three-phase snapshot → unpinned transform+alloc → token-guarded
    /// install protocol as FLeeC's (`FleecCache::rmw`).
    fn rmw(
        &self,
        key: &[u8],
        f: impl Fn(u32, u32, &[u8]) -> Option<(Vec<u8>, u32, u32)>,
    ) -> RmwResult {
        let hash = hash_key(key);
        loop {
            let snap = {
                let guard = self.collector.pin();
                self.rmw_snapshot(key, hash, &guard)
            };
            let Some((token, flags, deadline, data)) = snap else {
                return RmwResult::NotFound;
            };
            let (new_value, new_flags, new_deadline) = match f(flags, deadline, &data) {
                Some(v) => v,
                None => return RmwResult::Aborted,
            };
            let item = match self.alloc_item_pressured(&new_value, new_flags, new_deadline, 0) {
                Ok(i) => i,
                Err(e) => return RmwResult::Failed(e),
            };
            let guard = self.collector.pin();
            if self.install_rmw(key, hash, token, item, &guard) {
                return RmwResult::Done(new_value);
            }
            // Token moved under us: free the speculative item and retry.
            // SAFETY: the speculative item was never published — no
            // reader can hold it, free directly.
            unsafe { Item::dealloc(&self.slab, item) };
        }
    }

    /// `flush_all` helper: tombstone one slot's item regardless of CLOCK
    /// or freeze state (no eviction metrics — protocol flush is not
    /// cache pressure) and reset the slot's CLOCK.
    fn flush_slot(&self, t: &OaTable, idx: usize, guard: &Guard) {
        let w = t.slots[idx].load(Ordering::Acquire);
        if let SlotState::Resident { entry, .. } = decode_slot(w) {
            // SAFETY: resident entries are freed only with their
            // generation through EBR; we hold a guard.
            let e = unsafe { &*entry };
            loop {
                let iw = e.item.load(Ordering::Acquire);
                match decode_item(iw) {
                    ItemState::Live(item) => {
                        if e.item
                            // ord: AcqRel — Acquire pairs with the Release
                            // that published `item`; Release publishes
                            // the tombstone to racing writers.
                            .compare_exchange(iw, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                            .is_ok()
                        {
                            Item::retire(guard, &self.slab, item);
                            // ord: relaxed-ok — accounting counter.
                            self.items.fetch_sub(1, Ordering::Relaxed);
                            break;
                        }
                    }
                    ItemState::Tomb | ItemState::Moved => break,
                }
            }
        }
        // ord: relaxed-ok — CLOCK eviction heuristic reset.
        t.clocks[idx].store(0, Ordering::Relaxed);
    }
}

impl Cache for OaFlashCache {
    fn engine_name(&self) -> &'static str {
        "oaflash"
    }

    /// The batched fast path — FLeeC's shape on the open-addressing
    /// table:
    ///
    /// * **One EBR guard** pinned for the whole batch; GET hits are
    ///   delivered zero-copy ([`OaFlashCache::get_view`] — the batch
    ///   guard keeps every lent slice byte-stable until return, even
    ///   across concurrent generation migration).
    /// * Keys are **pre-hashed** and home slots touched in ascending
    ///   order so execution finds the lines resident.
    /// * Items for plain storage ops are **pre-allocated before
    ///   pinning** (allocation may force reclamation, which wants
    ///   quiescence); tokens are stamped at install, so the token
    ///   sequence matches a sequential run.
    /// * **RMW ops run the classic three-phase loop at their turn**
    ///   (re-entrant pin under the batch guard). This is a deliberate
    ///   simplification over FLeeC's speculative RMW staging: semantics
    ///   are identical; the cost is that an RMW op's allocation happens
    ///   under the held guard, so epoch advancement under memory
    ///   pressure is slightly more constrained for RMW-heavy batches.
    /// * Metrics are **batched**: one counter add per counter per batch.
    fn execute_batch_into(&self, ops: &[Op<'_>], sink: &mut dyn BatchSink) {
        if ops.is_empty() {
            return;
        }
        let hashes: Vec<u64> = ops.iter().map(|op| hash_key(op.key())).collect();

        // Phase A (unpinned): validate keys and pre-allocate storage
        // items.
        let mut staged: Vec<Stage> = Vec::with_capacity(ops.len());
        let mut sets = 0u64;
        for op in ops {
            let stage = match *op {
                Op::Set {
                    key,
                    value,
                    flags,
                    exptime,
                }
                | Op::Add {
                    key,
                    value,
                    flags,
                    exptime,
                }
                | Op::Replace {
                    key,
                    value,
                    flags,
                    exptime,
                }
                | Op::CasOp {
                    key,
                    value,
                    flags,
                    exptime,
                    ..
                } => {
                    if key.len() > MAX_KEY_LEN || key.is_empty() {
                        Stage::Store(Err(StoreOutcome::NotStored))
                    } else {
                        sets += 1;
                        let deadline = deadline_from_exptime(exptime);
                        // Token 0 here; store_prealloc stamps the real one
                        // at install time to keep sequential ordering.
                        Stage::Store(self.alloc_item_pressured(value, flags, deadline, 0))
                    }
                }
                _ => Stage::Pass,
            };
            staged.push(stage);
        }

        // Phase B (pinned once): prefetch home slots, then execute in
        // batch order under the single guard.
        let (mut gets, mut hits, mut misses, mut deletes) = (0u64, 0u64, 0u64, 0u64);
        // Sampled clock (same shape as FLeeC's): one relaxed tick decides
        // whether this batch reads `Instant::now` per op and records
        // probe lengths; non-sampled batches pay one predictable branch.
        let timed = self.latency.sample_batch(self.config.latency_sample);
        {
            let guard = self.collector.pin();
            if timed {
                // ord: relaxed-ok — stats-grade sampling flag (see the
                // field doc); no data is ordered against it.
                self.probe_sample.store(true, Ordering::Relaxed);
            }
            if ops.len() > 1 {
                let t = self.root(&guard);
                let mut order: Vec<u32> = (0..ops.len() as u32).collect();
                order.sort_unstable_by_key(|&i| t.home(hashes[i as usize]));
                for &i in &order {
                    // ord: relaxed-ok — cache-line prefetch; the value is
                    // discarded and re-loaded with Acquire at execution.
                    let _ = t.slots[t.home(hashes[i as usize])].load(Ordering::Relaxed);
                }
            }
            for (i, op) in ops.iter().enumerate() {
                let t0 = if timed { Some(std::time::Instant::now()) } else { None };
                let hash = hashes[i];
                match *op {
                    Op::Get { key } => {
                        gets += 1;
                        match self.get_view(key, hash, &guard) {
                            Some((flags, cas, data)) => {
                                hits += 1;
                                sink.value(i, key, flags, cas, data);
                            }
                            None => {
                                misses += 1;
                                sink.miss(i);
                            }
                        }
                    }
                    Op::Set { key, .. } => sink.store(
                        i,
                        self.finish_staged(key, hash, staged[i], StoreMode::Set, &guard),
                    ),
                    Op::Add { key, .. } => sink.store(
                        i,
                        self.finish_staged(key, hash, staged[i], StoreMode::Add, &guard),
                    ),
                    Op::Replace { key, .. } => sink.store(
                        i,
                        self.finish_staged(key, hash, staged[i], StoreMode::Replace, &guard),
                    ),
                    Op::CasOp { key, cas, .. } => sink.store(
                        i,
                        self.finish_staged(key, hash, staged[i], StoreMode::Cas(cas), &guard),
                    ),
                    Op::Delete { key } => {
                        deletes += 1;
                        sink.deleted(i, self.delete_in(key, hash, &guard));
                    }
                    // RMW ops: classic loop at their turn (re-entrant pin
                    // under the batch guard) — see the method docs.
                    Op::Append { key, suffix } => sink.store(i, self.append(key, suffix)),
                    Op::Prepend { key, prefix } => sink.store(i, self.prepend(key, prefix)),
                    Op::Incr { key, delta } => sink.counter(i, self.incr(key, delta)),
                    Op::Decr { key, delta } => sink.counter(i, self.decr(key, delta)),
                    Op::Touch { key, exptime } => sink.touched(i, self.touch(key, exptime)),
                }
                if let Some(t0) = t0 {
                    self.latency
                        .record(op.class(), t0.elapsed().as_nanos() as u64);
                }
            }
            if timed {
                // ord: relaxed-ok — as the store above.
                self.probe_sample.store(false, Ordering::Relaxed);
            }
        }

        // Phase C: one counter update each for the whole batch.
        if gets > 0 {
            self.metrics.gets.add(gets);
            self.metrics.hits.add(hits);
            self.metrics.misses.add(misses);
        }
        if sets > 0 {
            self.metrics.sets.add(sets);
        }
        if deletes > 0 {
            self.metrics.deletes.add(deletes);
        }
    }

    fn get(&self, key: &[u8]) -> Option<GetResult> {
        self.metrics.gets.inc();
        let hash = hash_key(key);
        let guard = self.collector.pin();
        let r = self.get_in(key, hash, &guard);
        if r.is_some() {
            self.metrics.hits.inc();
        } else {
            self.metrics.misses.inc();
        }
        r
    }

    fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Set)
    }

    fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Add)
    }

    fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Replace)
    }

    fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Cas(cas))
    }

    fn append(&self, key: &[u8], suffix: &[u8]) -> StoreOutcome {
        match self.rmw(key, |flags, deadline, old| {
            let mut v = Vec::with_capacity(old.len() + suffix.len());
            v.extend_from_slice(old);
            v.extend_from_slice(suffix);
            Some((v, flags, deadline))
        }) {
            RmwResult::Done(_) => StoreOutcome::Stored,
            RmwResult::NotFound | RmwResult::Aborted => StoreOutcome::NotStored,
            RmwResult::Failed(e) => e,
        }
    }

    fn prepend(&self, key: &[u8], prefix: &[u8]) -> StoreOutcome {
        match self.rmw(key, |flags, deadline, old| {
            let mut v = Vec::with_capacity(old.len() + prefix.len());
            v.extend_from_slice(prefix);
            v.extend_from_slice(old);
            Some((v, flags, deadline))
        }) {
            RmwResult::Done(_) => StoreOutcome::Stored,
            RmwResult::NotFound | RmwResult::Aborted => StoreOutcome::NotStored,
            RmwResult::Failed(e) => e,
        }
    }

    fn delete(&self, key: &[u8]) -> bool {
        self.metrics.deletes.inc();
        let hash = hash_key(key);
        let guard = self.collector.pin();
        self.delete_in(key, hash, &guard)
    }

    fn incr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut result = None;
        let out = self.rmw(key, |flags, deadline, old| {
            let n = parse_counter(old)?;
            let v = n.wrapping_add(delta);
            Some((v.to_string().into_bytes(), flags, deadline))
        });
        if let RmwResult::Done(v) = out {
            result = std::str::from_utf8(&v).ok()?.parse().ok();
        }
        result
    }

    fn decr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut result = None;
        let out = self.rmw(key, |flags, deadline, old| {
            let n = parse_counter(old)?;
            let v = n.saturating_sub(delta);
            Some((v.to_string().into_bytes(), flags, deadline))
        });
        if let RmwResult::Done(v) = out {
            result = std::str::from_utf8(&v).ok()?.parse().ok();
        }
        result
    }

    fn touch(&self, key: &[u8], exptime: u32) -> bool {
        let deadline = deadline_from_exptime(exptime);
        matches!(
            self.rmw(key, |flags, _old_deadline, old| Some((
                old.to_vec(),
                flags,
                deadline
            ))),
            RmwResult::Done(_)
        )
    }

    fn flush_all(&self) {
        let guard = self.collector.pin();
        let mut t = self.root(&guard);
        loop {
            for idx in 0..t.len() {
                self.flush_slot(t, idx, &guard);
            }
            let next = t.next.load(Ordering::Acquire);
            if next.is_null() {
                break;
            }
            // SAFETY: guard-protected successor table — chain tables
            // retire only through EBR.
            t = unsafe { &*next };
        }
    }

    fn item_count(&self) -> usize {
        // ord: relaxed-ok — approximate counter by contract.
        self.items.load(Ordering::Relaxed)
    }

    fn bucket_count(&self) -> usize {
        let guard = self.collector.pin();
        self.root(&guard).len()
    }

    fn stats(&self) -> StatsSnapshot {
        let mut internals = crate::cache::substrate_internals(&self.collector, &self.slab);
        // ord: relaxed-ok — accounting counter; stats tolerate racy
        // snapshots.
        internals.oa_migrations = self.migrations.load(Ordering::Relaxed);
        internals.oa_displacements = self.displacements();
        internals.oa_probe = self.oa_probe.snapshot();
        StatsSnapshot {
            metrics: self.metrics.snapshot(),
            items: self.item_count(),
            buckets: self.bucket_count(),
            mem_used: self.mem_used(),
            mem_limit: self.mem_limit(),
            latency: self.latency.snapshot(),
            internals,
            slabs: crate::cache::slab_class_snapshots(&self.slab),
        }
    }

    fn mem_used(&self) -> usize {
        self.slab
            .class_stats()
            .iter()
            .map(|c| c.live_chunks * c.chunk_size)
            .sum()
    }

    fn mem_limit(&self) -> usize {
        self.config.mem_limit
    }

    fn tenant_slabs(&self) -> Vec<Arc<crate::slab::Slab>> {
        vec![Arc::clone(&self.slab)]
    }

    fn maintenance(&self) {
        let guard = self.collector.pin();
        let root = self.root(&guard);
        let next = root.next.load(Ordering::Acquire);
        if !next.is_null() {
            // SAFETY: guard-protected successor table — chain tables
            // retire only through EBR.
            let next_ref = unsafe { &*next };
            for idx in 0..root.len() {
                self.migrate_slot(root, idx, next_ref, &guard);
            }
            self.try_promote(&guard);
        }
    }

    fn clock_snapshot(&self) -> Option<Vec<u8>> {
        let guard = self.collector.pin();
        let t = self.root(&guard);
        Some(
            t.clocks
                .iter()
                // ord: relaxed-ok — diagnostic snapshot of the CLOCK
                // values; racy by nature.
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
        )
    }

    fn set_evict_params(&self, decay: u8, batch: u32) {
        // ord: relaxed-ok — tuning knobs (both stores); no data is
        // ordered against them.
        self.evict_decay.store(decay.max(1), Ordering::Relaxed);
        // ord: relaxed-ok — as above.
        self.evict_batch.store(batch.max(1), Ordering::Relaxed);
    }
}

impl Drop for OaFlashCache {
    fn drop(&mut self) {
        // Exclusive access: free the whole generation chain. Entries are
        // freed by OaTable::drop; item chunks die with the slab pages;
        // anything retired into the collector frees when it drains.
        let mut t = *self.table.get_mut();
        while !t.is_null() {
            // SAFETY: `&mut self` in drop — exclusive access; every table
            // in the chain is owned by the cache until this point.
            let boxed = unsafe { Box::from_raw(t) };
            // ord: relaxed-ok — exclusive access in drop.
            t = boxed.next.load(Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::op::execute_sequential;
    use crate::sync::Xoshiro256;

    fn small() -> OaFlashCache {
        OaFlashCache::new(CacheConfig::small())
    }

    fn root_claimed(c: &OaFlashCache) -> usize {
        let g = c.collector.pin();
        c.root(&g).claimed.load(Ordering::Relaxed)
    }

    #[test]
    fn set_get_roundtrip_with_metadata() {
        let c = small();
        assert_eq!(c.set(b"k", b"value", 77, 0), StoreOutcome::Stored);
        let r = c.get(b"k").unwrap();
        assert_eq!(r.data, b"value");
        assert_eq!(r.flags, 77);
        assert!(r.cas > 0);
        assert_eq!(c.item_count(), 1);
    }

    #[test]
    fn set_overwrites_and_bumps_cas() {
        let c = small();
        c.set(b"k", b"v1", 0, 0);
        let cas1 = c.get(b"k").unwrap().cas;
        c.set(b"k", b"v2", 0, 0);
        let r = c.get(b"k").unwrap();
        assert_eq!(r.data, b"v2");
        assert!(r.cas > cas1);
        assert_eq!(c.item_count(), 1, "overwrite must not grow the count");
    }

    #[test]
    fn add_replace_semantics() {
        let c = small();
        assert_eq!(c.replace(b"k", b"x", 0, 0), StoreOutcome::NotFound);
        assert_eq!(c.add(b"k", b"1", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.add(b"k", b"2", 0, 0), StoreOutcome::NotStored);
        assert_eq!(c.replace(b"k", b"3", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"3");
    }

    #[test]
    fn cas_token_gating() {
        let c = small();
        c.set(b"k", b"v1", 0, 0);
        let tok = c.get(b"k").unwrap().cas;
        assert_eq!(c.cas(b"k", b"v2", 0, 0, tok), StoreOutcome::Stored);
        assert_eq!(c.cas(b"k", b"v3", 0, 0, tok), StoreOutcome::Exists);
        assert_eq!(c.cas(b"missing", b"x", 0, 0, 1), StoreOutcome::NotFound);
        assert_eq!(c.get(b"k").unwrap().data, b"v2");
    }

    #[test]
    fn delete_then_reinsert_revives_the_claim() {
        let c = small();
        c.set(b"k", b"v", 0, 0);
        let claims = root_claimed(&c);
        assert!(c.delete(b"k"));
        assert!(!c.delete(b"k"));
        assert!(c.get(b"k").is_none());
        assert_eq!(c.item_count(), 0);
        assert_eq!(c.set(b"k", b"v2", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"v2");
        // Revival must reuse the tombstoned claim, not burn a new slot —
        // what bounds slot consumption to distinct keys per generation.
        assert_eq!(root_claimed(&c), claims, "revival must not claim a new slot");
    }

    #[test]
    fn incr_decr_arithmetic() {
        let c = small();
        c.set(b"n", b"10", 0, 0);
        assert_eq!(c.incr(b"n", 5), Some(15));
        assert_eq!(c.decr(b"n", 3), Some(12));
        assert_eq!(c.decr(b"n", 100), Some(0), "decr saturates at 0");
        assert_eq!(c.incr(b"missing", 1), None);
        c.set(b"s", b"not-a-number", 0, 0);
        assert_eq!(c.incr(b"s", 1), None);
    }

    #[test]
    fn append_prepend() {
        let c = small();
        assert_eq!(c.append(b"k", b"x"), StoreOutcome::NotStored);
        c.set(b"k", b"mid", 0, 0);
        assert_eq!(c.append(b"k", b"-end"), StoreOutcome::Stored);
        assert_eq!(c.prepend(b"k", b"start-"), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"start-mid-end");
    }

    #[test]
    fn flush_all_empties_cache() {
        let c = small();
        for i in 0..100u32 {
            c.set(format!("k{i}").as_bytes(), b"v", 0, 0);
        }
        assert_eq!(c.item_count(), 100);
        c.flush_all();
        assert_eq!(c.item_count(), 0);
        for i in 0..100u32 {
            assert!(c.get(format!("k{i}").as_bytes()).is_none());
        }
        // Flushed claims stay reusable: the same keys store again.
        for i in 0..100u32 {
            assert_eq!(c.set(format!("k{i}").as_bytes(), b"w", 0, 0), StoreOutcome::Stored);
        }
        assert_eq!(c.item_count(), 100);
    }

    #[test]
    fn expansion_relocates_entries_and_preserves_items() {
        let c = small(); // 64 slots
        for i in 0..300u32 {
            assert_eq!(
                c.set(format!("key-{i}").as_bytes(), format!("val-{i}").as_bytes(), 0, 0),
                StoreOutcome::Stored
            );
        }
        // Finish any in-flight migration so the root reflects the final
        // generation.
        for _ in 0..6 {
            c.maintenance();
        }
        assert!(c.bucket_count() > 64, "table must have grown");
        assert!(c.stats().metrics.expansions > 0);
        assert!(
            c.displacements() > 0,
            "growth must have relocated entries across generations"
        );
        assert_eq!(c.item_count(), 300);
        for i in 0..300u32 {
            assert_eq!(
                c.get(format!("key-{i}").as_bytes()).unwrap().data,
                format!("val-{i}").as_bytes(),
                "key-{i} lost across migration"
            );
        }
    }

    #[test]
    fn eviction_frees_memory_under_pressure() {
        let c = OaFlashCache::new(CacheConfig {
            mem_limit: 1 << 20,
            initial_buckets: 64,
            ..CacheConfig::default()
        });
        let value = vec![0xabu8; 4096];
        for i in 0..400u32 {
            assert_eq!(
                c.set(format!("big-{i}").as_bytes(), &value, 0, 0),
                StoreOutcome::Stored,
                "eviction must keep stores succeeding at the memory limit"
            );
        }
        assert!(c.stats().metrics.evictions > 0, "pressure must have evicted");
        assert!(c.mem_used() <= c.mem_limit());
    }

    #[test]
    fn batch_matches_sequential_oracle() {
        let c = small();
        let oracle = small();
        let ops = [
            Op::Set {
                key: b"a",
                value: b"1",
                flags: 7,
                exptime: 0,
            },
            Op::Get { key: b"a" },
            Op::Incr { key: b"a", delta: 41 },
            Op::Append {
                key: b"a",
                suffix: b"!",
            },
            Op::Get { key: b"a" },
            Op::Get { key: b"missing" },
            Op::Delete { key: b"a" },
            Op::Delete { key: b"a" },
        ];
        let batched = c.execute_batch(&ops);
        let sequential = execute_sequential(&oracle, &ops);
        assert_eq!(batched, sequential, "batch must match the sequential oracle");
    }

    #[test]
    fn concurrent_storm_with_expansion_stays_consistent() {
        use std::sync::atomic::AtomicU32;
        let c = Arc::new(OaFlashCache::new(CacheConfig {
            mem_limit: 16 << 20,
            initial_buckets: 64,
            ..CacheConfig::default()
        }));
        let errors = Arc::new(AtomicU32::new(0));
        let threads: Vec<_> = (0..4u64)
            .map(|tid| {
                let c = Arc::clone(&c);
                let errors = Arc::clone(&errors);
                std::thread::spawn(move || {
                    let mut rng = Xoshiro256::seeded(0x0af1a5 + tid);
                    for n in 0..3000u64 {
                        let key = format!("storm-{}", rng.next_below(512));
                        match rng.next_below(10) {
                            0..=4 => {
                                let v = format!("{tid}-{n}");
                                if c.set(key.as_bytes(), v.as_bytes(), 0, 0)
                                    != StoreOutcome::Stored
                                {
                                    // ord: relaxed-ok — test accounting.
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            5..=7 => {
                                // Hits must carry intact bytes.
                                if let Some(r) = c.get(key.as_bytes()) {
                                    if r.data.is_empty() {
                                        // ord: relaxed-ok — test accounting.
                                        errors.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                            }
                            _ => {
                                c.delete(key.as_bytes());
                            }
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(errors.load(Ordering::Relaxed), 0);
        // The 512-key space over 64 initial slots must have expanded.
        for _ in 0..6 {
            c.maintenance();
        }
        assert!(c.bucket_count() > 64);
        // Every surviving key must read back consistently.
        let live = (0..512u64)
            .filter(|i| c.get(format!("storm-{i}").as_bytes()).is_some())
            .count();
        assert_eq!(c.item_count(), live, "item count must match live keys");
    }

    #[test]
    fn stats_and_clock_snapshot_shape() {
        let c = small();
        c.set(b"k", b"v", 0, 0);
        c.get(b"k");
        c.get(b"missing");
        let s = c.stats();
        assert_eq!(s.metrics.gets, 2);
        assert_eq!(s.metrics.hits, 1);
        assert_eq!(s.metrics.misses, 1);
        assert_eq!(s.metrics.sets, 1);
        assert_eq!(s.items, 1);
        assert_eq!(s.buckets, 64);
        assert_eq!(s.mem_limit, 4 << 20);
        let clocks = c.clock_snapshot().unwrap();
        assert_eq!(clocks.len(), 64);
        assert!(clocks.iter().any(|&v| v > 0), "hit must have touched a clock");
    }
}
