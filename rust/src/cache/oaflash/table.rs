//! The open-addressing table generations behind [`super::OaFlashCache`].
//!
//! One generation is a power-of-two array of *slot words*. A slot word is
//! one of:
//!
//! * `0` — **empty**: never claimed in this generation.
//! * `entry-ptr` (tag `0`) — **resident**: points at a heap [`Entry`].
//! * `entry-ptr | `[`SLOT_FRZ`] — **frozen resident**: migration has
//!   claimed the entry; it is still fully readable (and its item word is
//!   still writable) but the slot word itself is terminal.
//! * [`FWD_WORD`] — **forwarded-empty**: the slot was frozen while still
//!   empty. Terminal; the generation is closed for any key whose probe
//!   reaches this slot.
//!
//! The load-bearing structural invariant is **slot monotonicity**: a slot
//! word only ever moves forward through `empty → {resident, forwarded}`
//! and `resident → frozen resident`; a claimed slot never changes which
//! [`Entry`] it holds and never becomes empty again. Combined with the
//! first-empty-claim discipline in the engine, monotonicity gives each
//! key at most one entry per generation and makes an empty slot an
//! authoritative "this key was never here" for every probe that reaches
//! it (see `rust/docs/concurrency.md`, oaflash section).
//!
//! Entries carry the *item word* from [`crate::cache::fleec::node`]
//! unchanged — `Live(ptr) / Tomb / Moved` — so mutation linearizes on a
//! single CAS exactly like FLeeC's chained engine, and relocation between
//! generations moves only the item *pointer*, never the slab bytes.

use std::sync::atomic::{AtomicPtr, AtomicU8, AtomicUsize, Ordering};

use crate::sync::tagged::{tag_of, untagged};

/// Slot tag bit: resident entry frozen for migration.
pub const SLOT_FRZ: usize = 0b01;

/// Whole-word marker: slot frozen while empty (forwarded-empty).
pub const FWD_WORD: usize = 0b10;

/// Maximum probe distance from a key's home slot. A probe that walks
/// this many occupied non-matching slots declares the generation full
/// for that key (writers then expand / descend; readers descend).
pub const PROBE_WINDOW: usize = 64;

/// Slots transferred per cooperatively-claimed migration span.
pub const MIGRATE_SPAN: usize = 32;

/// One key's table entry. Heap-allocated once at claim time and never
/// moved or mutated structurally afterwards (only the `item` word and
/// the containing slot's tag change), so guard-holding readers can keep
/// dereferencing it for as long as their pin lasts — entries retire only
/// with their generation, through EBR.
pub struct Entry {
    pub hash: u64,
    /// Packed item word — same encoding as the FLeeC node
    /// ([`crate::cache::fleec::node::decode_item`]).
    pub item: AtomicUsize,
    pub key: Box<[u8]>,
}

impl Entry {
    /// Heap-allocate an entry holding `item_word`.
    // guard-stable: returns an exclusively-owned, unpublished entry; once
    // a slot-claim CAS publishes it, it is only freed with its table
    // generation through EBR retirement, never under a live guard.
    pub fn alloc(hash: u64, key: &[u8], item_word: usize) -> *mut Entry {
        Box::into_raw(Box::new(Entry {
            hash,
            item: AtomicUsize::new(item_word),
            key: key.to_vec().into_boxed_slice(),
        }))
    }
}

/// Decoded slot word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotState {
    Empty,
    /// Forwarded-empty: terminally closed without ever holding an entry.
    Fwd,
    Resident {
        entry: *mut Entry,
        frozen: bool,
    },
}

/// Decode a slot word into its state.
#[inline]
pub fn decode_slot(w: usize) -> SlotState {
    if w == 0 {
        SlotState::Empty
    } else if w == FWD_WORD {
        SlotState::Fwd
    } else {
        SlotState::Resident {
            entry: untagged(w) as *mut Entry,
            frozen: tag_of(w) & SLOT_FRZ != 0,
        }
    }
}

/// One table generation. Generations form a forward chain (`next`)
/// during migration; the engine's root pointer swings down the chain as
/// generations complete.
pub struct OaTable {
    pub mask: usize,
    /// Slot words (see module docs for the encoding).
    pub slots: Box<[AtomicUsize]>,
    /// Per-slot CLOCK values (the paper's embedded multi-bit CLOCK,
    /// here at entry granularity instead of bucket granularity).
    pub clocks: Box<[AtomicU8]>,
    /// CLOCK hand (shared sweep position).
    pub hand: AtomicUsize,
    /// Successor generation (null until expansion starts).
    pub next: AtomicPtr<OaTable>,
    /// Next unclaimed migration span start (grows past `len`).
    pub cursor: AtomicUsize,
    /// Slots whose transfer is complete (forwarded, or frozen with the
    /// item word swapped to `Moved`).
    pub migrated: AtomicUsize,
    /// Slots ever claimed by an entry — tombstoned entries included.
    /// This, not the live-item count, is what drives expansion: probe
    /// lengths degrade with *claimed* slots.
    pub claimed: AtomicUsize,
}

impl OaTable {
    /// Allocate a generation of `capacity` slots (must be a power of
    /// two), leaked to a raw pointer for the atomic chain.
    // guard-stable: the returned table is exclusively owned until a CAS
    // publishes it (root or a `next` link); afterwards it is only freed
    // through EBR retirement once unreachable.
    pub fn alloc(capacity: usize) -> *mut OaTable {
        assert!(capacity.is_power_of_two());
        Box::into_raw(Box::new(OaTable {
            mask: capacity - 1,
            slots: (0..capacity).map(|_| AtomicUsize::new(0)).collect(),
            clocks: (0..capacity).map(|_| AtomicU8::new(0)).collect(),
            hand: AtomicUsize::new(0),
            next: AtomicPtr::new(std::ptr::null_mut()),
            cursor: AtomicUsize::new(0),
            migrated: AtomicUsize::new(0),
            claimed: AtomicUsize::new(0),
        }))
    }

    /// Slot count.
    #[inline]
    pub fn len(&self) -> usize {
        self.mask + 1
    }

    /// A key's home slot.
    #[inline]
    pub fn home(&self, hash: u64) -> usize {
        hash as usize & self.mask
    }

    /// Whether every slot's transfer is complete. The `Acquire` pairs
    /// with the `AcqRel` `migrated` increments, so a `true` result
    /// proves every relocation happened-before it — what makes the root
    /// promotion safe to follow with retirement of this generation.
    #[inline]
    pub fn fully_migrated(&self) -> bool {
        self.migrated.load(Ordering::Acquire) == self.len()
    }
}

impl Drop for OaTable {
    fn drop(&mut self) {
        // Exclusive access (drop runs post-EBR grace or from the engine's
        // own Drop): free every resident entry exactly once. Claimed
        // slots never change entries (slot monotonicity), so each
        // resident pointer appears in exactly one slot. Items hanging
        // off live entry words are slab chunks — they die with the slab
        // pages (engine Drop) or were already retired (migration/flush).
        for slot in self.slots.iter_mut() {
            // ord: relaxed-ok — exclusive access in drop.
            if let SlotState::Resident { entry, .. } = decode_slot(slot.load(Ordering::Relaxed)) {
                // SAFETY: `entry` came from `Entry::alloc` (Box) and this
                // is the sole slot holding it; exclusive access in drop.
                unsafe { drop(Box::from_raw(entry)) };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::fleec::node::TOMB_WORD;

    #[test]
    fn slot_word_decoding() {
        assert_eq!(decode_slot(0), SlotState::Empty);
        assert_eq!(decode_slot(FWD_WORD), SlotState::Fwd);
        let e = Entry::alloc(7, b"k", TOMB_WORD);
        assert_eq!(
            decode_slot(e as usize),
            SlotState::Resident {
                entry: e,
                frozen: false
            }
        );
        assert_eq!(
            decode_slot(e as usize | SLOT_FRZ),
            SlotState::Resident {
                entry: e,
                frozen: true
            }
        );
        unsafe { drop(Box::from_raw(e)) };
    }

    #[test]
    fn table_frees_resident_entries_on_drop() {
        let t = OaTable::alloc(64);
        let tref = unsafe { &*t };
        assert_eq!(tref.len(), 64);
        let e = Entry::alloc(1, b"abc", TOMB_WORD);
        tref.slots[tref.home(1)].store(e as usize, Ordering::Relaxed);
        let f = Entry::alloc(2, b"def", TOMB_WORD);
        tref.slots[tref.home(2)].store(f as usize | SLOT_FRZ, Ordering::Relaxed);
        tref.slots[5].store(FWD_WORD, Ordering::Relaxed);
        // Drop must free both entries (frozen included) and skip
        // empty/forwarded slots without faulting.
        unsafe { drop(Box::from_raw(t)) };
    }

    #[test]
    fn home_masks_low_bits() {
        let t = OaTable::alloc(256);
        let tref = unsafe { &*t };
        assert_eq!(tref.home(0x1234), 0x34);
        assert_eq!(tref.home(u64::MAX), 255);
        unsafe { drop(Box::from_raw(t)) };
    }
}
