//! Baseline engine modeling **Memcached's blocking design** — the system
//! the paper compares against.
//!
//! Synchronization structure (the property under test):
//!
//! * the hash table is guarded by **striped mutexes** (Memcached's item
//!   locks; stripe chosen by key hash),
//! * strict LRU lives in **one intrusive doubly-linked list under a single
//!   mutex** (Memcached's `cache_lock`): *every hit takes the global LRU
//!   lock* to move the item to the front — the serialization point that
//!   collapses under skewed/high-contention load,
//! * expansion is **stop-the-world**: all stripes are held while the
//!   bucket array is rebuilt.
//!
//! Unlike FLeeC there is no epoch machinery: everything is mutated in
//! place under locks. Value memory is accounted per entry (key + value +
//! fixed overhead) against `mem_limit`, and eviction pops the LRU tail
//! with `try_lock` on the victim's stripe (Memcached's discipline, which
//! also avoids lock-order inversion).
//!
//! Lock ordering: stripe → LRU. The evictor holds LRU and only
//! `try_lock`s stripes, so the orders never deadlock.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::cache::{
    deadline_from_exptime, hash_key, is_expired, Cache, CacheConfig, GetResult, StatsSnapshot,
    StoreOutcome, MAX_KEY_LEN,
};
use crate::metrics::EngineMetrics;

/// Fixed per-entry overhead charged against the memory budget (headers,
/// pointers; mirrors the slab chunk slack the lock-free engine pays).
const ENTRY_OVERHEAD: usize = 64;

/// One cache entry. LRU links are only touched under the LRU lock; all
/// other fields only under the entry's stripe lock.
struct MEntry {
    hash: u64,
    key: Box<[u8]>,
    value: Vec<u8>,
    flags: u32,
    deadline: u32,
    cas: u64,
    prev: *mut MEntry,
    next: *mut MEntry,
}

impl MEntry {
    fn footprint(&self) -> usize {
        self.key.len() + self.value.len() + ENTRY_OVERHEAD
    }
}

/// Strict-LRU intrusive list; `head` = most recently used.
#[derive(Default)]
struct Lru {
    head: *mut MEntry,
    tail: *mut MEntry,
}

// SAFETY: the raw entry pointers are only dereferenced under the LRU
// lock (the list lives inside a Mutex).
unsafe impl Send for Lru {}

impl Lru {
    /// # Safety
    /// `e` must point to a live entry; caller holds the LRU lock.
    unsafe fn push_front(&mut self, e: *mut MEntry) {
        (*e).prev = std::ptr::null_mut();
        (*e).next = self.head;
        if !self.head.is_null() {
            (*self.head).prev = e;
        }
        self.head = e;
        if self.tail.is_null() {
            self.tail = e;
        }
    }

    /// # Safety
    /// `e` must be a live entry currently linked into this list; caller
    /// holds the LRU lock.
    unsafe fn unlink(&mut self, e: *mut MEntry) {
        let (p, n) = ((*e).prev, (*e).next);
        if p.is_null() {
            self.head = n;
        } else {
            (*p).next = n;
        }
        if n.is_null() {
            self.tail = p;
        } else {
            (*n).prev = p;
        }
        (*e).prev = std::ptr::null_mut();
        (*e).next = std::ptr::null_mut();
    }

    /// # Safety
    /// Same contract as [`Lru::unlink`].
    unsafe fn move_to_front(&mut self, e: *mut MEntry) {
        if self.head == e {
            return;
        }
        self.unlink(e);
        self.push_front(e);
    }
}

/// Bucket array; replaced wholesale by stop-the-world expansion.
struct TableState {
    buckets: Vec<Vec<*mut MEntry>>,
    mask: usize,
}

/// The blocking baseline engine.
pub struct MemcachedCache {
    stripes: Box<[Mutex<()>]>,
    state: UnsafeCell<TableState>,
    lru: Mutex<Lru>,
    items: AtomicUsize,
    bytes: AtomicUsize,
    cas_counter: AtomicU64,
    metrics: EngineMetrics,
    config: CacheConfig,
}

// SAFETY: the UnsafeCell'd table is only touched under stripe locks (all
// stripes for structural changes), the LRU under its own Mutex, and the
// rest is atomics.
unsafe impl Send for MemcachedCache {}
// SAFETY: same locking discipline as Send.
unsafe impl Sync for MemcachedCache {}

impl MemcachedCache {
    pub fn new(config: CacheConfig) -> Self {
        let buckets = config.initial_buckets.next_power_of_two();
        let stripes = (0..config.lock_stripes.next_power_of_two())
            .map(|_| Mutex::new(()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        MemcachedCache {
            stripes,
            state: UnsafeCell::new(TableState {
                buckets: (0..buckets).map(|_| Vec::new()).collect(),
                mask: buckets - 1,
            }),
            lru: Mutex::new(Lru::default()),
            items: AtomicUsize::new(0),
            bytes: AtomicUsize::new(0),
            cas_counter: AtomicU64::new(0),
            metrics: EngineMetrics::default(),
            config,
        }
    }

    #[inline]
    fn stripe(&self, hash: u64) -> &Mutex<()> {
        &self.stripes[(hash as usize) & (self.stripes.len() - 1)]
    }

    /// Access the table state.
    ///
    /// # Safety
    /// Caller must hold at least one stripe (reads of the array
    /// structure) — expansion holds *all* stripes to mutate.
    #[allow(clippy::mut_from_ref)]
    unsafe fn state(&self) -> &mut TableState {
        &mut *self.state.get()
    }

    /// Find an entry in its bucket.
    ///
    /// # Safety
    /// Caller must hold `hash`'s stripe lock.
    unsafe fn find(&self, hash: u64, key: &[u8]) -> Option<(usize, usize, *mut MEntry)> {
        let st = self.state();
        let idx = (hash as usize) & st.mask;
        for (pos, &e) in st.buckets[idx].iter().enumerate() {
            if (*e).hash == hash && *(*e).key == *key {
                return Some((idx, pos, e));
            }
        }
        None
    }

    /// Remove `e` from its bucket and the LRU and free it.
    ///
    /// # Safety
    /// Caller holds the stripe owning `(idx, pos)`; `e` is the entry at
    /// that position. Takes the LRU lock itself (stripe → LRU order).
    unsafe fn remove_entry(&self, idx: usize, pos: usize, e: *mut MEntry) {
        let st = self.state();
        st.buckets[idx].swap_remove(pos);
        {
            let mut lru = self.lru.lock().unwrap();
            lru.unlink(e);
        }
        self.bytes.fetch_sub((*e).footprint(), Ordering::Relaxed);
        self.items.fetch_sub(1, Ordering::Relaxed);
        drop(Box::from_raw(e));
    }

    /// Evict from the LRU tail until `bytes ≤ mem_limit`. Holds the LRU
    /// lock and `try_lock`s victim stripes (skipping contended ones).
    fn evict_to_limit(&self) {
        while self.bytes.load(Ordering::Relaxed) > self.config.mem_limit {
            let mut lru = self.lru.lock().unwrap();
            let mut victim = lru.tail;
            let mut evicted = false;
            // Walk tail-ward candidates (bounded) looking for one whose
            // stripe we can grab without blocking.
            for _ in 0..8 {
                if victim.is_null() {
                    break;
                }
                // SAFETY: `victim` is linked in the LRU we hold locked, so
                // it cannot be freed out from under us (every free
                // unlinks under this lock first).
                let hash = unsafe { (*victim).hash };
                if let Ok(_s) = self.stripe(hash).try_lock() {
                    // SAFETY: victim's stripe lock acquired — full access
                    // to its bucket; LRU still held for the unlink.
                    unsafe {
                        let key = (*victim).key.clone();
                        if let Some((idx, pos, e)) = self.find(hash, &key) {
                            debug_assert_eq!(e, victim);
                            let st = self.state();
                            st.buckets[idx].swap_remove(pos);
                            lru.unlink(e);
                            self.bytes.fetch_sub((*e).footprint(), Ordering::Relaxed);
                            self.items.fetch_sub(1, Ordering::Relaxed);
                            self.metrics.evictions.inc();
                            drop(Box::from_raw(e));
                            evicted = true;
                        }
                    }
                    break;
                }
                // SAFETY: still under the LRU lock (see above).
                victim = unsafe { (*victim).prev };
            }
            drop(lru);
            if !evicted {
                // Everything contended: yield and retry (blocking behavior
                // is the point of this baseline).
                std::thread::yield_now();
                if self.items.load(Ordering::Relaxed) == 0 {
                    break;
                }
            }
        }
    }

    /// Stop-the-world expansion: hold every stripe, rebuild the array.
    fn maybe_expand(&self) {
        let need = |items: usize, buckets: usize| {
            (items as f64) > self.config.load_factor * buckets as f64
        };
        {
            // Cheap pre-check under one stripe.
            let _s0 = self.stripes[0].lock().unwrap();
            // SAFETY: only `mask` is read; it changes only under all
            // stripes, which includes the stripe-0 lock held here.
            let st = unsafe { self.state() };
            if !need(self.items.load(Ordering::Relaxed), st.mask + 1) {
                return;
            }
        }
        // Acquire ALL stripes in index order (the stop-the-world phase).
        let guards: Vec<MutexGuard<()>> =
            self.stripes.iter().map(|s| s.lock().unwrap()).collect();
        // SAFETY: every stripe is locked — exclusive structural access.
        let st = unsafe { self.state() };
        if !need(self.items.load(Ordering::Relaxed), st.mask + 1) {
            return; // someone else expanded while we queued
        }
        let new_size = (st.mask + 1) * 2;
        let mut new_buckets: Vec<Vec<*mut MEntry>> = (0..new_size).map(|_| Vec::new()).collect();
        for bucket in st.buckets.drain(..) {
            for e in bucket {
                // SAFETY: all stripes held; every bucketed entry is live.
                let idx = unsafe { (*e).hash as usize } & (new_size - 1);
                new_buckets[idx].push(e);
            }
        }
        st.buckets = new_buckets;
        st.mask = new_size - 1;
        self.metrics.expansions.inc();
        drop(guards);
    }

    fn store_inner(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, mode: Mode) -> StoreOutcome {
        if key.len() > MAX_KEY_LEN || key.is_empty() {
            return StoreOutcome::NotStored;
        }
        self.metrics.sets.inc();
        let hash = hash_key(key);
        let deadline = deadline_from_exptime(exptime);
        let cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
        let outcome = {
            let _s = self.stripe(hash).lock().unwrap();
            // SAFETY: `hash`'s stripe lock is held for the whole block;
            // every dereferenced entry lives in that stripe's buckets.
            unsafe {
                match self.find(hash, key) {
                    Some((idx, pos, e)) => {
                        if is_expired((*e).deadline) {
                            self.remove_entry(idx, pos, e);
                            self.metrics.expired.inc();
                            match mode {
                                Mode::Replace | Mode::Cas(_) => StoreOutcome::NotFound,
                                _ => self.insert_new(hash, key, value, flags, deadline, cas),
                            }
                        } else {
                            match mode {
                                Mode::Add => StoreOutcome::NotStored,
                                Mode::Cas(tok) if (*e).cas != tok => StoreOutcome::Exists,
                                _ => {
                                    let old = (*e).value.len();
                                    (*e).value.clear();
                                    (*e).value.extend_from_slice(value);
                                    (*e).flags = flags;
                                    (*e).deadline = deadline;
                                    (*e).cas = cas;
                                    if value.len() >= old {
                                        self.bytes.fetch_add(value.len() - old, Ordering::Relaxed);
                                    } else {
                                        self.bytes.fetch_sub(old - value.len(), Ordering::Relaxed);
                                    }
                                    let mut lru = self.lru.lock().unwrap();
                                    lru.move_to_front(e);
                                    StoreOutcome::Stored
                                }
                            }
                        }
                    }
                    None => match mode {
                        Mode::Replace | Mode::Cas(_) => StoreOutcome::NotFound,
                        _ => self.insert_new(hash, key, value, flags, deadline, cas),
                    },
                }
            }
        };
        if outcome == StoreOutcome::Stored {
            self.evict_to_limit();
            self.maybe_expand();
        }
        outcome
    }

    /// Insert a brand-new entry.
    ///
    /// # Safety
    /// Caller must hold `hash`'s stripe lock.
    unsafe fn insert_new(
        &self,
        hash: u64,
        key: &[u8],
        value: &[u8],
        flags: u32,
        deadline: u32,
        cas: u64,
    ) -> StoreOutcome {
        let e = Box::into_raw(Box::new(MEntry {
            hash,
            key: key.to_vec().into_boxed_slice(),
            value: value.to_vec(),
            flags,
            deadline,
            cas,
            prev: std::ptr::null_mut(),
            next: std::ptr::null_mut(),
        }));
        let st = self.state();
        let idx = (hash as usize) & st.mask;
        st.buckets[idx].push(e);
        self.bytes.fetch_add((*e).footprint(), Ordering::Relaxed);
        self.items.fetch_add(1, Ordering::Relaxed);
        let mut lru = self.lru.lock().unwrap();
        lru.push_front(e);
        StoreOutcome::Stored
    }

    /// In-place read-modify-write under the stripe lock (the blocking
    /// engines don't need token dances).
    fn rmw_inner(
        &self,
        key: &[u8],
        f: impl FnOnce(&mut MEntry) -> bool,
    ) -> Option<()> {
        let hash = hash_key(key);
        let _s = self.stripe(hash).lock().unwrap();
        // SAFETY: `hash`'s stripe lock is held for the whole block.
        unsafe {
            let (idx, pos, e) = self.find(hash, key)?;
            if is_expired((*e).deadline) {
                self.remove_entry(idx, pos, e);
                self.metrics.expired.inc();
                return None;
            }
            let before = (*e).footprint();
            if !f(&mut *e) {
                return None;
            }
            let after = (*e).footprint();
            if after >= before {
                self.bytes.fetch_add(after - before, Ordering::Relaxed);
            } else {
                self.bytes.fetch_sub(before - after, Ordering::Relaxed);
            }
            (*e).cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
            let mut lru = self.lru.lock().unwrap();
            lru.move_to_front(e);
        }
        Some(())
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Set,
    Add,
    Replace,
    Cas(u64),
}

impl MemcachedCache {
    /// The engine's live request-path counters. Inherent on purpose:
    /// generic consumers read counters through the merging
    /// [`Cache::stats`] path only.
    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// Locked lookup core (metrics-free), shared by [`Cache::get`] and
    /// the sink batch path: on a live hit, calls `hit` with the entry's
    /// `(flags, cas, value)` **while the stripe lock is held** — the
    /// borrow is only valid inside the closure — then bumps the LRU.
    /// Returns `None` on miss/expiry.
    fn get_with<R>(&self, key: &[u8], hit: impl FnOnce(u32, u64, &[u8]) -> R) -> Option<R> {
        let hash = hash_key(key);
        let _s = self.stripe(hash).lock().unwrap();
        // SAFETY: `hash`'s stripe lock is held for the whole block; the
        // `hit` borrow ends before the lock drops.
        unsafe {
            match self.find(hash, key) {
                Some((idx, pos, e)) => {
                    if is_expired((*e).deadline) {
                        self.remove_entry(idx, pos, e);
                        self.metrics.expired.inc();
                        None
                    } else {
                        let r = hit((*e).flags, (*e).cas, &(*e).value);
                        // THE bottleneck the paper attacks: every hit
                        // serializes on the global LRU lock.
                        let mut lru = self.lru.lock().unwrap();
                        lru.move_to_front(e);
                        Some(r)
                    }
                }
                None => None,
            }
        }
    }
}

impl Cache for MemcachedCache {
    fn engine_name(&self) -> &'static str {
        "memcached"
    }

    /// Sequential per-op execution (batching buys a blocking engine
    /// nothing), except that GET hits lend the sink the entry's bytes
    /// under the stripe lock ([`MemcachedCache::get_with`]) instead of
    /// cloning the value — the one copy is sink-side, straight to its
    /// destination.
    fn execute_batch_into(&self, ops: &[crate::cache::Op<'_>], sink: &mut dyn crate::cache::BatchSink) {
        for (i, op) in ops.iter().enumerate() {
            match *op {
                crate::cache::Op::Get { key } => {
                    self.metrics.gets.inc();
                    let hit = self
                        .get_with(key, |flags, cas, data| sink.value(i, key, flags, cas, data))
                        .is_some();
                    if hit {
                        self.metrics.hits.inc();
                    } else {
                        self.metrics.misses.inc();
                        sink.miss(i);
                    }
                }
                _ => crate::cache::op::forward_one(self, i, op, sink),
            }
        }
    }

    fn get(&self, key: &[u8]) -> Option<GetResult> {
        self.metrics.gets.inc();
        let result = self.get_with(key, |flags, cas, data| GetResult {
            data: data.to_vec(),
            flags,
            cas,
        });
        if result.is_some() {
            self.metrics.hits.inc();
        } else {
            self.metrics.misses.inc();
        }
        result
    }

    fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store_inner(key, value, flags, exptime, Mode::Set)
    }

    fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store_inner(key, value, flags, exptime, Mode::Add)
    }

    fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store_inner(key, value, flags, exptime, Mode::Replace)
    }

    fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> StoreOutcome {
        self.store_inner(key, value, flags, exptime, Mode::Cas(cas))
    }

    fn append(&self, key: &[u8], suffix: &[u8]) -> StoreOutcome {
        match self.rmw_inner(key, |e| {
            e.value.extend_from_slice(suffix);
            true
        }) {
            Some(()) => StoreOutcome::Stored,
            None => StoreOutcome::NotStored,
        }
    }

    fn prepend(&self, key: &[u8], prefix: &[u8]) -> StoreOutcome {
        match self.rmw_inner(key, |e| {
            let mut v = Vec::with_capacity(prefix.len() + e.value.len());
            v.extend_from_slice(prefix);
            v.extend_from_slice(&e.value);
            e.value = v;
            true
        }) {
            Some(()) => StoreOutcome::Stored,
            None => StoreOutcome::NotStored,
        }
    }

    fn delete(&self, key: &[u8]) -> bool {
        self.metrics.deletes.inc();
        let hash = hash_key(key);
        let _s = self.stripe(hash).lock().unwrap();
        // SAFETY: `hash`'s stripe lock is held for the whole block.
        unsafe {
            match self.find(hash, key) {
                Some((idx, pos, e)) => {
                    self.remove_entry(idx, pos, e);
                    true
                }
                None => false,
            }
        }
    }

    fn incr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut out = None;
        self.rmw_inner(key, |e| {
            if let Ok(n) = std::str::from_utf8(&e.value)
                .unwrap_or("")
                .trim()
                .parse::<u64>()
            {
                let v = n.wrapping_add(delta);
                e.value = v.to_string().into_bytes();
                out = Some(v);
                true
            } else {
                false
            }
        })?;
        out
    }

    fn decr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut out = None;
        self.rmw_inner(key, |e| {
            if let Ok(n) = std::str::from_utf8(&e.value)
                .unwrap_or("")
                .trim()
                .parse::<u64>()
            {
                let v = n.saturating_sub(delta);
                e.value = v.to_string().into_bytes();
                out = Some(v);
                true
            } else {
                false
            }
        })?;
        out
    }

    fn touch(&self, key: &[u8], exptime: u32) -> bool {
        let deadline = deadline_from_exptime(exptime);
        self.rmw_inner(key, |e| {
            e.deadline = deadline;
            true
        })
        .is_some()
    }

    fn flush_all(&self) {
        let _guards: Vec<MutexGuard<()>> =
            self.stripes.iter().map(|s| s.lock().unwrap()).collect();
        let mut lru = self.lru.lock().unwrap();
        // SAFETY: every stripe is locked — exclusive structural access.
        let st = unsafe { self.state() };
        for bucket in st.buckets.iter_mut() {
            for e in bucket.drain(..) {
                // SAFETY: all stripes + LRU held; each entry is freed
                // exactly once (drained from its only bucket).
                unsafe {
                    lru.unlink(e);
                    drop(Box::from_raw(e));
                }
            }
        }
        self.items.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
    }

    fn item_count(&self) -> usize {
        self.items.load(Ordering::Relaxed)
    }

    fn bucket_count(&self) -> usize {
        let _s = self.stripes[0].lock().unwrap();
        // SAFETY: `mask` changes only under all stripes; stripe 0 held.
        unsafe { self.state().mask + 1 }
    }

    fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            metrics: self.metrics.snapshot(),
            items: self.item_count(),
            buckets: self.bucket_count(),
            mem_used: self.mem_used(),
            mem_limit: self.mem_limit(),
            // Blocking engines have no EBR/slab substrate and use the
            // sequential batch path: observability extras stay zero.
            ..StatsSnapshot::default()
        }
    }

    fn mem_used(&self) -> usize {
        self.bytes.load(Ordering::Relaxed)
    }

    fn mem_limit(&self) -> usize {
        self.config.mem_limit
    }
}

impl Drop for MemcachedCache {
    fn drop(&mut self) {
        let st = self.state.get_mut();
        for bucket in st.buckets.iter_mut() {
            for e in bucket.drain(..) {
                // SAFETY: `&mut self` in drop — exclusive access; each
                // entry is owned by exactly one bucket.
                unsafe { drop(Box::from_raw(e)) };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn small() -> MemcachedCache {
        MemcachedCache::new(CacheConfig::small())
    }

    #[test]
    fn roundtrip_and_semantics() {
        let c = small();
        assert_eq!(c.set(b"k", b"v", 9, 0), StoreOutcome::Stored);
        let r = c.get(b"k").unwrap();
        assert_eq!((r.data.as_slice(), r.flags), (b"v" as &[u8], 9));
        assert_eq!(c.add(b"k", b"x", 0, 0), StoreOutcome::NotStored);
        assert_eq!(c.replace(b"k", b"w", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"w");
        assert!(c.delete(b"k"));
        assert!(c.get(b"k").is_none());
        assert_eq!(c.replace(b"k", b"z", 0, 0), StoreOutcome::NotFound);
    }

    #[test]
    fn cas_incr_append() {
        let c = small();
        c.set(b"n", b"5", 0, 0);
        let tok = c.get(b"n").unwrap().cas;
        assert_eq!(c.cas(b"n", b"6", 0, 0, tok), StoreOutcome::Stored);
        assert_eq!(c.cas(b"n", b"7", 0, 0, tok), StoreOutcome::Exists);
        assert_eq!(c.incr(b"n", 4), Some(10));
        assert_eq!(c.decr(b"n", 20), Some(0));
        c.set(b"s", b"b", 0, 0);
        c.append(b"s", b"c");
        c.prepend(b"s", b"a");
        assert_eq!(c.get(b"s").unwrap().data, b"abc");
    }

    #[test]
    fn strict_lru_evicts_least_recent() {
        let c = MemcachedCache::new(CacheConfig {
            mem_limit: 10 * (ENTRY_OVERHEAD + 6 + 1024),
            initial_buckets: 64,
            ..CacheConfig::small()
        });
        let v = vec![0u8; 1024];
        for i in 0..10u32 {
            c.set(format!("key{i:02}").as_bytes(), &v, 0, 0);
        }
        // Touch key00 so it is MRU, then overflow by one.
        assert!(c.get(b"key00").is_some());
        c.set(b"key10", &v, 0, 0);
        // The LRU victim must be key01 (oldest untouched), NOT key00.
        assert!(c.get(b"key00").is_some(), "recently used key survived");
        assert!(c.get(b"key01").is_none(), "LRU victim evicted");
        assert!(c.metrics().snapshot().evictions >= 1);
    }

    #[test]
    fn stop_the_world_expansion_preserves_items() {
        let c = MemcachedCache::new(CacheConfig {
            initial_buckets: 8,
            ..CacheConfig::small()
        });
        for i in 0..100u32 {
            c.set(format!("e{i}").as_bytes(), &i.to_le_bytes(), 0, 0);
        }
        assert!(c.bucket_count() > 8);
        for i in 0..100u32 {
            assert_eq!(
                c.get(format!("e{i}").as_bytes()).unwrap().data,
                i.to_le_bytes().to_vec()
            );
        }
    }

    #[test]
    fn concurrent_storm_consistency() {
        use crate::workload::{check_value, encode_key, fill_value, KEY_LEN};
        let c = Arc::new(MemcachedCache::new(CacheConfig {
            mem_limit: 4 << 20,
            initial_buckets: 32,
            ..CacheConfig::small()
        }));
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    let mut rng = crate::sync::Xoshiro256::seeded(t);
                    let mut key = [0u8; KEY_LEN];
                    let mut val = vec![0u8; 128];
                    for _ in 0..5_000 {
                        let id = rng.next_below(300);
                        let k = encode_key(&mut key, id);
                        if rng.chance(0.7) {
                            if let Some(r) = c.get(k) {
                                assert!(check_value(id, &r.data));
                            }
                        } else {
                            let len = 16 + (id as usize % 100);
                            fill_value(id, &mut val[..len]);
                            c.set(k, &val[..len], 0, 0);
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn flush_all_resets() {
        let c = small();
        for i in 0..50u32 {
            c.set(format!("f{i}").as_bytes(), b"v", 0, 0);
        }
        c.flush_all();
        assert_eq!(c.item_count(), 0);
        assert_eq!(c.mem_used(), 0);
        assert!(c.get(b"f0").is_none());
    }
}
