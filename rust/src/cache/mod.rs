//! The engine-neutral cache interface and shared item semantics.
//!
//! All four engines — [`memcached`] (blocking baseline), [`memclock`]
//! (blocking table + CLOCK eviction, the paper's intermediate step),
//! [`fleec`] (the paper's lock-free system) and [`oaflash`] (lock-free
//! open addressing over the same item substrate) — implement [`Cache`],
//! so the protocol server, the workload driver and every bench are
//! generic over the engine and the paper's comparison is an `--engine`
//! flag.
//! [`sharded::Sharded`] wraps any of them in an N-way key-hash router
//! that is itself a [`Cache`], so every consumer scales by shard count
//! without knowing it.

pub mod fleec;
pub mod memcached;
pub mod memclock;
pub mod oaflash;
pub mod op;
pub mod sharded;
pub mod tenant;

pub use op::{BatchSink, CollectSink, Op, OpResult};

use std::sync::Arc;

use crate::metrics::{HistogramSnapshot, LatencySnapshot, MetricsSnapshot};

/// Hard cap on key length (Memcached's limit).
pub const MAX_KEY_LEN: usize = 250;

/// Result of a read hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GetResult {
    pub data: Vec<u8>,
    pub flags: u32,
    pub cas: u64,
}

/// Outcome of a storage command, mirroring the protocol's replies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreOutcome {
    /// Stored successfully (`STORED`).
    Stored,
    /// Precondition failed — e.g. `add` on an existing key (`NOT_STORED`).
    NotStored,
    /// `cas` token mismatch (`EXISTS`).
    Exists,
    /// `cas`/`replace`/`append` on a missing key (`NOT_FOUND`).
    NotFound,
    /// Item exceeds the largest slab chunk (`SERVER_ERROR`).
    TooLarge,
    /// Eviction could not free memory fast enough (`SERVER_ERROR`).
    OutOfMemory,
}

/// Parameters every engine is constructed from.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Value-memory budget in bytes (slab `-m`).
    pub mem_limit: usize,
    /// Initial hash-table bucket count (rounded up to a power of two).
    pub initial_buckets: usize,
    /// Expansion threshold: grow when `items > load_factor × buckets`
    /// (the paper fixes 1.5).
    pub load_factor: f64,
    /// Maximum CLOCK value (the paper: multi-bit, distinguishes mildly
    /// from highly popular buckets). 1 = classic second-chance CLOCK.
    pub clock_max: u8,
    /// Lock stripes for the blocking engines.
    pub lock_stripes: usize,
    /// Items evicted per eviction pass before re-trying an allocation.
    pub evict_batch: u32,
    /// Latency sampling stride: record per-op latency histograms on
    /// 1-in-N batches (`--latency-sample N`). 0 disables the clock
    /// entirely; 1 times every batch (tests / deep profiling).
    pub latency_sample: u32,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            mem_limit: 64 << 20,
            initial_buckets: 1024,
            load_factor: 1.5,
            clock_max: 3,
            lock_stripes: 16,
            evict_batch: 8,
            latency_sample: 64,
        }
    }
}

impl CacheConfig {
    /// Small-footprint config used across tests.
    pub fn small() -> Self {
        CacheConfig {
            mem_limit: 4 << 20,
            initial_buckets: 64,
            ..Self::default()
        }
    }
}

/// One coherent `stats`-grade view of a cache: request counters plus the
/// capacity figures the text protocol reports, the sampled per-op-class
/// latency histograms (`stats latency`), the subsystem internals
/// (`stats internals`) and the per-size-class slab occupancy (`stats
/// slabs`). Exists so aggregating engines ([`sharded::Sharded`]) can
/// hand the serving plane a *merged* view — [`StatsSnapshot::absorb`]
/// sums every field (histograms merge bucket-wise, slab classes merge
/// by chunk size), and per-shard `mem_limit`s add back up to the
/// configured total.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub metrics: MetricsSnapshot,
    pub items: usize,
    pub buckets: usize,
    pub mem_used: usize,
    pub mem_limit: usize,
    /// Sampled per-op-class latency histograms (empty when
    /// `latency_sample == 0` or the engine does not time batches).
    pub latency: LatencySnapshot,
    /// Subsystem gauges/counters (EBR, slab, open addressing).
    pub internals: InternalsSnapshot,
    /// Per-size-class slab occupancy; empty for engines without a slab.
    pub slabs: Vec<SlabClassSnapshot>,
}

impl StatsSnapshot {
    /// Fold another snapshot into this one (all fields sum).
    pub fn absorb(&mut self, other: &StatsSnapshot) {
        self.metrics.absorb(&other.metrics);
        self.items += other.items;
        self.buckets += other.buckets;
        self.mem_used += other.mem_used;
        self.mem_limit += other.mem_limit;
        self.latency.absorb(&other.latency);
        self.internals.absorb(&other.internals);
        if self.slabs.is_empty() {
            self.slabs = other.slabs.clone();
        } else {
            for s in &other.slabs {
                match self.slabs.iter_mut().find(|c| c.chunk_size == s.chunk_size) {
                    Some(c) => c.absorb(s),
                    None => self.slabs.push(s.clone()),
                }
            }
            self.slabs.sort_by_key(|c| c.chunk_size);
        }
    }
}

/// Subsystem internals surfaced by `stats internals`: where the
/// lock-free design pays (or would be seen failing to). All fields are
/// stats-grade relaxed counter folds; [`absorb`](Self::absorb) sums
/// them across shards.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct InternalsSnapshot {
    /// EBR: successful global-epoch advances.
    pub ebr_advances: u64,
    /// EBR: advance attempts that found a pinned straggler and gave up.
    pub ebr_failed_advances: u64,
    /// EBR: items currently parked in limbo bags (deferred, not yet
    /// reclaimable).
    pub ebr_deferred_items: u64,
    /// EBR: bytes currently parked in limbo bags.
    pub ebr_deferred_bytes: u64,
    /// EBR: items whose destructors have run (freed for reuse).
    pub ebr_reclaimed_items: u64,
    /// Slab: allocations served from a thread's private magazine (the
    /// zero-shared-CAS fast path).
    pub slab_magazine_hits: u64,
    /// Slab: magazine refills that went to the shared segment lists.
    pub slab_shared_refills: u64,
    /// Slab: flush-request epochs honored by registered threads.
    pub slab_flushes_honored: u64,
    /// Open addressing: slot migrations completed (generation moves).
    pub oa_migrations: u64,
    /// Open addressing: entries displaced during insert probing.
    pub oa_displacements: u64,
    /// Open addressing: probe lengths (slot distance from home, not
    /// nanoseconds), recorded on sampled batches.
    pub oa_probe: HistogramSnapshot,
}

impl InternalsSnapshot {
    /// Fold another snapshot into this one (counters sum, the probe
    /// histogram merges bucket-wise).
    pub fn absorb(&mut self, other: &InternalsSnapshot) {
        self.ebr_advances += other.ebr_advances;
        self.ebr_failed_advances += other.ebr_failed_advances;
        self.ebr_deferred_items += other.ebr_deferred_items;
        self.ebr_deferred_bytes += other.ebr_deferred_bytes;
        self.ebr_reclaimed_items += other.ebr_reclaimed_items;
        self.slab_magazine_hits += other.slab_magazine_hits;
        self.slab_shared_refills += other.slab_shared_refills;
        self.slab_flushes_honored += other.slab_flushes_honored;
        self.oa_migrations += other.oa_migrations;
        self.oa_displacements += other.oa_displacements;
        self.oa_probe.absorb(&other.oa_probe);
    }
}

/// Per-size-class slab occupancy for `stats slabs` (memcached's
/// `STAT <cls>:chunk_size …` shape). Shards share one chunk-size
/// ladder, so [`absorb`](Self::absorb) merges same-size classes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SlabClassSnapshot {
    pub chunk_size: usize,
    /// Chunks holding live items.
    pub live_chunks: usize,
    /// Chunks parked in free lists / magazines.
    pub cached_chunks: usize,
    /// All chunks ever carved for this class.
    pub total_chunks: usize,
}

impl SlabClassSnapshot {
    pub fn absorb(&mut self, other: &SlabClassSnapshot) {
        debug_assert_eq!(self.chunk_size, other.chunk_size, "merging across class ladders");
        self.live_chunks += other.live_chunks;
        self.cached_chunks += other.cached_chunks;
        self.total_chunks += other.total_chunks;
    }
}

/// Assemble the EBR + slab portion of an [`InternalsSnapshot`], shared by
/// the engines built over the collector/slab substrate (fleec, oaflash).
/// The open-addressing fields stay default; oaflash fills them itself.
pub(crate) fn substrate_internals(
    collector: &crate::ebr::Collector,
    slab: &crate::slab::Slab,
) -> InternalsSnapshot {
    let (attempts, successes) = collector.advance_stats();
    InternalsSnapshot {
        ebr_advances: successes as u64,
        ebr_failed_advances: attempts.saturating_sub(successes) as u64,
        ebr_deferred_items: collector.pending_items() as u64,
        ebr_deferred_bytes: collector.pending_bytes() as u64,
        ebr_reclaimed_items: collector.reclaimed_items() as u64,
        slab_magazine_hits: slab.magazine_hits(),
        slab_shared_refills: slab.shared_refills(),
        slab_flushes_honored: slab.flushes_honored(),
        ..InternalsSnapshot::default()
    }
}

/// Convert the slab's per-class occupancy into `stats slabs` rows.
pub(crate) fn slab_class_snapshots(slab: &crate::slab::Slab) -> Vec<SlabClassSnapshot> {
    slab.class_stats()
        .iter()
        .map(|c| SlabClassSnapshot {
            chunk_size: c.chunk_size,
            live_chunks: c.live_chunks,
            cached_chunks: c.cached_chunks,
            total_chunks: c.total_chunks,
        })
        .collect()
}

/// The engine-neutral cache interface (Memcached text-protocol semantics).
///
/// The API is two-tier, **sink-first**: the single-key methods below are
/// the convenience tier; the batched core the serving plane uses is
/// [`Cache::execute_batch_into`], which streams one result per op into a
/// caller-supplied [`BatchSink`] — GET hits hand the sink the item's
/// bytes *borrowed from the engine* (FLeeC: slab bytes kept alive by the
/// pinned batch guard; blocking engines: entry bytes under the held
/// stripe lock), so a consumer can move value bytes slab→destination in
/// one copy with no intermediate allocation. [`Cache::execute_batch`] is
/// the owned-results convenience wrapper over a [`CollectSink`].
pub trait Cache: Send + Sync {
    /// Engine identifier used by the CLI / benches.
    fn engine_name(&self) -> &'static str;

    /// Execute a batch of typed commands, delivering exactly one result
    /// per op into `sink` (indices are batch positions; delivery order is
    /// unspecified — see [`BatchSink`]). Must be indistinguishable from
    /// running the ops sequentially through the single-key methods (same
    /// results, state and `cas`-token sequence); engines implement it
    /// natively to cut per-operation synchronization cost and to lend
    /// value bytes without copying ([`op::execute_sequential_into`] is
    /// the reference body, one trait crossing per op).
    ///
    /// Caveat at the memory limit: a batching engine may pre-allocate a
    /// batch's storage up front and hold synchronization state across
    /// it, so *which* victims get evicted — and whether a store reports
    /// `OutOfMemory` — can differ from a sequential run under pressure.
    /// Per-op semantics (preconditions, cas gating, reply values for
    /// the state actually observed) are honored regardless.
    fn execute_batch_into(&self, ops: &[Op<'_>], sink: &mut dyn BatchSink);

    /// Owned-results convenience tier over [`Cache::execute_batch_into`]:
    /// collect every delivery (copying value bytes) and return them
    /// index-aligned with the input batch.
    fn execute_batch(&self, ops: &[Op<'_>]) -> Vec<OpResult> {
        let mut sink = CollectSink::new(ops.len());
        self.execute_batch_into(ops, &mut sink);
        sink.into_results()
    }

    /// Look up `key`; bumps recency on hit.
    fn get(&self, key: &[u8]) -> Option<GetResult>;

    /// Unconditional store.
    fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome;

    /// Store only if absent.
    fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome;

    /// Store only if present.
    fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome;

    /// Append bytes to an existing value.
    fn append(&self, key: &[u8], suffix: &[u8]) -> StoreOutcome;

    /// Prepend bytes to an existing value.
    fn prepend(&self, key: &[u8], prefix: &[u8]) -> StoreOutcome;

    /// Compare-and-store against a `cas` token from [`Cache::get`].
    fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> StoreOutcome;

    /// Remove `key`; whether it was present.
    fn delete(&self, key: &[u8]) -> bool;

    /// Increment a decimal value; `None` when missing or non-numeric.
    fn incr(&self, key: &[u8], delta: u64) -> Option<u64>;

    /// Decrement (saturating at 0 per the protocol).
    fn decr(&self, key: &[u8], delta: u64) -> Option<u64>;

    /// Update expiry only.
    fn touch(&self, key: &[u8], exptime: u32) -> bool;

    /// Drop everything.
    fn flush_all(&self);

    /// Live item count (approximate under concurrency).
    fn item_count(&self) -> usize;

    /// Current bucket count (for expansion tests / stats).
    fn bucket_count(&self) -> usize;

    /// Value-memory in use, as accounted by the engine's allocator.
    fn mem_used(&self) -> usize;

    /// The configured value-memory budget (`stats` reports it as
    /// `limit_maxbytes`). Aggregating engines sum their shards'.
    fn mem_limit(&self) -> usize;

    /// One coherent `stats` view — the **only** counter read path the
    /// trait exposes. Bare engines assemble their own figures (each keeps
    /// a live `EngineMetrics` as an inherent detail); aggregating caches
    /// like [`sharded::Sharded`] merge their children's snapshots, so a
    /// generic consumer can never land on a counter view that an
    /// aggregator silently leaves at zero. (The trait used to also expose
    /// the live `metrics()` handle, which had exactly that trap.)
    fn stats(&self) -> StatsSnapshot;

    /// Background maintenance hook driven by the coordinator (expansion
    /// tail work, reclamation nudges). Default: nothing.
    fn maintenance(&self) {}

    /// Snapshot of the per-bucket CLOCK values, when the engine has them
    /// (planner input). `None` for the strict-LRU baseline.
    fn clock_snapshot(&self) -> Option<Vec<u8>> {
        None
    }

    /// Apply planner-chosen eviction parameters (CLOCK engines only).
    fn set_evict_params(&self, _decay: u8, _batch: u32) {}

    /// The slab allocators backing this cache, for the multi-tenant
    /// plane ([`tenant`]): per-tenant accounting and budget words live
    /// on the slab, so the plane enables tenancy on and arbitrates over
    /// exactly these. Routers concatenate their shards'. Engines without
    /// a slab (the blocking baselines) return nothing — they still get
    /// namespace isolation and per-tenant hit stats, just no memory
    /// accounting or arbitration.
    fn tenant_slabs(&self) -> Vec<Arc<crate::slab::Slab>> {
        Vec::new()
    }
}

/// Construct an engine by name (CLI / benches).
pub fn build_engine(name: &str, config: CacheConfig) -> crate::Result<Arc<dyn Cache>> {
    match name {
        "fleec" => Ok(Arc::new(fleec::FleecCache::new(config))),
        "oaflash" => Ok(Arc::new(oaflash::OaFlashCache::new(config))),
        "memcached" => Ok(Arc::new(memcached::MemcachedCache::new(config))),
        "memclock" => Ok(Arc::new(memclock::MemClockCache::new(config))),
        other => anyhow::bail!("unknown engine '{other}' (expected fleec|oaflash|memcached|memclock)"),
    }
}

/// Construct an engine behind an N-shard key-hash router
/// ([`sharded::Sharded`]). `shards <= 1` returns the bare engine (no
/// router layer on the depth-1 path); larger counts round up to a power
/// of two. The configured `mem_limit`/`initial_buckets` are divided
/// across shards so aggregate capacity matches the unsharded build.
pub fn build_sharded(
    name: &str,
    shards: usize,
    config: CacheConfig,
) -> crate::Result<Arc<dyn Cache>> {
    if shards <= 1 {
        return build_engine(name, config);
    }
    match name {
        "fleec" => Ok(Arc::new(sharded::Sharded::from_fn(shards, config, |_, c| {
            fleec::FleecCache::new(c)
        }))),
        "memcached" => Ok(Arc::new(sharded::Sharded::from_fn(shards, config, |_, c| {
            memcached::MemcachedCache::new(c)
        }))),
        "memclock" => Ok(Arc::new(sharded::Sharded::from_fn(shards, config, |_, c| {
            memclock::MemClockCache::new(c)
        }))),
        "oaflash" => Ok(Arc::new(sharded::Sharded::from_fn(shards, config, |_, c| {
            oaflash::OaFlashCache::new(c)
        }))),
        other => anyhow::bail!("unknown engine '{other}' (expected fleec|oaflash|memcached|memclock)"),
    }
}

/// All engine names, baseline-first (bench iteration order).
pub const ENGINES: [&str; 4] = ["memcached", "memclock", "fleec", "oaflash"];

/// FNV-1a 64-bit — the hash every engine uses so key placement is
/// identical across the three systems (fair hit-ratio comparisons).
#[inline]
pub fn hash_key(key: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in key {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    // Final avalanche so power-of-two masking uses high entropy.
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^ (h >> 33)
}

/// Seconds since the cache process started (item expiry clock).
pub fn uptime_secs() -> u32 {
    use once_cell::sync::Lazy;
    static START: Lazy<std::time::Instant> = Lazy::new(std::time::Instant::now);
    START.elapsed().as_secs() as u32
}

/// Resolve a protocol `exptime` to an absolute uptime deadline.
/// 0 = never; ≤ 60×60×24×30 = relative seconds; larger = unix time (we
/// treat it as relative to start for determinism in benches).
pub fn deadline_from_exptime(exptime: u32) -> u32 {
    const THIRTY_DAYS: u32 = 60 * 60 * 24 * 30;
    match exptime {
        0 => 0,
        t if t <= THIRTY_DAYS => uptime_secs().saturating_add(t).max(1),
        t => t.max(1),
    }
}

/// Whether an absolute deadline has passed.
#[inline]
pub fn is_expired(deadline: u32) -> bool {
    deadline != 0 && uptime_secs() >= deadline
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_stable_and_spreads() {
        assert_eq!(hash_key(b"key1"), hash_key(b"key1"));
        assert_ne!(hash_key(b"key1"), hash_key(b"key2"));
        // Low bits must differ for sequential keys (power-of-two masking).
        let mut low = std::collections::HashSet::new();
        for i in 0..256u32 {
            low.insert(hash_key(format!("k{i:012}").as_bytes()) & 0xff);
        }
        // 256 balls into 256 bins leave ≈ 256·(1−e⁻¹) ≈ 162 distinct.
        assert!(low.len() > 140, "low-bit entropy too poor: {}", low.len());
    }

    #[test]
    fn exptime_resolution_rules() {
        assert_eq!(deadline_from_exptime(0), 0);
        let d = deadline_from_exptime(10);
        assert!(d >= 10 && d >= uptime_secs());
        assert!(!is_expired(0), "0 never expires");
        assert!(is_expired(1).eq(&(uptime_secs() >= 1)));
    }

    #[test]
    fn build_engine_rejects_unknown() {
        assert!(build_engine("nope", CacheConfig::small()).is_err());
    }
}
