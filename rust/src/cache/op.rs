//! Typed, owner-less command representation — the batched half of the
//! two-tier cache API — and the **result sink** batch results flow
//! through.
//!
//! [`Op`] is one cache command with **borrowed** keys/values (no
//! allocation to build a batch; the server borrows straight from its read
//! buffer, the driver from its per-thread scratch buffers). [`OpResult`]
//! mirrors the protocol's reply space one-to-one, so a reply writer can
//! render a result without consulting the op that produced it.
//!
//! The primary executor is
//! [`crate::cache::Cache::execute_batch_into`]: it pushes one result per
//! op into a caller-supplied [`BatchSink`]. A GET hit is delivered as
//! [`BatchSink::value`] with the item's bytes **borrowed from the
//! engine** — FLeeC hands out the slab bytes directly while its batch
//! guard is pinned (epoch reclamation keeps the slice stable for the
//! whole batch), the blocking engines hand out the entry's bytes while
//! holding its stripe lock — so a sink can stream value bytes to their
//! final destination (the server writes them straight into the
//! connection outbuf) without the engine ever materializing an owned
//! copy. [`crate::cache::Cache::execute_batch`] is the convenience
//! wrapper: it runs a [`CollectSink`] and returns owned, index-aligned
//! [`OpResult`]s.
//!
//! The contract every engine must obey: a batch behaves exactly like
//! issuing its ops sequentially through the single-key convenience
//! methods — same results, same final state, same `cas`-token sequence.
//! Batching is purely a *synchronization* optimization (the FLeeC engine
//! pins one EBR guard for a whole batch instead of one per op), never a
//! semantic one. `rust/tests/batch_semantics.rs` holds every engine to
//! this equivalence. (Sole carve-out, documented on the trait: at the
//! memory limit, eviction timing and `OutOfMemory` outcomes may differ
//! from a sequential run.)

use super::{Cache, GetResult, StoreOutcome};

/// One cache command, borrowing key/value bytes from the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op<'a> {
    /// Look up a key (`get`/`gets` — CAS tokens are always returned).
    Get { key: &'a [u8] },
    /// Unconditional store.
    Set {
        key: &'a [u8],
        value: &'a [u8],
        flags: u32,
        exptime: u32,
    },
    /// Store only if absent.
    Add {
        key: &'a [u8],
        value: &'a [u8],
        flags: u32,
        exptime: u32,
    },
    /// Store only if present.
    Replace {
        key: &'a [u8],
        value: &'a [u8],
        flags: u32,
        exptime: u32,
    },
    /// Append bytes to an existing value.
    Append { key: &'a [u8], suffix: &'a [u8] },
    /// Prepend bytes to an existing value.
    Prepend { key: &'a [u8], prefix: &'a [u8] },
    /// Compare-and-store against a token from a previous read.
    CasOp {
        key: &'a [u8],
        value: &'a [u8],
        flags: u32,
        exptime: u32,
        cas: u64,
    },
    /// Remove a key.
    Delete { key: &'a [u8] },
    /// Increment a decimal value.
    Incr { key: &'a [u8], delta: u64 },
    /// Decrement a decimal value (saturating at 0).
    Decr { key: &'a [u8], delta: u64 },
    /// Update expiry only.
    Touch { key: &'a [u8], exptime: u32 },
}

impl<'a> Op<'a> {
    /// The key this op addresses.
    #[inline]
    pub fn key(&self) -> &'a [u8] {
        match *self {
            Op::Get { key }
            | Op::Set { key, .. }
            | Op::Add { key, .. }
            | Op::Replace { key, .. }
            | Op::Append { key, .. }
            | Op::Prepend { key, .. }
            | Op::CasOp { key, .. }
            | Op::Delete { key }
            | Op::Incr { key, .. }
            | Op::Decr { key, .. }
            | Op::Touch { key, .. } => key,
        }
    }

    /// Whether the op leaves cache state untouched (modulo recency).
    #[inline]
    pub fn is_read(&self) -> bool {
        matches!(self, Op::Get { .. })
    }

    /// The latency class this op records under (`stats latency`):
    /// lookups, fresh installs, read-modify-writes and unlinks have
    /// mechanically different costs, so they get separate histograms.
    #[inline]
    pub fn class(&self) -> crate::metrics::OpClass {
        use crate::metrics::OpClass;
        match self {
            Op::Get { .. } => OpClass::Get,
            Op::Set { .. } | Op::Add { .. } | Op::Replace { .. } | Op::CasOp { .. } => {
                OpClass::Store
            }
            Op::Append { .. }
            | Op::Prepend { .. }
            | Op::Incr { .. }
            | Op::Decr { .. }
            | Op::Touch { .. } => OpClass::Rmw,
            Op::Delete { .. } => OpClass::Delete,
        }
    }
}

/// Result of one executed [`Op`], index-aligned with the input batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpResult {
    /// `Get` outcome (`None` = miss).
    Value(Option<GetResult>),
    /// Outcome of any of the six storage commands.
    Store(StoreOutcome),
    /// `Delete` outcome: whether the key was present.
    Deleted(bool),
    /// `Incr`/`Decr` outcome (`None` = missing or non-numeric).
    Counter(Option<u64>),
    /// `Touch` outcome: whether the key was present.
    Touched(bool),
}

/// Receiver for batch results — the zero-copy half of the batch API.
///
/// [`crate::cache::Cache::execute_batch_into`] calls **exactly one**
/// method per op, passing the op's batch index. The contract, on both
/// sides of the boundary:
///
/// * **Delivery order is unspecified.** Bare engines deliver in batch
///   order, but routers ([`crate::cache::sharded::Sharded`]) deliver
///   shard-grouped — each op's index is correct, their sequence is not.
///   A sink that renders in batch order must reorder (see
///   `server::batch`'s emitter, which parks out-of-order results and
///   streams the in-order prefix straight through).
/// * **`value`'s `data` slice is borrowed from the engine** and valid
///   only for the duration of the call: FLeeC lends slab bytes kept
///   alive by its pinned batch guard, the blocking engines lend entry
///   bytes under a held lock. Copy it if you need it later; never stash
///   the reference. (On FLeeC the bytes are in fact stable until
///   `execute_batch_into` returns — concurrent overwrites and evictions
///   only *retire* items through EBR, and the batch guard holds the
///   epoch — which is what makes lending them across the API boundary
///   sound. `rust/tests/read_path.rs` stress-tests this.)
/// * **A sink must not call back into the cache** (single-key methods or
///   another batch): the engine may be holding locks or an EBR guard
///   across the call, so re-entry can deadlock or pin epochs forever.
///   Sinks should do cheap, non-blocking work — format bytes, bump
///   counters, copy out.
pub trait BatchSink {
    /// `Get` hit: header fields plus the value bytes (borrowed — see the
    /// trait docs for the lifetime contract).
    fn value(&mut self, idx: usize, key: &[u8], flags: u32, cas: u64, data: &[u8]);
    /// `Get` miss.
    fn miss(&mut self, idx: usize);
    /// Outcome of any of the six storage commands.
    fn store(&mut self, idx: usize, outcome: StoreOutcome);
    /// `Delete` outcome: whether the key was present.
    fn deleted(&mut self, idx: usize, existed: bool);
    /// `Incr`/`Decr` outcome (`None` = missing or non-numeric).
    fn counter(&mut self, idx: usize, value: Option<u64>);
    /// `Touch` outcome: whether the key was present.
    fn touched(&mut self, idx: usize, existed: bool);
}

/// The collecting sink behind the owned-results convenience tier:
/// copies every delivery into an index-aligned `Vec<OpResult>`
/// (tolerating out-of-order delivery from routers).
pub struct CollectSink {
    slots: Vec<Option<OpResult>>,
}

impl CollectSink {
    /// A sink expecting exactly `n` deliveries (one per op).
    pub fn new(n: usize) -> Self {
        CollectSink {
            slots: vec![None; n],
        }
    }

    /// Unwrap into index-aligned results. Panics if an engine broke the
    /// exactly-once contract and left a slot empty.
    pub fn into_results(self) -> Vec<OpResult> {
        self.slots
            .into_iter()
            .map(|r| r.expect("execute_batch_into left a result slot empty"))
            .collect()
    }

    fn put(&mut self, idx: usize, r: OpResult) {
        debug_assert!(self.slots[idx].is_none(), "double delivery for op {idx}");
        self.slots[idx] = Some(r);
    }
}

impl BatchSink for CollectSink {
    fn value(&mut self, idx: usize, _key: &[u8], flags: u32, cas: u64, data: &[u8]) {
        self.put(
            idx,
            OpResult::Value(Some(GetResult {
                data: data.to_vec(),
                flags,
                cas,
            })),
        );
    }

    fn miss(&mut self, idx: usize) {
        self.put(idx, OpResult::Value(None));
    }

    fn store(&mut self, idx: usize, outcome: StoreOutcome) {
        self.put(idx, OpResult::Store(outcome));
    }

    fn deleted(&mut self, idx: usize, existed: bool) {
        self.put(idx, OpResult::Deleted(existed));
    }

    fn counter(&mut self, idx: usize, value: Option<u64>) {
        self.put(idx, OpResult::Counter(value));
    }

    fn touched(&mut self, idx: usize, existed: bool) {
        self.put(idx, OpResult::Touched(existed));
    }
}

/// Execute one op through the single-key convenience methods and deliver
/// its result to `sink` as op `idx`. The building block engines use for
/// ops they have no sink-native path for.
pub fn forward_one<C: Cache + ?Sized>(cache: &C, idx: usize, op: &Op<'_>, sink: &mut dyn BatchSink) {
    match *op {
        Op::Get { key } => match cache.get(key) {
            Some(r) => sink.value(idx, key, r.flags, r.cas, &r.data),
            None => sink.miss(idx),
        },
        Op::Set {
            key,
            value,
            flags,
            exptime,
        } => sink.store(idx, cache.set(key, value, flags, exptime)),
        Op::Add {
            key,
            value,
            flags,
            exptime,
        } => sink.store(idx, cache.add(key, value, flags, exptime)),
        Op::Replace {
            key,
            value,
            flags,
            exptime,
        } => sink.store(idx, cache.replace(key, value, flags, exptime)),
        Op::Append { key, suffix } => sink.store(idx, cache.append(key, suffix)),
        Op::Prepend { key, prefix } => sink.store(idx, cache.prepend(key, prefix)),
        Op::CasOp {
            key,
            value,
            flags,
            exptime,
            cas,
        } => sink.store(idx, cache.cas(key, value, flags, exptime, cas)),
        Op::Delete { key } => sink.deleted(idx, cache.delete(key)),
        Op::Incr { key, delta } => sink.counter(idx, cache.incr(key, delta)),
        Op::Decr { key, delta } => sink.counter(idx, cache.decr(key, delta)),
        Op::Touch { key, exptime } => sink.touched(idx, cache.touch(key, exptime)),
    }
}

/// Reference sink executor: one trait crossing per op, delivery in batch
/// order. The body an engine without any batch-level synchronization
/// opportunity would write.
pub fn execute_sequential_into<C: Cache + ?Sized>(
    cache: &C,
    ops: &[Op<'_>],
    sink: &mut dyn BatchSink,
) {
    for (idx, op) in ops.iter().enumerate() {
        forward_one(cache, idx, op, sink);
    }
}

/// Execute one op through the single-key convenience methods.
pub fn execute_one<C: Cache + ?Sized>(cache: &C, op: &Op<'_>) -> OpResult {
    match *op {
        Op::Get { key } => OpResult::Value(cache.get(key)),
        Op::Set {
            key,
            value,
            flags,
            exptime,
        } => OpResult::Store(cache.set(key, value, flags, exptime)),
        Op::Add {
            key,
            value,
            flags,
            exptime,
        } => OpResult::Store(cache.add(key, value, flags, exptime)),
        Op::Replace {
            key,
            value,
            flags,
            exptime,
        } => OpResult::Store(cache.replace(key, value, flags, exptime)),
        Op::Append { key, suffix } => OpResult::Store(cache.append(key, suffix)),
        Op::Prepend { key, prefix } => OpResult::Store(cache.prepend(key, prefix)),
        Op::CasOp {
            key,
            value,
            flags,
            exptime,
            cas,
        } => OpResult::Store(cache.cas(key, value, flags, exptime, cas)),
        Op::Delete { key } => OpResult::Deleted(cache.delete(key)),
        Op::Incr { key, delta } => OpResult::Counter(cache.incr(key, delta)),
        Op::Decr { key, delta } => OpResult::Counter(cache.decr(key, delta)),
        Op::Touch { key, exptime } => OpResult::Touched(cache.touch(key, exptime)),
    }
}

/// Reference batch executor: one trait crossing per op, owned results.
/// The semantic oracle the equivalence tests compare fast paths against.
pub fn execute_sequential<C: Cache + ?Sized>(cache: &C, ops: &[Op<'_>]) -> Vec<OpResult> {
    ops.iter().map(|op| execute_one(cache, op)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};

    #[test]
    fn op_key_extraction_covers_all_variants() {
        let ops = [
            Op::Get { key: b"k" },
            Op::Set {
                key: b"k",
                value: b"v",
                flags: 0,
                exptime: 0,
            },
            Op::Append {
                key: b"k",
                suffix: b"s",
            },
            Op::Delete { key: b"k" },
            Op::Incr { key: b"k", delta: 1 },
            Op::Touch { key: b"k", exptime: 5 },
        ];
        for op in &ops {
            assert_eq!(op.key(), b"k");
        }
        assert!(ops[0].is_read());
        assert!(!ops[1].is_read());
    }

    #[test]
    fn default_batch_matches_single_key_methods() {
        for engine in crate::cache::ENGINES {
            let cache = build_engine(engine, CacheConfig::small()).unwrap();
            let ops = [
                Op::Set {
                    key: b"a",
                    value: b"1",
                    flags: 7,
                    exptime: 0,
                },
                Op::Get { key: b"a" },
                Op::Incr { key: b"a", delta: 41 },
                Op::Get { key: b"missing" },
                Op::Delete { key: b"a" },
                Op::Delete { key: b"a" },
            ];
            let results = cache.execute_batch(&ops);
            assert_eq!(results.len(), ops.len(), "{engine}");
            assert_eq!(results[0], OpResult::Store(StoreOutcome::Stored), "{engine}");
            match &results[1] {
                OpResult::Value(Some(r)) => {
                    assert_eq!(r.data, b"1", "{engine}");
                    assert_eq!(r.flags, 7, "{engine}");
                }
                other => panic!("{engine}: {other:?}"),
            }
            assert_eq!(results[2], OpResult::Counter(Some(42)), "{engine}");
            assert_eq!(results[3], OpResult::Value(None), "{engine}");
            assert_eq!(results[4], OpResult::Deleted(true), "{engine}");
            assert_eq!(results[5], OpResult::Deleted(false), "{engine}");
        }
    }

    /// A sink that records the order and shape of every delivery.
    #[derive(Default)]
    struct TraceSink {
        calls: Vec<(usize, OpResult)>,
    }

    impl BatchSink for TraceSink {
        fn value(&mut self, idx: usize, _key: &[u8], flags: u32, cas: u64, data: &[u8]) {
            self.calls.push((
                idx,
                OpResult::Value(Some(GetResult {
                    data: data.to_vec(),
                    flags,
                    cas,
                })),
            ));
        }
        fn miss(&mut self, idx: usize) {
            self.calls.push((idx, OpResult::Value(None)));
        }
        fn store(&mut self, idx: usize, outcome: StoreOutcome) {
            self.calls.push((idx, OpResult::Store(outcome)));
        }
        fn deleted(&mut self, idx: usize, existed: bool) {
            self.calls.push((idx, OpResult::Deleted(existed)));
        }
        fn counter(&mut self, idx: usize, value: Option<u64>) {
            self.calls.push((idx, OpResult::Counter(value)));
        }
        fn touched(&mut self, idx: usize, existed: bool) {
            self.calls.push((idx, OpResult::Touched(existed)));
        }
    }

    #[test]
    fn sink_path_delivers_exactly_once_per_op_on_every_engine() {
        for engine in crate::cache::ENGINES {
            let cache = build_engine(engine, CacheConfig::small()).unwrap();
            cache.set(b"n", b"5", 0, 0);
            let ops = [
                Op::Set {
                    key: b"a",
                    value: b"hello",
                    flags: 3,
                    exptime: 0,
                },
                Op::Get { key: b"a" },
                Op::Get { key: b"missing" },
                Op::Incr { key: b"n", delta: 2 },
                Op::Touch { key: b"a", exptime: 60 },
                Op::Delete { key: b"a" },
            ];
            let mut sink = TraceSink::default();
            cache.execute_batch_into(&ops, &mut sink);
            assert_eq!(sink.calls.len(), ops.len(), "{engine}: one call per op");
            let mut seen = vec![false; ops.len()];
            for &(idx, _) in &sink.calls {
                assert!(!seen[idx], "{engine}: double delivery for op {idx}");
                seen[idx] = true;
            }
            // Sink deliveries must agree with the owned convenience tier
            // run on a fresh identical cache.
            let oracle = build_engine(engine, CacheConfig::small()).unwrap();
            oracle.set(b"n", b"5", 0, 0);
            let owned = oracle.execute_batch(&ops);
            for &(idx, ref r) in &sink.calls {
                assert_eq!(r, &owned[idx], "{engine}: op {idx}");
            }
            match &sink.calls.iter().find(|(i, _)| *i == 1).unwrap().1 {
                OpResult::Value(Some(r)) => {
                    assert_eq!(r.data, b"hello", "{engine}");
                    assert_eq!(r.flags, 3, "{engine}");
                }
                other => panic!("{engine}: {other:?}"),
            }
        }
    }

    #[test]
    fn collect_sink_tolerates_out_of_order_delivery() {
        let mut sink = CollectSink::new(3);
        sink.counter(2, Some(7));
        sink.miss(0);
        sink.store(1, StoreOutcome::Stored);
        assert_eq!(
            sink.into_results(),
            vec![
                OpResult::Value(None),
                OpResult::Store(StoreOutcome::Stored),
                OpResult::Counter(Some(7)),
            ]
        );
    }
}
