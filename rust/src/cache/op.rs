//! Typed, owner-less command representation — the batched half of the
//! two-tier cache API.
//!
//! [`Op`] is one cache command with **borrowed** keys/values (no
//! allocation to build a batch; the server borrows straight from its read
//! buffer, the driver from its per-thread scratch buffers). [`OpResult`]
//! mirrors the protocol's reply space one-to-one, so a reply writer can
//! render a result without consulting the op that produced it.
//!
//! [`crate::cache::Cache::execute_batch`] takes a slice of ops and returns
//! one result per op, **in order**. The contract every engine must obey:
//! a batch behaves exactly like issuing its ops sequentially through the
//! single-key convenience methods — same results, same final state, same
//! `cas`-token sequence. Batching is purely a *synchronization* optimization
//! (the FLeeC engine pins one EBR guard for a whole batch instead of one
//! per op), never a semantic one. `rust/tests/batch_semantics.rs` holds
//! every engine to this equivalence. (Sole carve-out, documented on the
//! trait: at the memory limit, eviction timing and `OutOfMemory`
//! outcomes may differ from a sequential run.)

use super::{Cache, GetResult, StoreOutcome};

/// One cache command, borrowing key/value bytes from the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op<'a> {
    /// Look up a key (`get`/`gets` — CAS tokens are always returned).
    Get { key: &'a [u8] },
    /// Unconditional store.
    Set {
        key: &'a [u8],
        value: &'a [u8],
        flags: u32,
        exptime: u32,
    },
    /// Store only if absent.
    Add {
        key: &'a [u8],
        value: &'a [u8],
        flags: u32,
        exptime: u32,
    },
    /// Store only if present.
    Replace {
        key: &'a [u8],
        value: &'a [u8],
        flags: u32,
        exptime: u32,
    },
    /// Append bytes to an existing value.
    Append { key: &'a [u8], suffix: &'a [u8] },
    /// Prepend bytes to an existing value.
    Prepend { key: &'a [u8], prefix: &'a [u8] },
    /// Compare-and-store against a token from a previous read.
    CasOp {
        key: &'a [u8],
        value: &'a [u8],
        flags: u32,
        exptime: u32,
        cas: u64,
    },
    /// Remove a key.
    Delete { key: &'a [u8] },
    /// Increment a decimal value.
    Incr { key: &'a [u8], delta: u64 },
    /// Decrement a decimal value (saturating at 0).
    Decr { key: &'a [u8], delta: u64 },
    /// Update expiry only.
    Touch { key: &'a [u8], exptime: u32 },
}

impl<'a> Op<'a> {
    /// The key this op addresses.
    #[inline]
    pub fn key(&self) -> &'a [u8] {
        match *self {
            Op::Get { key }
            | Op::Set { key, .. }
            | Op::Add { key, .. }
            | Op::Replace { key, .. }
            | Op::Append { key, .. }
            | Op::Prepend { key, .. }
            | Op::CasOp { key, .. }
            | Op::Delete { key }
            | Op::Incr { key, .. }
            | Op::Decr { key, .. }
            | Op::Touch { key, .. } => key,
        }
    }

    /// Whether the op leaves cache state untouched (modulo recency).
    #[inline]
    pub fn is_read(&self) -> bool {
        matches!(self, Op::Get { .. })
    }
}

/// Result of one executed [`Op`], index-aligned with the input batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpResult {
    /// `Get` outcome (`None` = miss).
    Value(Option<GetResult>),
    /// Outcome of any of the six storage commands.
    Store(StoreOutcome),
    /// `Delete` outcome: whether the key was present.
    Deleted(bool),
    /// `Incr`/`Decr` outcome (`None` = missing or non-numeric).
    Counter(Option<u64>),
    /// `Touch` outcome: whether the key was present.
    Touched(bool),
}

/// Execute one op through the single-key convenience methods.
pub fn execute_one<C: Cache + ?Sized>(cache: &C, op: &Op<'_>) -> OpResult {
    match *op {
        Op::Get { key } => OpResult::Value(cache.get(key)),
        Op::Set {
            key,
            value,
            flags,
            exptime,
        } => OpResult::Store(cache.set(key, value, flags, exptime)),
        Op::Add {
            key,
            value,
            flags,
            exptime,
        } => OpResult::Store(cache.add(key, value, flags, exptime)),
        Op::Replace {
            key,
            value,
            flags,
            exptime,
        } => OpResult::Store(cache.replace(key, value, flags, exptime)),
        Op::Append { key, suffix } => OpResult::Store(cache.append(key, suffix)),
        Op::Prepend { key, prefix } => OpResult::Store(cache.prepend(key, prefix)),
        Op::CasOp {
            key,
            value,
            flags,
            exptime,
            cas,
        } => OpResult::Store(cache.cas(key, value, flags, exptime, cas)),
        Op::Delete { key } => OpResult::Deleted(cache.delete(key)),
        Op::Incr { key, delta } => OpResult::Counter(cache.incr(key, delta)),
        Op::Decr { key, delta } => OpResult::Counter(cache.decr(key, delta)),
        Op::Touch { key, exptime } => OpResult::Touched(cache.touch(key, exptime)),
    }
}

/// Reference batch executor: one trait crossing per op. This is the
/// default [`Cache::execute_batch`] body, and the semantic oracle the
/// equivalence tests compare fast paths against.
pub fn execute_sequential<C: Cache + ?Sized>(cache: &C, ops: &[Op<'_>]) -> Vec<OpResult> {
    ops.iter().map(|op| execute_one(cache, op)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};

    #[test]
    fn op_key_extraction_covers_all_variants() {
        let ops = [
            Op::Get { key: b"k" },
            Op::Set {
                key: b"k",
                value: b"v",
                flags: 0,
                exptime: 0,
            },
            Op::Append {
                key: b"k",
                suffix: b"s",
            },
            Op::Delete { key: b"k" },
            Op::Incr { key: b"k", delta: 1 },
            Op::Touch { key: b"k", exptime: 5 },
        ];
        for op in &ops {
            assert_eq!(op.key(), b"k");
        }
        assert!(ops[0].is_read());
        assert!(!ops[1].is_read());
    }

    #[test]
    fn default_batch_matches_single_key_methods() {
        for engine in crate::cache::ENGINES {
            let cache = build_engine(engine, CacheConfig::small()).unwrap();
            let ops = [
                Op::Set {
                    key: b"a",
                    value: b"1",
                    flags: 7,
                    exptime: 0,
                },
                Op::Get { key: b"a" },
                Op::Incr { key: b"a", delta: 41 },
                Op::Get { key: b"missing" },
                Op::Delete { key: b"a" },
                Op::Delete { key: b"a" },
            ];
            let results = cache.execute_batch(&ops);
            assert_eq!(results.len(), ops.len(), "{engine}");
            assert_eq!(results[0], OpResult::Store(StoreOutcome::Stored), "{engine}");
            match &results[1] {
                OpResult::Value(Some(r)) => {
                    assert_eq!(r.data, b"1", "{engine}");
                    assert_eq!(r.flags, 7, "{engine}");
                }
                other => panic!("{engine}: {other:?}"),
            }
            assert_eq!(results[2], OpResult::Counter(Some(42)), "{engine}");
            assert_eq!(results[3], OpResult::Value(None), "{engine}");
            assert_eq!(results[4], OpResult::Deleted(true), "{engine}");
            assert_eq!(results[5], OpResult::Deleted(false), "{engine}");
        }
    }
}
