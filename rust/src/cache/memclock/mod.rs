//! MemcLock — the paper's **intermediate system**: Memcached's blocking
//! striped-lock hash table, but with the strict-LRU list replaced by the
//! hash-table-embedded CLOCK policy (one multi-bit CLOCK value per
//! bucket).
//!
//! This isolates the *eviction-policy* change from the *concurrency
//! control* change: hits bump an atomic CLOCK value instead of taking the
//! global LRU lock, yet every lookup/store still serializes on its stripe
//! and expansion is still stop-the-world. The paper's evaluation question
//! — "what does approximating LRU cost in hit-ratio, and what does it buy
//! in performance?" — is answered by comparing this engine against both
//! neighbours.
//!
//! Stripe selection uses the hash's low bits, which are also the bucket's
//! low bits, so `stripes ≤ buckets` keeps bucket↔stripe mapping stable
//! across expansions (the same trick Memcached's item locks rely on).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::cache::{
    deadline_from_exptime, hash_key, is_expired, Cache, CacheConfig, GetResult, StatsSnapshot,
    StoreOutcome, MAX_KEY_LEN,
};
use crate::metrics::EngineMetrics;

/// Per-entry overhead charged to the budget (same constant as the
/// baseline so memory comparisons are apples-to-apples).
const ENTRY_OVERHEAD: usize = 64;

struct CEntry {
    hash: u64,
    key: Box<[u8]>,
    value: Vec<u8>,
    flags: u32,
    deadline: u32,
    cas: u64,
}

impl CEntry {
    fn footprint(&self) -> usize {
        self.key.len() + self.value.len() + ENTRY_OVERHEAD
    }
}

struct TableState {
    buckets: Vec<Vec<Box<CEntry>>>,
    /// One CLOCK value per bucket (the embedded eviction state).
    clocks: Vec<AtomicU8>,
    mask: usize,
}

/// The blocking-table + CLOCK-eviction engine.
pub struct MemClockCache {
    stripes: Box<[Mutex<()>]>,
    state: UnsafeCell<TableState>,
    hand: AtomicUsize,
    items: AtomicUsize,
    bytes: AtomicUsize,
    cas_counter: AtomicU64,
    metrics: EngineMetrics,
    config: CacheConfig,
}

// SAFETY: the UnsafeCell'd table is only touched under stripe locks (all
// stripes for structural changes); everything else is atomics.
unsafe impl Send for MemClockCache {}
// SAFETY: same locking discipline as Send.
unsafe impl Sync for MemClockCache {}

impl MemClockCache {
    pub fn new(config: CacheConfig) -> Self {
        let buckets = config.initial_buckets.next_power_of_two();
        let nstripes = config.lock_stripes.next_power_of_two().min(buckets);
        MemClockCache {
            stripes: (0..nstripes).map(|_| Mutex::new(())).collect::<Vec<_>>().into_boxed_slice(),
            state: UnsafeCell::new(TableState {
                buckets: (0..buckets).map(|_| Vec::new()).collect(),
                clocks: (0..buckets).map(|_| AtomicU8::new(0)).collect(),
                mask: buckets - 1,
            }),
            hand: AtomicUsize::new(0),
            items: AtomicUsize::new(0),
            bytes: AtomicUsize::new(0),
            cas_counter: AtomicU64::new(0),
            metrics: EngineMetrics::default(),
            config,
        }
    }

    #[inline]
    fn stripe_of(&self, hash: u64) -> &Mutex<()> {
        &self.stripes[(hash as usize) & (self.stripes.len() - 1)]
    }

    /// # Safety
    /// Caller must hold the stripe lock(s) covering whatever it touches:
    /// one stripe for its own bucket, all stripes for structural fields
    /// (`mask`, the vectors themselves).
    #[allow(clippy::mut_from_ref)]
    unsafe fn state(&self) -> &mut TableState {
        &mut *self.state.get()
    }

    /// Find under the caller-held stripe.
    ///
    /// # Safety
    /// Caller must hold `hash`'s stripe lock.
    unsafe fn find(&self, hash: u64, key: &[u8]) -> Option<(usize, usize)> {
        let st = self.state();
        let idx = (hash as usize) & st.mask;
        st.buckets[idx]
            .iter()
            .position(|e| e.hash == hash && *e.key == *key)
            .map(|pos| (idx, pos))
    }

    /// Bump the bucket CLOCK to max (atomic; no lock beyond the stripe the
    /// caller already holds — and it would be safe lock-free too).
    ///
    /// # Safety
    /// Caller must hold `idx`'s stripe lock (pins the clocks vector).
    #[inline]
    unsafe fn touch_clock(&self, idx: usize) {
        let st = self.state();
        let max = self.config.clock_max;
        let c = &st.clocks[idx];
        if c.load(Ordering::Relaxed) != max {
            c.store(max, Ordering::Relaxed);
        }
    }

    /// # Safety
    /// Caller must hold `idx`'s stripe lock.
    unsafe fn remove_at(&self, idx: usize, pos: usize) -> Box<CEntry> {
        let st = self.state();
        let e = st.buckets[idx].swap_remove(pos);
        self.bytes.fetch_sub(e.footprint(), Ordering::Relaxed);
        self.items.fetch_sub(1, Ordering::Relaxed);
        e
    }

    /// CLOCK sweep until memory is under the limit: decrement warm
    /// buckets, empty cold ones (taking each bucket's stripe briefly).
    fn evict_to_limit(&self) {
        let mut scanned = 0usize;
        while self.bytes.load(Ordering::Relaxed) > self.config.mem_limit {
            let raw = self.hand.fetch_add(1, Ordering::Relaxed);
            let _s = self.stripes[raw & (self.stripes.len() - 1)].lock().unwrap();
            // SAFETY: `raw`'s stripe is locked above, and the bucket/clock
            // index below maps to that same stripe (stripes ≤ buckets).
            let st = unsafe { self.state() };
            let idx = raw & st.mask;
            scanned += 1;
            if scanned > 4 * (st.mask + 1) {
                break; // safety valve
            }
            let c = st.clocks[idx].load(Ordering::Relaxed);
            if c > 0 {
                st.clocks[idx].store(c - 1, Ordering::Relaxed);
                continue;
            }
            let n = st.buckets[idx].len();
            for _ in 0..n {
                // SAFETY: `idx`'s stripe lock is still held (`_s`).
                unsafe {
                    let _ = self.remove_at(idx, 0);
                }
                self.metrics.evictions.inc();
            }
        }
    }

    fn maybe_expand(&self) {
        let need = |items: usize, buckets: usize| {
            (items as f64) > self.config.load_factor * buckets as f64
        };
        {
            let _s0 = self.stripes[0].lock().unwrap();
            // SAFETY: only `mask` is read; it changes only under all
            // stripes, which includes the stripe-0 lock held here.
            let st = unsafe { self.state() };
            if !need(self.items.load(Ordering::Relaxed), st.mask + 1) {
                return;
            }
        }
        let guards: Vec<MutexGuard<()>> =
            self.stripes.iter().map(|s| s.lock().unwrap()).collect();
        // SAFETY: every stripe is locked — exclusive structural access.
        let st = unsafe { self.state() };
        if !need(self.items.load(Ordering::Relaxed), st.mask + 1) {
            return;
        }
        let new_size = (st.mask + 1) * 2;
        let mut new_buckets: Vec<Vec<Box<CEntry>>> = (0..new_size).map(|_| Vec::new()).collect();
        for bucket in st.buckets.drain(..) {
            for e in bucket {
                let idx = (e.hash as usize) & (new_size - 1);
                new_buckets[idx].push(e);
            }
        }
        st.buckets = new_buckets;
        st.clocks = (0..new_size).map(|_| AtomicU8::new(1)).collect();
        st.mask = new_size - 1;
        self.metrics.expansions.inc();
        drop(guards);
    }

    fn store_inner(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, mode: Mode) -> StoreOutcome {
        if key.len() > MAX_KEY_LEN || key.is_empty() {
            return StoreOutcome::NotStored;
        }
        self.metrics.sets.inc();
        let hash = hash_key(key);
        let deadline = deadline_from_exptime(exptime);
        let cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
        let outcome = {
            let _s = self.stripe_of(hash).lock().unwrap();
            // SAFETY: `hash`'s stripe lock is held for the whole block;
            // every touched bucket/clock index maps to that stripe.
            unsafe {
                match self.find(hash, key) {
                    Some((idx, pos)) => {
                        let st = self.state();
                        if is_expired(st.buckets[idx][pos].deadline) {
                            let _ = self.remove_at(idx, pos);
                            self.metrics.expired.inc();
                            match mode {
                                Mode::Replace | Mode::Cas(_) => StoreOutcome::NotFound,
                                _ => self.insert_new(hash, key, value, flags, deadline, cas),
                            }
                        } else {
                            let e = &mut st.buckets[idx][pos];
                            match mode {
                                Mode::Add => StoreOutcome::NotStored,
                                Mode::Cas(tok) if e.cas != tok => StoreOutcome::Exists,
                                _ => {
                                    let old = e.value.len();
                                    e.value.clear();
                                    e.value.extend_from_slice(value);
                                    e.flags = flags;
                                    e.deadline = deadline;
                                    e.cas = cas;
                                    if value.len() >= old {
                                        self.bytes.fetch_add(value.len() - old, Ordering::Relaxed);
                                    } else {
                                        self.bytes.fetch_sub(old - value.len(), Ordering::Relaxed);
                                    }
                                    self.touch_clock(idx);
                                    StoreOutcome::Stored
                                }
                            }
                        }
                    }
                    None => match mode {
                        Mode::Replace | Mode::Cas(_) => StoreOutcome::NotFound,
                        _ => self.insert_new(hash, key, value, flags, deadline, cas),
                    },
                }
            }
        };
        if outcome == StoreOutcome::Stored {
            self.evict_to_limit();
            self.maybe_expand();
        }
        outcome
    }

    /// # Safety
    /// Caller must hold `hash`'s stripe lock.
    unsafe fn insert_new(
        &self,
        hash: u64,
        key: &[u8],
        value: &[u8],
        flags: u32,
        deadline: u32,
        cas: u64,
    ) -> StoreOutcome {
        let st = self.state();
        let idx = (hash as usize) & st.mask;
        let e = Box::new(CEntry {
            hash,
            key: key.to_vec().into_boxed_slice(),
            value: value.to_vec(),
            flags,
            deadline,
            cas,
        });
        self.bytes.fetch_add(e.footprint(), Ordering::Relaxed);
        self.items.fetch_add(1, Ordering::Relaxed);
        st.buckets[idx].push(e);
        // Fresh insert: mildly warm (CLOCK 1 when cold), matching FLeeC.
        let _ = st.clocks[idx].compare_exchange(0, 1, Ordering::Relaxed, Ordering::Relaxed);
        StoreOutcome::Stored
    }

    fn rmw_inner(&self, key: &[u8], f: impl FnOnce(&mut CEntry) -> bool) -> Option<()> {
        let hash = hash_key(key);
        let _s = self.stripe_of(hash).lock().unwrap();
        // SAFETY: `hash`'s stripe lock is held for the whole block.
        unsafe {
            let (idx, pos) = self.find(hash, key)?;
            let st = self.state();
            if is_expired(st.buckets[idx][pos].deadline) {
                let _ = self.remove_at(idx, pos);
                self.metrics.expired.inc();
                return None;
            }
            let e = &mut st.buckets[idx][pos];
            let before = e.footprint();
            if !f(e) {
                return None;
            }
            e.cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
            let after = e.footprint();
            if after >= before {
                self.bytes.fetch_add(after - before, Ordering::Relaxed);
            } else {
                self.bytes.fetch_sub(before - after, Ordering::Relaxed);
            }
            self.touch_clock(idx);
        }
        Some(())
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Set,
    Add,
    Replace,
    Cas(u64),
}

impl MemClockCache {
    /// The engine's live request-path counters. Inherent on purpose:
    /// generic consumers read counters through the merging
    /// [`Cache::stats`] path only.
    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// Locked lookup core (metrics-free), shared by [`Cache::get`] and
    /// the sink batch path: on a live hit, calls `hit` with the entry's
    /// `(flags, cas, value)` **while the stripe lock is held** — the
    /// borrow is only valid inside the closure — then bumps the bucket
    /// CLOCK. Returns `None` on miss/expiry.
    fn get_with<R>(&self, key: &[u8], hit: impl FnOnce(u32, u64, &[u8]) -> R) -> Option<R> {
        let hash = hash_key(key);
        let _s = self.stripe_of(hash).lock().unwrap();
        // SAFETY: `hash`'s stripe lock is held for the whole block; the
        // `hit` borrow ends before the lock drops.
        unsafe {
            match self.find(hash, key) {
                Some((idx, pos)) => {
                    let st = self.state();
                    if is_expired(st.buckets[idx][pos].deadline) {
                        let _ = self.remove_at(idx, pos);
                        self.metrics.expired.inc();
                        None
                    } else {
                        let e = &st.buckets[idx][pos];
                        let r = hit(e.flags, e.cas, &e.value);
                        // No LRU lock: recency is one atomic store.
                        self.touch_clock(idx);
                        Some(r)
                    }
                }
                None => None,
            }
        }
    }
}

impl Cache for MemClockCache {
    fn engine_name(&self) -> &'static str {
        "memclock"
    }

    /// Sequential per-op execution (batching buys a blocking engine
    /// nothing), except that GET hits lend the sink the entry's bytes
    /// under the stripe lock ([`MemClockCache::get_with`]) instead of
    /// cloning the value — the one copy is sink-side, straight to its
    /// destination.
    fn execute_batch_into(&self, ops: &[crate::cache::Op<'_>], sink: &mut dyn crate::cache::BatchSink) {
        for (i, op) in ops.iter().enumerate() {
            match *op {
                crate::cache::Op::Get { key } => {
                    self.metrics.gets.inc();
                    let hit = self
                        .get_with(key, |flags, cas, data| sink.value(i, key, flags, cas, data))
                        .is_some();
                    if hit {
                        self.metrics.hits.inc();
                    } else {
                        self.metrics.misses.inc();
                        sink.miss(i);
                    }
                }
                _ => crate::cache::op::forward_one(self, i, op, sink),
            }
        }
    }

    fn get(&self, key: &[u8]) -> Option<GetResult> {
        self.metrics.gets.inc();
        let result = self.get_with(key, |flags, cas, data| GetResult {
            data: data.to_vec(),
            flags,
            cas,
        });
        if result.is_some() {
            self.metrics.hits.inc();
        } else {
            self.metrics.misses.inc();
        }
        result
    }

    fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store_inner(key, value, flags, exptime, Mode::Set)
    }

    fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store_inner(key, value, flags, exptime, Mode::Add)
    }

    fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store_inner(key, value, flags, exptime, Mode::Replace)
    }

    fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> StoreOutcome {
        self.store_inner(key, value, flags, exptime, Mode::Cas(cas))
    }

    fn append(&self, key: &[u8], suffix: &[u8]) -> StoreOutcome {
        match self.rmw_inner(key, |e| {
            e.value.extend_from_slice(suffix);
            true
        }) {
            Some(()) => StoreOutcome::Stored,
            None => StoreOutcome::NotStored,
        }
    }

    fn prepend(&self, key: &[u8], prefix: &[u8]) -> StoreOutcome {
        match self.rmw_inner(key, |e| {
            let mut v = Vec::with_capacity(prefix.len() + e.value.len());
            v.extend_from_slice(prefix);
            v.extend_from_slice(&e.value);
            e.value = v;
            true
        }) {
            Some(()) => StoreOutcome::Stored,
            None => StoreOutcome::NotStored,
        }
    }

    fn delete(&self, key: &[u8]) -> bool {
        self.metrics.deletes.inc();
        let hash = hash_key(key);
        let _s = self.stripe_of(hash).lock().unwrap();
        // SAFETY: `hash`'s stripe lock is held for the whole block.
        unsafe {
            match self.find(hash, key) {
                Some((idx, pos)) => {
                    let _ = self.remove_at(idx, pos);
                    true
                }
                None => false,
            }
        }
    }

    fn incr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut out = None;
        self.rmw_inner(key, |e| {
            if let Ok(n) = std::str::from_utf8(&e.value).unwrap_or("").trim().parse::<u64>() {
                let v = n.wrapping_add(delta);
                e.value = v.to_string().into_bytes();
                out = Some(v);
                true
            } else {
                false
            }
        })?;
        out
    }

    fn decr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut out = None;
        self.rmw_inner(key, |e| {
            if let Ok(n) = std::str::from_utf8(&e.value).unwrap_or("").trim().parse::<u64>() {
                let v = n.saturating_sub(delta);
                e.value = v.to_string().into_bytes();
                out = Some(v);
                true
            } else {
                false
            }
        })?;
        out
    }

    fn touch(&self, key: &[u8], exptime: u32) -> bool {
        let deadline = deadline_from_exptime(exptime);
        self.rmw_inner(key, |e| {
            e.deadline = deadline;
            true
        })
        .is_some()
    }

    fn flush_all(&self) {
        let _guards: Vec<MutexGuard<()>> =
            self.stripes.iter().map(|s| s.lock().unwrap()).collect();
        // SAFETY: every stripe is locked — exclusive structural access.
        let st = unsafe { self.state() };
        for bucket in st.buckets.iter_mut() {
            bucket.clear();
        }
        for c in st.clocks.iter() {
            c.store(0, Ordering::Relaxed);
        }
        self.items.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
    }

    fn item_count(&self) -> usize {
        self.items.load(Ordering::Relaxed)
    }

    fn bucket_count(&self) -> usize {
        let _s = self.stripes[0].lock().unwrap();
        // SAFETY: `mask` changes only under all stripes; stripe 0 held.
        unsafe { self.state().mask + 1 }
    }

    fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            metrics: self.metrics.snapshot(),
            items: self.item_count(),
            buckets: self.bucket_count(),
            mem_used: self.mem_used(),
            mem_limit: self.mem_limit(),
            // Blocking engines have no EBR/slab substrate and use the
            // sequential batch path: observability extras stay zero.
            ..StatsSnapshot::default()
        }
    }

    fn mem_used(&self) -> usize {
        self.bytes.load(Ordering::Relaxed)
    }

    fn mem_limit(&self) -> usize {
        self.config.mem_limit
    }

    fn clock_snapshot(&self) -> Option<Vec<u8>> {
        let _s = self.stripes[0].lock().unwrap();
        // SAFETY: the clocks vector is only replaced under all stripes;
        // stripe 0 held pins it, and the values are atomics.
        let st = unsafe { self.state() };
        Some(st.clocks.iter().map(|c| c.load(Ordering::Relaxed)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn small() -> MemClockCache {
        MemClockCache::new(CacheConfig::small())
    }

    #[test]
    fn roundtrip_and_semantics() {
        let c = small();
        assert_eq!(c.set(b"k", b"v", 3, 0), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"v");
        assert_eq!(c.add(b"k", b"x", 0, 0), StoreOutcome::NotStored);
        assert!(c.delete(b"k"));
        assert_eq!(c.replace(b"k", b"z", 0, 0), StoreOutcome::NotFound);
        assert_eq!(c.incr(b"k", 1), None);
    }

    #[test]
    fn clock_eviction_prefers_cold_buckets() {
        let c = MemClockCache::new(CacheConfig {
            mem_limit: 20 * (ENTRY_OVERHEAD + 6 + 512),
            initial_buckets: 256, // plenty of buckets → per-key CLOCK-ish
            ..CacheConfig::small()
        });
        let v = vec![0u8; 512];
        for i in 0..20u32 {
            c.set(format!("key{i:02}").as_bytes(), &v, 0, 0);
        }
        // Heat key00 repeatedly.
        for _ in 0..5 {
            assert!(c.get(b"key00").is_some());
        }
        // Overflow: several cold keys must go before the hot one.
        for i in 20..30u32 {
            c.set(format!("key{i:02}").as_bytes(), &v, 0, 0);
        }
        assert!(
            c.get(b"key00").is_some(),
            "hot key evicted despite max CLOCK"
        );
        assert!(c.metrics().snapshot().evictions > 0);
    }

    #[test]
    fn expansion_preserves_items_and_reseeds_clocks() {
        let c = MemClockCache::new(CacheConfig {
            initial_buckets: 8,
            ..CacheConfig::small()
        });
        for i in 0..100u32 {
            c.set(format!("e{i}").as_bytes(), &i.to_le_bytes(), 0, 0);
        }
        assert!(c.bucket_count() > 8);
        for i in 0..100u32 {
            assert!(c.get(format!("e{i}").as_bytes()).is_some());
        }
        let clocks = c.clock_snapshot().unwrap();
        assert_eq!(clocks.len(), c.bucket_count());
    }

    #[test]
    fn concurrent_storm_consistency() {
        use crate::workload::{check_value, encode_key, fill_value, KEY_LEN};
        let c = Arc::new(MemClockCache::new(CacheConfig {
            mem_limit: 4 << 20,
            initial_buckets: 32,
            ..CacheConfig::small()
        }));
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    let mut rng = crate::sync::Xoshiro256::seeded(t);
                    let mut key = [0u8; KEY_LEN];
                    let mut val = vec![0u8; 128];
                    for _ in 0..5_000 {
                        let id = rng.next_below(300);
                        let k = encode_key(&mut key, id);
                        if rng.chance(0.7) {
                            if let Some(r) = c.get(k) {
                                assert!(check_value(id, &r.data));
                            }
                        } else {
                            let len = 16 + (id as usize % 100);
                            fill_value(id, &mut val[..len]);
                            c.set(k, &val[..len], 0, 0);
                        }
                    }
                });
            }
        });
    }
}
