//! `Sharded<C>` — the engine router: N independent engine instances
//! behind one [`Cache`] face, routed by key hash.
//!
//! The ROADMAP's scaling lever past batching is one engine instance per
//! core-complex: each shard owns a private hash table, slab and (for
//! FLeeC) EBR collector, so cross-core contention drops by roughly the
//! shard count and the PR-1 batch path *compounds* — a socket read's
//! batch splits into per-shard **sub-batches** (batch → shard →
//! sub-batch), each of which still pays one EBR pin / one engine
//! crossing on engines that batch.
//!
//! Routing uses the **high 32 bits** of the shared [`hash_key`] value.
//! Every engine derives its bucket index (and the blocking engines their
//! lock stripe) from the *low* bits, so routing on the high bits keeps
//! each shard's table fully populated instead of pinning it to a
//! 1-in-N bucket subset.
//!
//! Semantics: ops on different keys commute (every result and state
//! transition in the [`Cache`] contract is per-key), and all ops for one
//! key land on one shard in their original relative order, so a routed
//! batch is indistinguishable from a sequential run — with one caveat:
//! `cas` tokens are allocated per shard, so token *values* differ from an
//! unsharded run (they remain unique per key, which is all the protocol
//! promises). `rust/tests/shard_semantics.rs` holds the router to this.
//!
//! Aggregate views merge: [`Cache::stats`] sums counters and memory
//! across shards (the configured `mem_limit` is divided across shards at
//! construction, so the merged `limit_maxbytes` equals the configured
//! total), `flush_all`/`maintenance` fan out, and `clock_snapshot`
//! concatenates the shards' CLOCK arrays in shard order.

use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use crate::cache::{
    hash_key, BatchSink, Cache, CacheConfig, GetResult, Op, StatsSnapshot, StoreOutcome,
};

/// The index-remapping sink adapter: wraps the caller's sink for one
/// shard's sub-batch, translating the shard's sub-batch indices back to
/// original batch positions (`map[sub_idx]`). Borrowed value bytes pass
/// straight through — the shard's guard/lock is still held across the
/// forwarded call, so the lending contract survives the hop.
struct RemapSink<'a, 'b> {
    inner: &'a mut dyn BatchSink,
    map: &'b [u32],
}

impl BatchSink for RemapSink<'_, '_> {
    fn value(&mut self, idx: usize, key: &[u8], flags: u32, cas: u64, data: &[u8]) {
        self.inner.value(self.map[idx] as usize, key, flags, cas, data);
    }

    fn miss(&mut self, idx: usize) {
        self.inner.miss(self.map[idx] as usize);
    }

    fn store(&mut self, idx: usize, outcome: StoreOutcome) {
        self.inner.store(self.map[idx] as usize, outcome);
    }

    fn deleted(&mut self, idx: usize, existed: bool) {
        self.inner.deleted(self.map[idx] as usize, existed);
    }

    fn counter(&mut self, idx: usize, value: Option<u64>) {
        self.inner.counter(self.map[idx] as usize, value);
    }

    fn touched(&mut self, idx: usize, existed: bool) {
        self.inner.touched(self.map[idx] as usize, existed);
    }
}

/// An N-shard router over any [`Cache`] engine.
pub struct Sharded<C: Cache> {
    shards: Box<[C]>,
    /// `shards.len() - 1`; the length is always a power of two.
    mask: usize,
    /// Interned `"<engine>/<n>"` display name.
    name: &'static str,
}

impl<C: Cache> Sharded<C> {
    /// Build `shards` engines (rounded up to a power of two) with
    /// `build(shard_index, per_shard_config)`. The configured `mem_limit`
    /// is divided across shards (remainder to shard 0) so the merged
    /// accounting still sums to the configured total; `initial_buckets`
    /// and `lock_stripes` are divided too, keeping total table size and
    /// total lock count — and therefore expansion behavior and the
    /// blocking engines' contention baseline — comparable to an
    /// unsharded engine (otherwise a shards-vs-flat bench would conflate
    /// the router's win with a plain stripe-count increase).
    pub fn from_fn(
        shards: usize,
        config: CacheConfig,
        mut build: impl FnMut(usize, CacheConfig) -> C,
    ) -> Self {
        let n = shards.max(1).next_power_of_two();
        let built: Vec<C> = (0..n)
            .map(|i| {
                let mut shard_config = config.clone();
                shard_config.mem_limit = config.mem_limit / n
                    + if i == 0 { config.mem_limit % n } else { 0 };
                shard_config.initial_buckets = (config.initial_buckets / n).max(8);
                shard_config.lock_stripes = (config.lock_stripes / n).max(1);
                build(i, shard_config)
            })
            .collect();
        let name = interned_name(built[0].engine_name(), n);
        Sharded {
            shards: built.into_boxed_slice(),
            mask: n - 1,
            name,
        }
    }

    /// Number of shards (a power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard index `key` routes to. High hash bits on purpose: the
    /// engines consume the low bits for bucket/stripe selection.
    #[inline]
    pub fn shard_of(&self, key: &[u8]) -> usize {
        ((hash_key(key) >> 32) as usize) & self.mask
    }

    /// Direct access to one shard (tests, diagnostics).
    pub fn shard(&self, idx: usize) -> &C {
        &self.shards[idx]
    }

    #[inline]
    fn route(&self, key: &[u8]) -> &C {
        &self.shards[self.shard_of(key)]
    }
}

impl<C: Cache> Cache for Sharded<C> {
    fn engine_name(&self) -> &'static str {
        self.name
    }

    /// Split the batch into per-shard sub-batches (preserving each key's
    /// op order) and execute one sub-batch per shard, each through that
    /// engine's own `execute_batch_into` — FLeeC shards still pin one
    /// EBR guard per sub-batch. Results flow to the caller's sink
    /// through an **index-remapping adapter** ([`RemapSink`]) that
    /// translates sub-batch positions back to original batch indices,
    /// so re-interleaving materializes nothing: the router adds no
    /// per-shard result vectors and no value copies, and a zero-copy
    /// engine hit stays zero-copy through the router. Consequently the
    /// sink sees deliveries **shard-grouped, not in batch order** — the
    /// delivery-order freedom [`crate::cache::BatchSink`] documents
    /// exists exactly for this path.
    fn execute_batch_into(&self, ops: &[Op<'_>], sink: &mut dyn crate::cache::BatchSink) {
        if ops.is_empty() {
            return;
        }
        if self.shards.len() == 1 {
            return self.shards[0].execute_batch_into(ops, sink);
        }
        // Counting-sort partition into one flat buffer: allocation count
        // is independent of the shard count (this sits on the
        // per-socket-read hot path). A stable grouping — ops iterate in
        // batch order and each shard's cursor only moves forward — so
        // sub-batch op order == original relative order and per-key
        // sequential semantics survive the split.
        let n = self.shards.len();
        let shard_ids: Vec<u32> = ops
            .iter()
            .map(|op| self.shard_of(op.key()) as u32)
            .collect();
        let mut starts = vec![0u32; n + 1];
        for &s in &shard_ids {
            starts[s as usize + 1] += 1;
        }
        for i in 0..n {
            starts[i + 1] += starts[i];
        }
        let mut cursor: Vec<u32> = starts[..n].to_vec();
        let mut flat_ops: Vec<Op<'_>> = vec![ops[0]; ops.len()];
        let mut flat_idx: Vec<u32> = vec![0; ops.len()];
        for (i, op) in ops.iter().enumerate() {
            let s = shard_ids[i] as usize;
            let pos = cursor[s] as usize;
            cursor[s] += 1;
            flat_ops[pos] = *op;
            flat_idx[pos] = i as u32;
        }
        // Execute per-shard slices; the remapping adapter forwards each
        // delivery to the caller's sink under its original index.
        for (s, shard) in self.shards.iter().enumerate() {
            let (lo, hi) = (starts[s] as usize, starts[s + 1] as usize);
            if lo == hi {
                continue;
            }
            // `&mut *sink`: reborrow (a struct literal would move the
            // `&mut dyn` out of `sink` on the first shard).
            let mut remap = RemapSink {
                inner: &mut *sink,
                map: &flat_idx[lo..hi],
            };
            shard.execute_batch_into(&flat_ops[lo..hi], &mut remap);
        }
    }

    fn get(&self, key: &[u8]) -> Option<GetResult> {
        self.route(key).get(key)
    }

    fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.route(key).set(key, value, flags, exptime)
    }

    fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.route(key).add(key, value, flags, exptime)
    }

    fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.route(key).replace(key, value, flags, exptime)
    }

    fn append(&self, key: &[u8], suffix: &[u8]) -> StoreOutcome {
        self.route(key).append(key, suffix)
    }

    fn prepend(&self, key: &[u8], prefix: &[u8]) -> StoreOutcome {
        self.route(key).prepend(key, prefix)
    }

    fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> StoreOutcome {
        self.route(key).cas(key, value, flags, exptime, cas)
    }

    fn delete(&self, key: &[u8]) -> bool {
        self.route(key).delete(key)
    }

    fn incr(&self, key: &[u8], delta: u64) -> Option<u64> {
        self.route(key).incr(key, delta)
    }

    fn decr(&self, key: &[u8], delta: u64) -> Option<u64> {
        self.route(key).decr(key, delta)
    }

    fn touch(&self, key: &[u8], exptime: u32) -> bool {
        self.route(key).touch(key, exptime)
    }

    fn flush_all(&self) {
        for s in self.shards.iter() {
            s.flush_all();
        }
    }

    fn item_count(&self) -> usize {
        self.shards.iter().map(|s| s.item_count()).sum()
    }

    fn bucket_count(&self) -> usize {
        self.shards.iter().map(|s| s.bucket_count()).sum()
    }

    fn mem_used(&self) -> usize {
        self.shards.iter().map(|s| s.mem_used()).sum()
    }

    fn mem_limit(&self) -> usize {
        self.shards.iter().map(|s| s.mem_limit()).sum()
    }

    /// The merge path: one [`StatsSnapshot`] per shard, summed. This is
    /// what makes `stats` over a sharded server truthful — counters,
    /// items, memory and `limit_maxbytes` all add back up to the whole.
    fn stats(&self) -> StatsSnapshot {
        let mut acc = StatsSnapshot::default();
        for s in self.shards.iter() {
            acc.absorb(&s.stats());
        }
        acc
    }

    fn maintenance(&self) {
        for s in self.shards.iter() {
            s.maintenance();
        }
    }

    fn tenant_slabs(&self) -> Vec<Arc<crate::slab::Slab>> {
        self.shards.iter().flat_map(|s| s.tenant_slabs()).collect()
    }

    fn clock_snapshot(&self) -> Option<Vec<u8>> {
        let mut out = Vec::new();
        for s in self.shards.iter() {
            out.extend(s.clock_snapshot()?);
        }
        Some(out)
    }

    fn set_evict_params(&self, decay: u8, batch: u32) {
        for s in self.shards.iter() {
            s.set_evict_params(decay, batch);
        }
    }
}

/// Intern `"<engine>/<n>"` so `engine_name` can stay `&'static str`
/// without leaking per instance (tests build thousands of routers).
fn interned_name(inner: &str, n: usize) -> &'static str {
    static NAMES: Lazy<Mutex<Vec<&'static str>>> = Lazy::new(|| Mutex::new(Vec::new()));
    let want = format!("{inner}/{n}");
    let mut names = NAMES.lock().unwrap();
    if let Some(&existing) = names.iter().find(|&&s| s == want) {
        return existing;
    }
    let leaked: &'static str = Box::leak(want.into_boxed_str());
    names.push(leaked);
    leaked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::fleec::FleecCache;
    use crate::cache::OpResult;

    fn router(n: usize) -> Sharded<FleecCache> {
        Sharded::from_fn(n, CacheConfig::small(), |_, cfg| FleecCache::new(cfg))
    }

    #[test]
    fn routing_is_deterministic_and_uses_every_shard() {
        let r = router(8);
        assert_eq!(r.shard_count(), 8);
        let mut seen = [false; 8];
        for i in 0..1024u32 {
            let key = format!("route-{i}");
            let a = r.shard_of(key.as_bytes());
            let b = r.shard_of(key.as_bytes());
            assert_eq!(a, b, "routing must be stable");
            seen[a] = true;
        }
        assert!(seen.iter().all(|&s| s), "1024 keys must touch all 8 shards");
    }

    #[test]
    fn shard_count_rounds_up_to_power_of_two() {
        assert_eq!(router(1).shard_count(), 1);
        assert_eq!(router(3).shard_count(), 4);
        assert_eq!(router(8).shard_count(), 8);
        assert_eq!(Sharded::from_fn(0, CacheConfig::small(), |_, cfg| {
            FleecCache::new(cfg)
        })
        .shard_count(), 1);
    }

    #[test]
    fn mem_limit_survives_the_split() {
        let config = CacheConfig {
            mem_limit: (4 << 20) + 3, // indivisible on purpose
            ..CacheConfig::small()
        };
        let r = Sharded::from_fn(4, config.clone(), |_, cfg| FleecCache::new(cfg));
        assert_eq!(r.mem_limit(), config.mem_limit);
        assert_eq!(r.stats().mem_limit, config.mem_limit);
    }

    #[test]
    fn single_key_ops_route_and_aggregate() {
        let r = router(4);
        for i in 0..64u32 {
            let key = format!("agg-{i}");
            assert_eq!(r.set(key.as_bytes(), b"v", 0, 0), StoreOutcome::Stored);
        }
        assert_eq!(r.item_count(), 64);
        for i in 0..64u32 {
            let key = format!("agg-{i}");
            assert_eq!(r.get(key.as_bytes()).unwrap().data, b"v");
        }
        let stats = r.stats();
        assert_eq!(stats.items, 64);
        assert_eq!(stats.metrics.sets, 64);
        assert_eq!(stats.metrics.gets, 64);
        assert_eq!(stats.metrics.hits, 64);
        r.flush_all();
        assert_eq!(r.item_count(), 0);
    }

    #[test]
    fn engine_name_reflects_shape_and_is_interned() {
        let a = router(4);
        let b = router(4);
        assert_eq!(a.engine_name(), "fleec/4");
        assert!(std::ptr::eq(a.engine_name(), b.engine_name()));
        assert_eq!(router(1).engine_name(), "fleec/1");
    }

    #[test]
    fn batch_splits_and_reinterleaves_in_order() {
        let r = router(4);
        // Interleave writes and reads on keys that land on different
        // shards; results must come back in original batch order.
        let keys: Vec<String> = (0..16).map(|i| format!("b-{i}")).collect();
        let mut ops = Vec::new();
        for key in &keys {
            ops.push(Op::Set {
                key: key.as_bytes(),
                value: key.as_bytes(),
                flags: 0,
                exptime: 0,
            });
        }
        for key in &keys {
            ops.push(Op::Get { key: key.as_bytes() });
        }
        let rs = r.execute_batch(&ops);
        assert_eq!(rs.len(), ops.len());
        for (i, key) in keys.iter().enumerate() {
            assert_eq!(rs[i], OpResult::Store(StoreOutcome::Stored));
            match &rs[keys.len() + i] {
                OpResult::Value(Some(v)) => assert_eq!(v.data, key.as_bytes()),
                other => panic!("slot {i}: {other:?}"),
            }
        }
    }

    #[test]
    fn sink_batch_delivers_original_indices_shard_grouped() {
        struct Recorder {
            deliveries: Vec<(usize, Vec<u8>)>,
            outcomes: Vec<(usize, StoreOutcome)>,
        }
        impl BatchSink for Recorder {
            fn value(&mut self, idx: usize, _key: &[u8], _flags: u32, _cas: u64, data: &[u8]) {
                self.deliveries.push((idx, data.to_vec()));
            }
            fn miss(&mut self, idx: usize) {
                self.deliveries.push((idx, Vec::new()));
            }
            fn store(&mut self, idx: usize, outcome: StoreOutcome) {
                self.outcomes.push((idx, outcome));
            }
            fn deleted(&mut self, _idx: usize, _existed: bool) {}
            fn counter(&mut self, _idx: usize, _value: Option<u64>) {}
            fn touched(&mut self, _idx: usize, _existed: bool) {}
        }

        let r = router(4);
        let keys: Vec<String> = (0..32).map(|i| format!("remap-{i}")).collect();
        let mut ops = Vec::new();
        for key in &keys {
            ops.push(Op::Set {
                key: key.as_bytes(),
                value: key.as_bytes(),
                flags: 0,
                exptime: 0,
            });
        }
        for key in &keys {
            ops.push(Op::Get { key: key.as_bytes() });
        }
        let mut sink = Recorder {
            deliveries: Vec::new(),
            outcomes: Vec::new(),
        };
        r.execute_batch_into(&ops, &mut sink);
        // Exactly one delivery per op, each under its ORIGINAL index with
        // the right payload, regardless of shard-grouped arrival order.
        assert_eq!(sink.outcomes.len(), keys.len());
        assert_eq!(sink.deliveries.len(), keys.len());
        let mut seen = vec![false; ops.len()];
        for &(idx, outcome) in &sink.outcomes {
            assert!(idx < keys.len() && !seen[idx], "bad store idx {idx}");
            seen[idx] = true;
            assert_eq!(outcome, StoreOutcome::Stored);
        }
        for (idx, data) in &sink.deliveries {
            assert!(*idx >= keys.len() && !seen[*idx], "bad get idx {idx}");
            seen[*idx] = true;
            assert_eq!(data, keys[idx - keys.len()].as_bytes(), "idx {idx}");
        }
        assert!(seen.iter().all(|&s| s), "every op delivered exactly once");
        // With >1 shard and 32 spread-out keys, delivery cannot be in
        // batch order (shard 0's sub-batch drains before shard 1's).
        let order: Vec<usize> = sink.deliveries.iter().map(|(i, _)| *i).collect();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_ne!(order, sorted, "expected shard-grouped (non-batch) order");
    }
}
