//! Multi-tenant cache plane: namespaces, per-tenant accounting, and a
//! Memshare-style slab arbiter.
//!
//! Production caches serve many applications from one fleet; FLeeC's
//! any-concurrency pitch only holds at fleet scale if tenants can share
//! one process without static memory partitions. This module is the
//! control plane for that (see `rust/docs/multitenancy.md` for the full
//! design):
//!
//! * **Namespaces** — each connection carries a tenant id set by the
//!   `tenant <name>` protocol command ([`TenantConn`]); the server's
//!   drain loop prefixes execution keys with `<name>\x1f` so tenants
//!   live in disjoint key spaces behind the *unchanged* `Cache` /
//!   `BatchSink` contract. The default tenant's prefix is **empty**,
//!   which is what makes a single-default-tenant server byte-exact
//!   indistinguishable from a tenant-less one (`tests/tenant_e2e.rs`
//!   proves it wire-differentially for every engine).
//! * **Accounting** — per-tenant gets/hits/sets counters and a sampled
//!   shadow-eviction signal live here ([`TenantSink`]); per-tenant
//!   live-byte/chunk attribution and soft page budgets live on the slab
//!   ([`crate::slab::tenant`]), stamped through the item header.
//! * **Arbitration** — [`TenantPlane::arbitrate`], driven by the
//!   coordinator through [`TenantCache::maintenance`], moves page
//!   budget from the tenant with the least eviction pain to the one
//!   with the most (Memshare's hit-rate-benefit rule, PAPERS.md),
//!   instead of locking anyone out: enforcement happens on the
//!   engines' pressure path (an over-budget tenant evicts from itself
//!   first; at its floor it alone sees `SERVER_ERROR out of memory`).
//!
//! Lock-freedom: the data plane (key prefixing, counter bumps, ghost
//! ring, budget reads) is straight-line code over relaxed atomics — the
//! magazine layer already privatized alloc/free, so tenant attribution
//! rides existing paths. The only mutex guards the *registry* (the
//! name→id table, touched by the rare `tenant` command) and the
//! arbiter's private scratch, which a `try_lock` skips rather than
//! waits on.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cache::{hash_key, BatchSink, Cache, GetResult, Op, OpResult, StatsSnapshot, StoreOutcome};
use crate::slab::{Slab, MAX_TENANTS};

/// Byte that joins a tenant name to the client key. Excluded from the
/// tenant-name alphabet, so namespaced key spaces are prefix-free and
/// can never collide across tenants.
pub const NS_SEP: u8 = 0x1f;

/// Ghost-ring size per tenant (power of two). Fingerprints of recently
/// stored keys; a miss that matches one is counted as an
/// eviction-caused miss — the arbiter's benefit signal.
const GHOST_SLOTS: usize = 2048;

/// Minimum benefit gap (shadow hits per tick) before the arbiter moves
/// a page — hysteresis against swapping budget on noise.
const MIN_BENEFIT_GAP: u64 = 4;

/// A lossy, lock-free ring of key fingerprints: one relaxed store to
/// record, one relaxed load to probe. Collisions and overwrites only
/// blur a sampling heuristic.
struct GhostRing {
    slots: Box<[AtomicU64]>,
}

impl GhostRing {
    fn new() -> Self {
        GhostRing {
            slots: (0..GHOST_SLOTS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    #[inline]
    fn fingerprint(hash: u64) -> u64 {
        hash | 1 // never 0, so an empty slot never matches
    }

    #[inline]
    fn note(&self, hash: u64) {
        // ord: relaxed-ok — lossy sampling ring; no payload published.
        self.slots[hash as usize & (GHOST_SLOTS - 1)]
            .store(Self::fingerprint(hash), Ordering::Relaxed);
    }

    #[inline]
    fn probe(&self, hash: u64) -> bool {
        // ord: relaxed-ok — see note().
        self.slots[hash as usize & (GHOST_SLOTS - 1)].load(Ordering::Relaxed)
            == Self::fingerprint(hash)
    }

    #[inline]
    fn clear(&self, hash: u64) {
        let slot = &self.slots[hash as usize & (GHOST_SLOTS - 1)];
        // ord: relaxed-ok — lossy ring; racing with a concurrent note
        // just re-records the key.
        if slot.load(Ordering::Relaxed) == Self::fingerprint(hash) {
            slot.store(0, Ordering::Relaxed);
        }
    }
}

/// Per-tenant wire-level counters (all relaxed; stats-grade).
#[derive(Default)]
struct TenantCounters {
    gets: AtomicU64,
    hits: AtomicU64,
    sets: AtomicU64,
    shadow_hits: AtomicU64,
}

/// One tenant's externally visible snapshot (`stats tenants`,
/// `/metrics`, the bench report).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSnapshot {
    pub name: String,
    pub gets: u64,
    pub hits: u64,
    pub sets: u64,
    /// Misses whose key the tenant recently stored — the sampled
    /// "would have hit with more memory" signal the arbiter maximizes.
    pub shadow_hits: u64,
    /// Live slab bytes attributed to the tenant (0 for slab-less
    /// engines).
    pub live_bytes: usize,
    /// Soft budget (0 = unlimited).
    pub budget_bytes: usize,
}

/// Plane configuration.
#[derive(Debug, Clone)]
pub struct PlaneConfig {
    /// Move budget between tenants by benefit on every maintenance
    /// tick. Off = static equal partition (the bench baseline).
    pub arbiter: bool,
}

impl Default for PlaneConfig {
    fn default() -> Self {
        PlaneConfig { arbiter: true }
    }
}

/// Arbiter scratch: last-seen counter values for windowed deltas.
#[derive(Default)]
struct ArbiterState {
    last_shadow: [u64; MAX_TENANTS],
}

/// The per-process tenant control plane. One per server; shared by every
/// connection, the stats renderers, and the coordinator-driven arbiter.
pub struct TenantPlane {
    /// The slabs backing the cache (one per slab-backed shard), with
    /// tenancy enabled on each. Fixed at construction.
    slabs: Vec<Arc<Slab>>,
    /// Aggregate value-memory budget (for equal splits).
    mem_limit: usize,
    /// Registry: index = tenant id; `names[0]` is the default tenant.
    /// Mutex is control-plane only (`tenant` commands, stats snapshots).
    names: Mutex<Vec<String>>,
    counters: [TenantCounters; MAX_TENANTS],
    ghosts: Box<[GhostRing]>,
    config: PlaneConfig,
    arbiter: Mutex<ArbiterState>,
    /// Budget moved by the arbiter, lifetime bytes (observability).
    moved_bytes: AtomicU64,
}

impl std::fmt::Debug for TenantPlane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TenantPlane")
            .field("slabs", &self.slabs.len())
            .field("mem_limit", &self.mem_limit)
            .field("arbiter", &self.config.arbiter)
            .finish_non_exhaustive()
    }
}

impl TenantPlane {
    /// Build a plane over `cache`'s slabs, enabling per-tenant slab
    /// accounting. The default tenant (id 0) exists from the start with
    /// an unlimited budget.
    pub fn new(cache: &dyn Cache, config: PlaneConfig) -> Arc<Self> {
        let slabs = cache.tenant_slabs();
        for slab in &slabs {
            slab.enable_tenancy();
        }
        Arc::new(TenantPlane {
            mem_limit: cache.mem_limit(),
            slabs,
            names: Mutex::new(vec!["default".to_string()]),
            counters: std::array::from_fn(|_| TenantCounters::default()),
            ghosts: (0..MAX_TENANTS).map(|_| GhostRing::new()).collect(),
            config,
            arbiter: Mutex::new(ArbiterState::default()),
            moved_bytes: AtomicU64::new(0),
        })
    }

    /// Whether the benefit arbiter runs on maintenance ticks.
    pub fn arbiter_enabled(&self) -> bool {
        self.config.arbiter
    }

    /// Register (or look up) a tenant by name and return its id.
    /// Registration re-splits the aggregate budget equally across the
    /// *named* tenants — the static partition the arbiter then improves
    /// on. The default tenant keeps an unlimited budget (a tenant-less
    /// client mix must behave exactly like a tenant-less server).
    pub fn register(&self, name: &[u8]) -> Result<u8, &'static str> {
        if name.is_empty() || name.len() > 64 {
            return Err("tenant name must be 1..=64 bytes");
        }
        if !name
            .iter()
            .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'_' | b'-' | b'.'))
        {
            return Err("tenant name must be [A-Za-z0-9_.-]");
        }
        let mut names = self.names.lock().unwrap();
        if let Some(id) = names.iter().position(|n| n.as_bytes() == name) {
            return Ok(id as u8);
        }
        if names.len() >= MAX_TENANTS {
            return Err("tenant table full");
        }
        names.push(String::from_utf8_lossy(name).into_owned());
        let id = (names.len() - 1) as u8;
        let named = names.len() - 1; // excluding default
        for slab in &self.slabs {
            let share = slab.mem_limit() / named.max(1);
            for t in 1..names.len() {
                slab.set_tenant_budget(t as u8, share);
            }
        }
        Ok(id)
    }

    /// The execution-key prefix for a tenant: empty for the default
    /// tenant, `<name>\x1f` otherwise.
    pub fn prefix_of(&self, id: u8) -> Vec<u8> {
        if id == 0 {
            return Vec::new();
        }
        let names = self.names.lock().unwrap();
        match names.get(id as usize) {
            Some(n) => {
                let mut p = n.as_bytes().to_vec();
                p.push(NS_SEP);
                p
            }
            None => Vec::new(),
        }
    }

    /// Number of registered tenants (default included).
    pub fn tenant_count(&self) -> usize {
        self.names.lock().unwrap().len()
    }

    /// Lifetime bytes of budget the arbiter has moved.
    pub fn moved_bytes(&self) -> u64 {
        // ord: relaxed-ok — observability counter.
        self.moved_bytes.load(Ordering::Relaxed)
    }

    /// Override a tenant's soft budget on every slab (tests, operator
    /// tooling). `bytes` is the aggregate; each slab gets its
    /// proportional share.
    pub fn set_budget(&self, id: u8, bytes: usize) {
        for slab in &self.slabs {
            let share = if self.mem_limit == 0 {
                bytes
            } else {
                (bytes as u128 * slab.mem_limit() as u128 / self.mem_limit as u128) as usize
            };
            slab.set_tenant_budget(id, share);
        }
    }

    #[inline]
    pub(crate) fn note_get(&self, id: u8, hit: bool, key_hash: impl FnOnce() -> u64) {
        let c = &self.counters[id as usize % MAX_TENANTS];
        // ord: relaxed-ok — stats-grade counters (all bumps below).
        c.gets.fetch_add(1, Ordering::Relaxed);
        if hit {
            c.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            let h = key_hash();
            if self.ghosts[id as usize % MAX_TENANTS].probe(h) {
                c.shadow_hits.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    #[inline]
    pub(crate) fn note_set(&self, id: u8, key_hash: u64) {
        let t = id as usize % MAX_TENANTS;
        // ord: relaxed-ok — stats-grade counter.
        self.counters[t].sets.fetch_add(1, Ordering::Relaxed);
        self.ghosts[t].note(key_hash);
    }

    #[inline]
    pub(crate) fn note_delete(&self, id: u8, key_hash: u64) {
        // An explicit delete is not an eviction: stop counting future
        // misses on this key as memory pain.
        self.ghosts[id as usize % MAX_TENANTS].clear(key_hash);
    }

    /// Snapshot every registered tenant (id order).
    pub fn snapshot(&self) -> Vec<TenantSnapshot> {
        let names = self.names.lock().unwrap().clone();
        names
            .into_iter()
            .enumerate()
            .map(|(id, name)| {
                let c = &self.counters[id];
                let mut live = 0usize;
                let mut budget = 0usize;
                for slab in &self.slabs {
                    live += slab.tenant_live_bytes(id as u8);
                    budget += slab.tenant_budget(id as u8);
                }
                TenantSnapshot {
                    name,
                    // ord: relaxed-ok — stats snapshot (all four loads).
                    gets: c.gets.load(Ordering::Relaxed),
                    hits: c.hits.load(Ordering::Relaxed),
                    sets: c.sets.load(Ordering::Relaxed),
                    shadow_hits: c.shadow_hits.load(Ordering::Relaxed),
                    live_bytes: live,
                    budget_bytes: budget,
                }
            })
            .collect()
    }

    /// One arbiter tick: move a page of budget from the named tenant
    /// with the smallest shadow-hit delta to the pressured one with the
    /// largest, per slab — Memshare's reassign-by-benefit rule. Runs on
    /// the coordinator's maintenance cadence; never blocks (a contended
    /// tick is skipped, the next one sees the accumulated deltas).
    pub fn arbitrate(&self) {
        if !self.config.arbiter {
            return;
        }
        let Ok(mut st) = self.arbiter.try_lock() else {
            return;
        };
        let n = self.tenant_count();
        // Windowed benefit per named tenant (default never arbitrates:
        // its budget is unlimited by construction).
        let mut benefit = [0u64; MAX_TENANTS];
        for t in 1..n {
            // ord: relaxed-ok — stats read for a heuristic.
            let now = self.counters[t].shadow_hits.load(Ordering::Relaxed);
            benefit[t] = now.saturating_sub(st.last_shadow[t]);
            st.last_shadow[t] = now;
        }
        if n < 3 {
            return; // need two named tenants to trade
        }
        for slab in &self.slabs {
            let page = slab.page_size().min(slab.mem_limit());
            // Taker: most benefit, and actually short on memory (its
            // live bytes press against its budget).
            let mut taker: Option<usize> = None;
            for t in 1..n {
                let b = slab.tenant_budget(t as u8);
                let pressured = b != 0 && slab.tenant_live_bytes(t as u8) + page > b;
                if pressured && taker.map_or(true, |best| benefit[t] > benefit[best]) {
                    taker = Some(t);
                }
            }
            let Some(taker) = taker else { continue };
            // Donor: least benefit among the others with budget to give.
            let mut donor: Option<usize> = None;
            for t in 1..n {
                if t == taker || slab.tenant_budget(t as u8) <= page {
                    continue;
                }
                if donor.map_or(true, |best| benefit[t] < benefit[best]) {
                    donor = Some(t);
                }
            }
            let Some(donor) = donor else { continue };
            if benefit[taker] < benefit[donor].saturating_add(MIN_BENEFIT_GAP) {
                continue;
            }
            let moved = slab.move_tenant_budget(donor as u8, taker as u8, page);
            if moved > 0 {
                // ord: relaxed-ok — observability counter.
                self.moved_bytes.fetch_add(moved as u64, Ordering::Relaxed);
            }
        }
    }
}

/// Per-connection tenant state: the id and cached execution-key prefix
/// the drain loop applies to every op.
pub struct TenantConn {
    plane: Arc<TenantPlane>,
    id: u8,
    prefix: Vec<u8>,
}

impl TenantConn {
    /// A connection starts as the default tenant (empty prefix).
    pub fn new(plane: Arc<TenantPlane>) -> Self {
        TenantConn {
            plane,
            id: 0,
            prefix: Vec::new(),
        }
    }

    /// Handle `tenant <name>`: register/look up and switch.
    pub fn switch(&mut self, name: &[u8]) -> Result<(), &'static str> {
        let id = self.plane.register(name)?;
        self.prefix = self.plane.prefix_of(id);
        self.id = id;
        Ok(())
    }

    /// Current tenant id.
    #[inline]
    pub fn id(&self) -> u8 {
        self.id
    }

    /// Execution-key prefix (empty for the default tenant).
    #[inline]
    pub fn prefix(&self) -> &[u8] {
        &self.prefix
    }

    /// The shared plane.
    #[inline]
    pub fn plane(&self) -> &Arc<TenantPlane> {
        &self.plane
    }
}

/// Sink adapter recording per-tenant hit statistics and the shadow
/// signal while forwarding every delivery unchanged. `ops` are the
/// **original** (un-prefixed) ops — ghost fingerprints must be stable
/// across budget changes, and reply rendering never sees engine keys
/// anyway.
pub struct TenantSink<'a, 'o> {
    inner: &'a mut dyn BatchSink,
    plane: &'a TenantPlane,
    id: u8,
    ops: &'a [Op<'o>],
}

impl<'a, 'o> TenantSink<'a, 'o> {
    pub fn new(
        inner: &'a mut dyn BatchSink,
        plane: &'a TenantPlane,
        id: u8,
        ops: &'a [Op<'o>],
    ) -> Self {
        TenantSink {
            inner,
            plane,
            id,
            ops,
        }
    }
}

impl BatchSink for TenantSink<'_, '_> {
    fn value(&mut self, idx: usize, key: &[u8], flags: u32, cas: u64, data: &[u8]) {
        self.plane.note_get(self.id, true, || 0);
        self.inner.value(idx, key, flags, cas, data);
    }

    fn miss(&mut self, idx: usize) {
        self.plane
            .note_get(self.id, false, || hash_key(self.ops[idx].key()));
        self.inner.miss(idx);
    }

    fn store(&mut self, idx: usize, outcome: StoreOutcome) {
        if outcome == StoreOutcome::Stored {
            self.plane
                .note_set(self.id, hash_key(self.ops[idx].key()));
        }
        self.inner.store(idx, outcome);
    }

    fn deleted(&mut self, idx: usize, existed: bool) {
        if existed {
            self.plane
                .note_delete(self.id, hash_key(self.ops[idx].key()));
        }
        self.inner.deleted(idx, existed);
    }

    fn counter(&mut self, idx: usize, value: Option<u64>) {
        self.inner.counter(idx, value);
    }

    fn touched(&mut self, idx: usize, existed: bool) {
        self.inner.touched(idx, existed);
    }
}

/// Transparent [`Cache`] wrapper that runs the arbiter on the
/// maintenance tick. Everything else delegates — namespacing happens in
/// the server's drain loop (key prefixing), accounting in the slab and
/// the sink adapter, so the engine contract is untouched.
pub struct TenantCache {
    inner: Arc<dyn Cache>,
    plane: Arc<TenantPlane>,
}

impl TenantCache {
    pub fn new(inner: Arc<dyn Cache>, plane: Arc<TenantPlane>) -> Self {
        TenantCache { inner, plane }
    }

    /// The wrapped plane (server wiring).
    pub fn plane(&self) -> &Arc<TenantPlane> {
        &self.plane
    }
}

impl Cache for TenantCache {
    fn engine_name(&self) -> &'static str {
        self.inner.engine_name()
    }

    fn execute_batch_into(&self, ops: &[Op<'_>], sink: &mut dyn BatchSink) {
        self.inner.execute_batch_into(ops, sink)
    }

    fn execute_batch(&self, ops: &[Op<'_>]) -> Vec<OpResult> {
        self.inner.execute_batch(ops)
    }

    fn get(&self, key: &[u8]) -> Option<GetResult> {
        self.inner.get(key)
    }

    fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.inner.set(key, value, flags, exptime)
    }

    fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.inner.add(key, value, flags, exptime)
    }

    fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.inner.replace(key, value, flags, exptime)
    }

    fn append(&self, key: &[u8], suffix: &[u8]) -> StoreOutcome {
        self.inner.append(key, suffix)
    }

    fn prepend(&self, key: &[u8], prefix: &[u8]) -> StoreOutcome {
        self.inner.prepend(key, prefix)
    }

    fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> StoreOutcome {
        self.inner.cas(key, value, flags, exptime, cas)
    }

    fn delete(&self, key: &[u8]) -> bool {
        self.inner.delete(key)
    }

    fn incr(&self, key: &[u8], delta: u64) -> Option<u64> {
        self.inner.incr(key, delta)
    }

    fn decr(&self, key: &[u8], delta: u64) -> Option<u64> {
        self.inner.decr(key, delta)
    }

    fn touch(&self, key: &[u8], exptime: u32) -> bool {
        self.inner.touch(key, exptime)
    }

    fn flush_all(&self) {
        self.inner.flush_all()
    }

    fn item_count(&self) -> usize {
        self.inner.item_count()
    }

    fn bucket_count(&self) -> usize {
        self.inner.bucket_count()
    }

    fn mem_used(&self) -> usize {
        self.inner.mem_used()
    }

    fn mem_limit(&self) -> usize {
        self.inner.mem_limit()
    }

    fn stats(&self) -> StatsSnapshot {
        self.inner.stats()
    }

    fn maintenance(&self) {
        self.inner.maintenance();
        self.plane.arbitrate();
    }

    fn clock_snapshot(&self) -> Option<Vec<u8>> {
        self.inner.clock_snapshot()
    }

    fn set_evict_params(&self, decay: u8, batch: u32) {
        self.inner.set_evict_params(decay, batch)
    }

    fn tenant_slabs(&self) -> Vec<Arc<Slab>> {
        self.inner.tenant_slabs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{build_engine, CacheConfig};

    fn plane_over(engine: &str, arbiter: bool) -> (Arc<dyn Cache>, Arc<TenantPlane>) {
        let cache = build_engine(engine, CacheConfig::small()).unwrap();
        let plane = TenantPlane::new(cache.as_ref(), PlaneConfig { arbiter });
        (cache, plane)
    }

    #[test]
    fn register_validates_and_dedupes() {
        let (_c, plane) = plane_over("fleec", true);
        let a = plane.register(b"app-a").unwrap();
        let b = plane.register(b"app.b").unwrap();
        assert_eq!(plane.register(b"app-a").unwrap(), a);
        assert_ne!(a, b);
        assert_eq!(plane.tenant_count(), 3);
        assert!(plane.register(b"").is_err());
        assert!(plane.register(b"has space").is_err());
        assert!(plane.register(b"has\x1fsep").is_err());
        assert!(plane.register(&[b'x'; 65]).is_err());
        assert_eq!(plane.prefix_of(0), b"".to_vec());
        let mut want = b"app-a".to_vec();
        want.push(NS_SEP);
        assert_eq!(plane.prefix_of(a), want);
    }

    #[test]
    fn register_fills_and_rejects_at_capacity() {
        let (_c, plane) = plane_over("fleec", true);
        for i in 1..MAX_TENANTS {
            plane.register(format!("t{i}").as_bytes()).unwrap();
        }
        assert!(plane.register(b"overflow").is_err());
    }

    #[test]
    fn registration_splits_budget_equally_across_named_tenants() {
        let (cache, plane) = plane_over("fleec", true);
        let slab = cache.tenant_slabs().pop().unwrap();
        let a = plane.register(b"a").unwrap();
        assert_eq!(slab.tenant_budget(a), slab.mem_limit());
        let b = plane.register(b"b").unwrap();
        assert_eq!(slab.tenant_budget(a), slab.mem_limit() / 2);
        assert_eq!(slab.tenant_budget(b), slab.mem_limit() / 2);
        assert_eq!(slab.tenant_budget(0), 0, "default stays unlimited");
    }

    #[test]
    fn ghost_ring_counts_evicted_reads_as_shadow_hits() {
        let (_c, plane) = plane_over("fleec", true);
        let id = plane.register(b"a").unwrap();
        plane.note_set(id, hash_key(b"k1"));
        // Miss on a never-stored key: cold, no shadow hit.
        plane.note_get(id, false, || hash_key(b"cold"));
        // Miss on a recently stored key: counts.
        plane.note_get(id, false, || hash_key(b"k1"));
        // Deleting clears the ghost entry.
        plane.note_delete(id, hash_key(b"k1"));
        plane.note_get(id, false, || hash_key(b"k1"));
        let snap = &plane.snapshot()[id as usize];
        assert_eq!(snap.gets, 3);
        assert_eq!(snap.shadow_hits, 1);
    }

    #[test]
    fn arbiter_moves_budget_toward_shadow_pain() {
        let (cache, plane) = plane_over("fleec", true);
        let slab = cache.tenant_slabs().pop().unwrap();
        let a = plane.register(b"a").unwrap();
        let b = plane.register(b"b").unwrap();
        let before_a = slab.tenant_budget(a);
        let before_b = slab.tenant_budget(b);
        // Tenant a screams (shadow hits), tenant b is content. Make a
        // pressured: live_bytes ~ budget via a direct accounting note.
        slab.set_tenant_budget(a, 64 << 10);
        for i in 0..200u32 {
            let key = i.to_le_bytes();
            plane.note_set(a, hash_key(&key));
            plane.note_get(a, false, || hash_key(&key));
        }
        // Pressure: pretend tenant a holds its whole budget.
        let class = slab.class_for(1024).unwrap();
        let chunk = slab.chunk_size(class);
        for _ in 0..(64 << 10) / chunk {
            slab.note_tenant_alloc(a, class);
        }
        plane.arbitrate();
        assert!(
            slab.tenant_budget(a) > 64 << 10,
            "pressured high-benefit tenant must gain budget"
        );
        assert!(slab.tenant_budget(b) < before_b);
        assert!(plane.moved_bytes() > 0);
        let _ = before_a;
        // Second tick with no new shadow hits: deltas are zero, nothing
        // moves.
        let a_now = slab.tenant_budget(a);
        plane.arbitrate();
        assert_eq!(slab.tenant_budget(a), a_now, "hysteresis holds on noise");
    }

    #[test]
    fn arbiter_off_is_static_partition() {
        let (cache, plane) = plane_over("fleec", false);
        let slab = cache.tenant_slabs().pop().unwrap();
        let a = plane.register(b"a").unwrap();
        let _b = plane.register(b"b").unwrap();
        for i in 0..100u32 {
            plane.note_set(a, hash_key(&i.to_le_bytes()));
            plane.note_get(a, false, || hash_key(&i.to_le_bytes()));
        }
        let before = slab.tenant_budget(a);
        plane.arbitrate();
        assert_eq!(slab.tenant_budget(a), before);
        assert_eq!(plane.moved_bytes(), 0);
    }

    #[test]
    fn tenant_cache_delegates_and_arbitrates_on_maintenance() {
        let (cache, plane) = plane_over("fleec", true);
        let wrapped = TenantCache::new(Arc::clone(&cache), Arc::clone(&plane));
        assert_eq!(wrapped.engine_name(), cache.engine_name());
        wrapped.set(b"k", b"v", 0, 0);
        assert_eq!(wrapped.get(b"k").unwrap().data, b"v");
        assert_eq!(wrapped.item_count(), 1);
        wrapped.maintenance(); // must not panic with zero named tenants
        assert_eq!(wrapped.mem_limit(), cache.mem_limit());
        assert_eq!(wrapped.tenant_slabs().len(), 1);
    }
}
