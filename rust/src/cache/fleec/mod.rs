//! FLeeC — the paper's lock-free cache engine.
//!
//! One lock-free hash table with the CLOCK eviction policy *embedded*
//! (one multi-bit CLOCK value per bucket), Harris-list buckets,
//! DEBRA-variant epoch reclamation and non-blocking expansion. There is
//! no LRU list and no stop-the-world resize: every Memcached structure
//! the paper identifies as blocking is replaced.
//!
//! Mutation linearizes on the node's *item word* (see [`node`]): `set`
//! publishes a freshly slab-allocated item with one CAS, `delete`
//! tombstones with one CAS, and migration `swap`s items out — so writers,
//! evictors and migrators can all race without losing updates.
//!
//! Memory pressure flows the paper's way: allocation failure first forces
//! the reclamation scheme forward (freeing memory that is merely waiting
//! on a grace period), and only then advances the CLOCK hand to evict.

pub mod node;
pub mod table;

use std::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::cache::{
    deadline_from_exptime, hash_key, is_expired, BatchSink, Cache, CacheConfig, GetResult, Op,
    StatsSnapshot, StoreOutcome, MAX_KEY_LEN,
};
use crate::ebr::{Collector, Guard};
use crate::metrics::{EngineMetrics, LatencyMetrics};
use crate::slab::{Slab, SlabConfig};

use node::{decode_item, live_word, Item, ItemState, Node, DEL, FRZ, ITEM_HEADER, TOMB_WORD};
use table::{migrate_bucket, search, Find, Table};

/// Allocation-retry rounds before a store reports `OutOfMemory`.
const OOM_ROUNDS: usize = 8;

/// Phase-A staging state for one batch op, consumed in phase B.
#[derive(Clone, Copy)]
enum Stage {
    /// Op stages nothing (get/delete).
    Pass,
    /// Plain storage op: the ready item or the terminal staging failure.
    Store(Result<*mut Item, StoreOutcome>),
    /// RMW op whose pre-read found no live value: terminal miss.
    RmwMiss,
    /// RMW op whose transform aborted (non-numeric incr/decr): terminal,
    /// nothing was allocated and no token is consumed.
    RmwAbort,
    /// RMW op staged like a plain store: install `item` iff the key's
    /// CAS token still equals `token`; `counter` is the incr/decr reply.
    RmwReady {
        token: u64,
        item: *mut Item,
        counter: Option<u64>,
    },
    /// RMW staging allocation failed (too large / out of memory).
    RmwFail(StoreOutcome),
    /// RMW op reading a key an earlier op in the same batch writes: it
    /// must observe that op's effect, so it runs the classic in-guard
    /// read-stage-install loop at its turn instead of speculating.
    RmwDependent,
}

/// Phase-A0 snapshot of the value an independent RMW op will transform.
enum RmwSnap {
    /// Not an RMW op.
    Pass,
    /// See [`Stage::RmwDependent`].
    Dependent,
    /// No live value under the key.
    Miss,
    /// Live value: token + header fields + a copy of the bytes.
    Live {
        token: u64,
        flags: u32,
        deadline: u32,
        data: Vec<u8>,
    },
}

/// Is this op one of the read-modify-write commands?
#[inline]
fn is_rmw(op: &Op<'_>) -> bool {
    matches!(
        op,
        Op::Append { .. } | Op::Prepend { .. } | Op::Incr { .. } | Op::Decr { .. } | Op::Touch { .. }
    )
}

/// The numeric-value parse `incr`/`decr` apply (protocol semantics:
/// UTF-8, surrounding whitespace tolerated).
#[inline]
fn parse_counter(data: &[u8]) -> Option<u64> {
    std::str::from_utf8(data).ok()?.trim().parse().ok()
}

/// The FLeeC cache engine.
pub struct FleecCache {
    collector: Arc<Collector>,
    slab: Arc<Slab>,
    /// Root of the table chain (EBR-protected).
    table: AtomicPtr<Table>,
    /// Live entries across the chain.
    items: AtomicUsize,
    /// Monotonic CAS-token source (also the RMW race detector).
    cas_counter: AtomicU64,
    metrics: EngineMetrics,
    /// Sampled per-op-class latency histograms (`stats latency`).
    latency: LatencyMetrics,
    config: CacheConfig,
    /// Planner-tunable eviction parameters.
    evict_decay: AtomicU8,
    evict_batch: AtomicU32,
    /// Debug-build test hook: staged batch-RMW installs that lost their
    /// token race and fell back to the in-guard loop. The batch tests
    /// assert this stays 0 for independent single-threaded batches.
    #[cfg(debug_assertions)]
    rmw_speculation_misses: AtomicU64,
}

impl FleecCache {
    /// Build an engine from `config`.
    pub fn new(config: CacheConfig) -> Self {
        let buckets = config.initial_buckets.next_power_of_two();
        let slab = Slab::new(SlabConfig {
            mem_limit: config.mem_limit,
            ..SlabConfig::default()
        });
        FleecCache {
            collector: Collector::default(),
            slab,
            table: AtomicPtr::new(Table::alloc(buckets)),
            items: AtomicUsize::new(0),
            cas_counter: AtomicU64::new(0),
            metrics: EngineMetrics::default(),
            latency: LatencyMetrics::default(),
            evict_batch: AtomicU32::new(config.evict_batch),
            evict_decay: AtomicU8::new(1),
            #[cfg(debug_assertions)]
            rmw_speculation_misses: AtomicU64::new(0),
            config,
        }
    }

    /// Failed staged-RMW installs since creation (debug builds; always 0
    /// in release). See the field doc.
    pub fn rmw_speculation_misses(&self) -> u64 {
        #[cfg(debug_assertions)]
        {
            // ord: relaxed-ok — debug accounting counter; stats tolerate
            // racy snapshots.
            self.rmw_speculation_misses.load(Ordering::Relaxed)
        }
        #[cfg(not(debug_assertions))]
        {
            0
        }
    }

    #[inline]
    fn note_rmw_speculation_miss(&self) {
        #[cfg(debug_assertions)]
        // ord: relaxed-ok — debug accounting counter.
        self.rmw_speculation_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// The EBR collector (shared with the coordinator).
    pub fn collector(&self) -> &Arc<Collector> {
        &self.collector
    }

    /// The engine's live request-path counters. Inherent on purpose:
    /// generic consumers read counters through the merging
    /// [`Cache::stats`] path only.
    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// The slab allocator (stats).
    pub fn slab(&self) -> &Arc<Slab> {
        &self.slab
    }

    #[inline]
    fn root<'g>(&self, _guard: &'g Guard) -> &'g Table {
        // SAFETY: the root table is only retired after being unlinked, and
        // we hold a guard.
        unsafe { &*self.table.load(Ordering::Acquire) }
    }

    /// Bump a bucket's CLOCK to the maximum (recently used). Load-first so
    /// hot buckets don't redirty the cache line on every hit.
    #[inline]
    fn touch_clock(&self, t: &Table, hash: u64) {
        let c = &t.clocks[t.index(hash)];
        let max = self.config.clock_max;
        // ord: relaxed-ok — CLOCK eviction heuristic (load + store below);
        // racy reads/writes only skew victim choice.
        if c.load(Ordering::Relaxed) != max {
            // ord: relaxed-ok — CLOCK heuristic, as above.
            c.store(max, Ordering::Relaxed);
        }
    }

    /// Mark a bucket mildly used (fresh insert: CLOCK 1 if previously 0,
    /// giving new items one sweep of protection without outranking hot
    /// buckets — the paper's multi-bit popularity distinction).
    #[inline]
    fn seed_clock(&self, t: &Table, hash: u64) {
        let c = &t.clocks[t.index(hash)];
        // ord: relaxed-ok — CLOCK eviction heuristic; a lost race only
        // skews victim choice.
        let _ = c.compare_exchange(0, 1, Ordering::Relaxed, Ordering::Relaxed);
    }

    /// Set the DEL mark on `node` unless its links are frozen.
    /// Returns false when frozen (caller must help migration).
    fn try_mark(node: &Node) -> bool {
        let mut w = node.next.load(Ordering::Acquire);
        loop {
            if w & DEL != 0 {
                return true;
            }
            if w & FRZ != 0 {
                return false;
            }
            match node
                .next
                // ord: AcqRel — Release seals the node's final successor
                // under the DEL mark; Acquire counterpart: the link loads
                // in search and the unlink CAS there.
                .compare_exchange_weak(w, w | DEL, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => return true,
                Err(cur) => w = cur,
            }
        }
    }

    /// Follow/assist the expansion chain until a write-search lands.
    fn locate_for_write<'g>(&self, hash: u64, key: &[u8], guard: &'g Guard) -> (&'g Table, Find) {
        let mut t = self.root(guard);
        loop {
            match search(t, hash, key, true, guard) {
                Find::Frozen => {
                    let next = t.next.load(Ordering::Acquire);
                    debug_assert!(!next.is_null());
                    // SAFETY: chain tables are retired only through EBR
                    // after the root swings past them; the guard keeps
                    // `next` live.
                    let next_ref = unsafe { &*next };
                    migrate_bucket(t, t.index(hash), next_ref, &self.slab, &self.items, guard);
                    self.try_promote(guard);
                    t = next_ref;
                }
                Find::Forwarded => {
                    let next = t.next.load(Ordering::Acquire);
                    debug_assert!(!next.is_null());
                    // SAFETY: guard-protected successor table, as above.
                    t = unsafe { &*next };
                }
                found => return (t, found),
            }
        }
    }

    /// If the root table is fully migrated, swing the root to its
    /// successor and retire the old generation.
    fn try_promote(&self, guard: &Guard) {
        let root = self.table.load(Ordering::Acquire);
        // SAFETY: the root table is only retired after being unlinked by
        // the CAS below, and we hold a guard.
        let t = unsafe { &*root };
        if !t.fully_migrated() {
            return;
        }
        let next = t.next.load(Ordering::Acquire);
        if next.is_null() {
            return;
        }
        if self
            .table
            // ord: AcqRel — Release publishes the promotion so later root
            // loads start at the new generation; Acquire counterpart: the
            // root loads in root() and here.
            .compare_exchange(root, next, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            // SAFETY: we won the root swing — sole retirer of the old
            // generation; stragglers still reading it hold guards.
            unsafe { guard.defer_drop_box(root) };
        }
    }

    /// Install a successor table when the load factor crosses the paper's
    /// 1.5 threshold.
    fn maybe_expand(&self, guard: &Guard) {
        let t = self.root(guard);
        // ord: relaxed-ok — load-factor heuristic; an approximate count
        // only shifts when expansion triggers.
        let items = self.items.load(Ordering::Relaxed);
        if (items as f64) <= self.config.load_factor * t.len() as f64 {
            return;
        }
        if !t.next.load(Ordering::Acquire).is_null() {
            // An expansion is already in flight: keep it moving (help one
            // bucket per overloaded insert) and promote when done, so
            // chained expansions never stall waiting for the maintenance
            // thread.
            // SAFETY: non-null was just checked; successor tables are
            // retired only through EBR and we hold a guard.
            let next = unsafe { &*t.next.load(Ordering::Acquire) };
            // ord: relaxed-ok — CLOCK-hand position; any interleaving of
            // increments is a valid sweep order.
            let idx = t.hand.fetch_add(1, Ordering::Relaxed) & t.mask;
            migrate_bucket(t, idx, next, &self.slab, &self.items, guard);
            self.try_promote(guard);
            return;
        }
        let new = Table::alloc(t.len() * 2);
        match t.next.compare_exchange(
            std::ptr::null_mut(),
            new,
            // ord: AcqRel — Release publishes the new table's initialized
            // buckets; Acquire counterpart: the `next` loads in
            // locate_for_write, migrate_bucket and the read paths.
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => {
                self.metrics.expansions.inc();
            }
            // SAFETY: the CAS failed — `new` was never published and we
            // still exclusively own the Box.
            Err(_) => unsafe {
                drop(Box::from_raw(new));
            },
        }
    }

    /// Allocate an item, driving reclamation and eviction on pressure.
    /// Runs UNPINNED (reclamation needs quiescence).
    fn alloc_item_pressured(
        &self,
        value: &[u8],
        flags: u32,
        deadline: u32,
        cas: u64,
    ) -> Result<*mut Item, StoreOutcome> {
        if ITEM_HEADER + value.len() > self.slab.chunk_size((self.slab.class_count() - 1) as u8) {
            return Err(StoreOutcome::TooLarge);
        }
        // Multi-tenant soft limits: an over-budget tenant evicts from
        // *itself* before touching the shared pool — the arbiter steers
        // memory by moving budget words, and this is the enforcement
        // edge. A tenant at its floor with nothing of its own left to
        // evict gets per-tenant OOM while other tenants keep storing.
        let tenant = crate::slab::tenant::current();
        let need = ITEM_HEADER + value.len();
        if self.slab.tenant_must_yield(tenant, need) {
            // ord: relaxed-ok — tuning knob; any recent value works.
            let batch = self.evict_batch.load(Ordering::Relaxed) as usize;
            for round in 0..OOM_ROUNDS {
                {
                    let guard = self.collector.pin();
                    self.evict_some_filtered(batch * (round + 1), &guard, Some(tenant));
                }
                // Evicted bytes leave the tenant's account only when the
                // grace period elapses (attribution unwinds in the EBR
                // reclaimer), so drain limbo before re-checking.
                self.collector.force_reclaim(2);
                if !self.slab.tenant_must_yield(tenant, need) {
                    break;
                }
            }
            if self.slab.tenant_must_yield(tenant, need) {
                // The budget still refuses `need` after evicting
                // everything of its own it could: per-tenant OOM. The
                // shared pool is off limits from over-budget, so other
                // tenants keep storing.
                self.metrics.oom_stalls.inc();
                return Err(StoreOutcome::OutOfMemory);
            }
        }
        for round in 0..OOM_ROUNDS {
            if let Some(item) = Item::alloc(&self.slab, value, flags, deadline, cas) {
                return Ok(item);
            }
            self.metrics.oom_stalls.inc();
            // Publish this thread's magazine-parked chunks (all classes)
            // to the shared free lists before acting on pressure: parked
            // chunks are free memory, and other threads/classes should be
            // able to reuse them before anything gets evicted. The raised
            // flush-request epoch reaches *other* threads' magazines too:
            // each registered thread flushes on its next alloc/free, so
            // only truly idle threads keep chunks parked (bounded by
            // MAG_CAP×idle-threads×chunk_size).
            self.slab.flush_local_magazines();
            self.slab.request_magazine_flush();
            // Paper order: reclaim limbo memory first (it is free memory
            // merely awaiting a grace period), evict only if that fails.
            self.collector.request_reclaim();
            self.collector.force_reclaim(2);
            if let Some(item) = Item::alloc(&self.slab, value, flags, deadline, cas) {
                return Ok(item);
            }
            {
                let guard = self.collector.pin();
                // ord: relaxed-ok — tuning knob; any recent value works.
                let batch = self.evict_batch.load(Ordering::Relaxed) as usize;
                self.evict_some(batch * (round + 1), &guard);
            }
            self.collector.force_reclaim(2);
        }
        Err(StoreOutcome::OutOfMemory)
    }

    /// Advance the CLOCK hand, decrementing per-bucket values and evicting
    /// the contents of zero-valued buckets, until `want` items were freed
    /// or two full revolutions found nothing.
    ///
    /// During expansion the sweep starts at the *tail* of the table chain
    /// (where migrated items live) and falls back to older generations
    /// for their unmigrated remainder — otherwise a mostly-forwarded root
    /// would starve eviction while memory sits in the successor.
    pub fn evict_some(&self, want: usize, guard: &Guard) -> usize {
        self.evict_some_filtered(want, guard, None)
    }

    /// [`Self::evict_some`] with an optional tenant filter: when set,
    /// only items stamped with that tenant are victims — the
    /// self-eviction half of per-tenant soft limits. The CLOCK hand and
    /// decay still advance globally (a filtered sweep is a normal sweep
    /// that declines other tenants' items).
    fn evict_some_filtered(&self, want: usize, guard: &Guard, tenant: Option<u8>) -> usize {
        // Collect the generation chain (expansion depth is ~1–2).
        let mut chain: Vec<&Table> = Vec::with_capacity(2);
        let mut t = self.root(guard);
        loop {
            chain.push(t);
            let next = t.next.load(Ordering::Acquire);
            if next.is_null() {
                break;
            }
            // SAFETY: chain tables are retired only through EBR after the
            // root swings past them; the guard keeps `next` live.
            t = unsafe { &*next };
        }
        // ord: relaxed-ok — tuning knob; any recent value works.
        let decay = self.evict_decay.load(Ordering::Relaxed).max(1);
        let mut freed = 0usize;
        for t in chain.iter().rev() {
            let size = t.len();
            let mut scanned = 0usize;
            while freed < want && scanned < 2 * size {
                // ord: relaxed-ok — CLOCK-hand position; any interleaving
                // of increments is a valid sweep order.
                let idx = t.hand.fetch_add(1, Ordering::Relaxed) & t.mask;
                scanned += 1;
                // ord: relaxed-ok — CLOCK eviction heuristic; a stale
                // value only skews victim choice.
                let c = t.clocks[idx].load(Ordering::Relaxed);
                if c > 0 {
                    // Racy decrement is fine: losing a race just means
                    // another sweeper already decremented.
                    let _ = t.clocks[idx].compare_exchange(
                        c,
                        c.saturating_sub(decay),
                        // ord: relaxed-ok — CLOCK heuristic (both
                        // orderings); a lost race only skews victims.
                        Ordering::Relaxed,
                        // ord: relaxed-ok — as above.
                        Ordering::Relaxed,
                    );
                    continue;
                }
                freed += self.evict_bucket(t, idx, guard, tenant);
            }
            if freed >= want {
                break;
            }
        }
        freed
    }

    /// Tombstone every live item in one bucket (skipping items whose
    /// stamp differs from `tenant`, when set). Returns items freed.
    fn evict_bucket(&self, t: &Table, idx: usize, guard: &Guard, tenant: Option<u8>) -> usize {
        let head = t.buckets[idx].load(Ordering::Acquire);
        if crate::sync::tagged::tag_of(head) != 0 {
            return 0; // frozen/forwarded: migration owns it
        }
        let mut freed = 0;
        let mut cur = crate::sync::tagged::untagged(head) as *mut Node;
        while !cur.is_null() {
            // SAFETY: nodes are unlinked before EBR retirement and we
            // hold a guard, so every reachable node is live.
            let node = unsafe { &*cur };
            let next = node.next.load(Ordering::Acquire);
            if next & DEL == 0 {
                let w = node.item.load(Ordering::Acquire);
                if let ItemState::Live(item) = decode_item(w) {
                    // SAFETY: the guard keeps `item` live (its word still
                    // carried the pointer a moment ago; retirement goes
                    // through EBR) and headers are immutable — the tenant
                    // stamp read cannot tear or dangle.
                    if tenant.is_some_and(|t| unsafe { (*item).tenant } != t) {
                        cur = crate::sync::tagged::untagged(next) as *mut Node;
                        continue;
                    }
                    if node
                        .item
                        // ord: AcqRel — Acquire pairs with the Release of
                        // the install CAS that published `item` (safe to
                        // retire); Release publishes the tombstone to
                        // writers whose item CAS now fails.
                        .compare_exchange(w, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        Item::retire(guard, &self.slab, item);
                        // ord: relaxed-ok — accounting counter; stats
                        // tolerate racy snapshots.
                        self.items.fetch_sub(1, Ordering::Relaxed);
                        self.metrics.evictions.inc();
                        Self::try_mark(node);
                        freed += 1;
                    }
                }
            }
            cur = crate::sync::tagged::untagged(next) as *mut Node;
        }
        freed
    }

    /// Lazily expire `node` (tombstone + retire). Returns true if we won.
    fn expire_node(&self, node: &Node, item_word: usize, item: *mut Item, guard: &Guard) -> bool {
        if node
            .item
            // ord: AcqRel — Acquire pairs with the Release of the install
            // CAS that published `item`; Release publishes the tombstone
            // to writers whose item CAS now fails.
            .compare_exchange(item_word, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            Item::retire(guard, &self.slab, item);
            // ord: relaxed-ok — accounting counter; stats tolerate racy
            // snapshots.
            self.items.fetch_sub(1, Ordering::Relaxed);
            self.metrics.expired.inc();
            Self::try_mark(node);
            true
        } else {
            false
        }
    }

    /// Shared store path. `mode` gates the precondition:
    /// set = unconditional, add = only-if-absent, replace = only-if-present,
    /// cas = only-if-token-matches.
    fn store(
        &self,
        key: &[u8],
        value: &[u8],
        flags: u32,
        exptime: u32,
        mode: StoreMode,
    ) -> StoreOutcome {
        if key.len() > MAX_KEY_LEN || key.is_empty() {
            return StoreOutcome::NotStored;
        }
        self.metrics.sets.inc();
        let deadline = deadline_from_exptime(exptime);
        let item = match self.alloc_item_pressured(value, flags, deadline, 0) {
            Ok(i) => i,
            Err(e) => return e,
        };
        let hash = hash_key(key);
        let guard = self.collector.pin();
        self.store_prealloc(key, hash, item, mode, &guard)
    }

    /// Install a pre-allocated `item` under `key` (metrics-free; the
    /// caller has already counted the set and may hold a batch-wide
    /// guard). Owns `item`: frees it on any non-`Stored` outcome.
    ///
    /// The CAS token is stamped here — at *install* time, not allocation
    /// time — so a batch that pre-allocates its items up front still
    /// hands out tokens in execution order, and batched runs produce the
    /// exact token sequence a sequential run would.
    fn store_prealloc(
        &self,
        key: &[u8],
        hash: u64,
        item: *mut Item,
        mode: StoreMode,
        guard: &Guard,
    ) -> StoreOutcome {
        // ord: relaxed-ok — the counter only needs uniqueness; the
        // install CAS's Release publishes the stamped token.
        let cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
        // SAFETY: `item` is exclusively ours — unpublished until the
        // install CAS below.
        unsafe { (*item).cas = cas };
        let mut shell: *mut Node = std::ptr::null_mut();
        let outcome = loop {
            let (t, find) = self.locate_for_write(hash, key, guard);
            match find {
                Find::Found(n) => {
                    // SAFETY: nodes are unlinked before EBR retirement and
                    // we hold a guard.
                    let node = unsafe { &*n };
                    let w = node.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(old) => {
                            // Preconditions against the live value.
                            // SAFETY: `old` was live under the guard;
                            // unpublished items retire through EBR, so the
                            // header outlives our pin.
                            let expired = is_expired(unsafe { (*old).deadline });
                            if expired && self.expire_node(node, w, old, guard) {
                                continue; // now absent; loop decides
                            }
                            match mode {
                                StoreMode::Add => break StoreOutcome::NotStored,
                                // SAFETY: guard-protected live item, as
                                // above.
                                StoreMode::Cas(expect) if unsafe { (*old).cas } != expect => {
                                    break StoreOutcome::Exists;
                                }
                                _ => {}
                            }
                            if node
                                .item
                                // ord: AcqRel — Release publishes the new
                                // item's bytes and token (Acquire
                                // counterpart: item loads in get_view /
                                // rmw_snapshot); Acquire pairs with the
                                // Release that published `old`, so the
                                // retire below is well-founded.
                                .compare_exchange(w, live_word(item), Ordering::AcqRel, Ordering::Acquire)
                                .is_ok()
                            {
                                Item::retire(guard, &self.slab, old);
                                self.touch_clock(t, hash);
                                break StoreOutcome::Stored;
                            }
                            // Raced with another writer/evictor: retry.
                        }
                        ItemState::Tomb => {
                            // Logically deleted node: finish its removal,
                            // then the key is absent.
                            if !Self::try_mark(node) {
                                continue; // frozen: next round helps
                            }
                            match mode {
                                StoreMode::Replace => break StoreOutcome::NotFound,
                                StoreMode::Cas(_) => break StoreOutcome::NotFound,
                                _ => continue,
                            }
                        }
                        ItemState::Moved => continue, // follow the chain
                    }
                }
                Find::Absent { pred, succ_word } => {
                    match mode {
                        StoreMode::Replace => break StoreOutcome::NotFound,
                        StoreMode::Cas(_) => break StoreOutcome::NotFound,
                        _ => {}
                    }
                    if shell.is_null() {
                        shell = Node::alloc(hash, key, item);
                    }
                    // SAFETY: `shell` is exclusively ours until the CAS
                    // below publishes it.
                    // ord: relaxed-ok — pre-publication store; the Release
                    // CAS below publishes it.
                    unsafe { (*shell).next.store(succ_word, Ordering::Relaxed) };
                    // SAFETY: `pred` is either a bucket head or a
                    // guard-protected node's link observed by search.
                    if unsafe {
                        (*pred).compare_exchange(
                            succ_word,
                            shell as usize,
                            // ord: AcqRel — Release publishes the node's
                            // hash/key/item/next writes; Acquire
                            // counterpart: the link loads in search.
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                    }
                    .is_ok()
                    {
                        shell = std::ptr::null_mut(); // published
                        // ord: relaxed-ok — accounting counter; the
                        // load-factor check tolerates approximation.
                        self.items.fetch_add(1, Ordering::Relaxed);
                        self.seed_clock(t, hash);
                        self.maybe_expand(guard);
                        break StoreOutcome::Stored;
                    }
                }
                Find::Frozen | Find::Forwarded => unreachable!("locate_for_write resolves these"),
            }
        };
        // Unpublished leftovers.
        if !shell.is_null() {
            // SAFETY: the shell was never published — we still exclusively
            // own the Box.
            unsafe { drop(Box::from_raw(shell)) };
        }
        if outcome != StoreOutcome::Stored {
            // SAFETY: on every non-Stored outcome the item was never
            // published — no reader can hold it, free directly.
            unsafe { Item::dealloc(&self.slab, item) };
        }
        outcome
    }

    /// Resolve one staged storage op from [`Cache::execute_batch`]'s
    /// pre-allocation phase: install the item, or surface the staging
    /// failure (invalid key, too large, out of memory).
    fn finish_staged(
        &self,
        key: &[u8],
        hash: u64,
        stage: Stage,
        mode: StoreMode,
        guard: &Guard,
    ) -> StoreOutcome {
        match stage {
            Stage::Store(Ok(item)) => self.store_prealloc(key, hash, item, mode, guard),
            Stage::Store(Err(e)) => e,
            _ => unreachable!("storage op was not staged in phase A"),
        }
    }

    /// Phase-A0 pre-read for an independent batched RMW op: the current
    /// token + header + value bytes, or `Miss`. Mirrors the classic
    /// [`FleecCache::rmw`] phase 1 (including lazy expiry).
    fn rmw_snapshot(&self, key: &[u8], hash: u64, guard: &Guard) -> RmwSnap {
        let mut t = self.root(guard);
        loop {
            match search(t, hash, key, false, guard) {
                Find::Found(n) => {
                    // SAFETY: nodes are unlinked before EBR retirement and
                    // we hold a guard.
                    let node = unsafe { &*n };
                    let w = node.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(item) => {
                            // SAFETY: live item observed under the guard;
                            // unpublishers retire through EBR, so header
                            // and bytes outlive our pin.
                            let hdr = unsafe { &*item };
                            if is_expired(hdr.deadline) {
                                self.expire_node(node, w, item, guard);
                                return RmwSnap::Miss;
                            }
                            return RmwSnap::Live {
                                token: hdr.cas,
                                flags: hdr.flags,
                                deadline: hdr.deadline,
                                // SAFETY: guard-protected live item, as
                                // above.
                                data: unsafe { Item::data(item) }.to_vec(),
                            };
                        }
                        ItemState::Tomb => return RmwSnap::Miss,
                        ItemState::Moved => {
                            let next = t.next.load(Ordering::Acquire);
                            if next.is_null() {
                                return RmwSnap::Miss;
                            }
                            // SAFETY: guard-protected successor table —
                            // chain tables retire only through EBR.
                            t = unsafe { &*next };
                        }
                    }
                }
                Find::Forwarded => {
                    let next = t.next.load(Ordering::Acquire);
                    if next.is_null() {
                        return RmwSnap::Miss;
                    }
                    // SAFETY: guard-protected successor table, as above.
                    t = unsafe { &*next };
                }
                Find::Absent { .. } | Find::Frozen => return RmwSnap::Miss,
            }
        }
    }

    /// Phase-A staging for one RMW op: apply the transform to the
    /// snapshot and pre-allocate the replacement item — **unpinned**, so
    /// allocation pressure can advance epochs freely, exactly like plain
    /// stores. Consumes the snapshot so append/touch reuse its buffer
    /// instead of copying the value a second time.
    fn stage_rmw(&self, op: &Op<'_>, snap: RmwSnap) -> Stage {
        let (token, flags, deadline, mut data) = match snap {
            RmwSnap::Dependent => return Stage::RmwDependent,
            RmwSnap::Miss => return Stage::RmwMiss,
            RmwSnap::Live {
                token,
                flags,
                deadline,
                data,
            } => (token, flags, deadline, data),
            RmwSnap::Pass => unreachable!("RMW op without a phase-A0 snapshot"),
        };
        let (value, new_flags, new_deadline, counter) = match *op {
            Op::Append { suffix, .. } => {
                data.extend_from_slice(suffix);
                (data, flags, deadline, None)
            }
            Op::Prepend { prefix, .. } => {
                let mut v = Vec::with_capacity(data.len() + prefix.len());
                v.extend_from_slice(prefix);
                v.extend_from_slice(&data);
                (v, flags, deadline, None)
            }
            Op::Incr { delta, .. } => {
                let Some(n) = parse_counter(&data) else {
                    return Stage::RmwAbort;
                };
                let v = n.wrapping_add(delta);
                (v.to_string().into_bytes(), flags, deadline, Some(v))
            }
            Op::Decr { delta, .. } => {
                let Some(n) = parse_counter(&data) else {
                    return Stage::RmwAbort;
                };
                let v = n.saturating_sub(delta);
                (v.to_string().into_bytes(), flags, deadline, Some(v))
            }
            Op::Touch { exptime, .. } => (data, flags, deadline_from_exptime(exptime), None),
            _ => unreachable!("stage_rmw on a non-RMW op"),
        };
        match self.alloc_item_pressured(&value, new_flags, new_deadline, 0) {
            Ok(item) => Stage::RmwReady {
                token,
                item,
                counter,
            },
            Err(e) => Stage::RmwFail(e),
        }
    }

    /// Phase-B install of a staged RMW item: succeeds iff the key still
    /// holds the snapshotted token (the CAS-token race detector, same as
    /// the classic RMW phase 3). Does **not** free `item` on failure —
    /// the caller owns that (and the fallback).
    fn install_staged_rmw(
        &self,
        key: &[u8],
        hash: u64,
        token: u64,
        item: *mut Item,
        guard: &Guard,
    ) -> bool {
        loop {
            let (_, find) = self.locate_for_write(hash, key, guard);
            match find {
                Find::Found(n) => {
                    // SAFETY: nodes are unlinked before EBR retirement and
                    // we hold a guard.
                    let node = unsafe { &*n };
                    let w = node.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(old) => {
                            // SAFETY: live item observed under the guard;
                            // unpublishers retire through EBR.
                            if unsafe { (*old).cas } != token {
                                return false;
                            }
                            // Stamp the token at install time so batched
                            // runs hand out tokens in execution order.
                            // ord: relaxed-ok — uniqueness only; the
                            // install CAS's Release publishes the stamp.
                            let cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
                            // SAFETY: `item` is exclusively ours until the
                            // CAS below publishes it.
                            unsafe { (*item).cas = cas };
                            if node
                                .item
                                // ord: AcqRel — Release publishes the new
                                // item's bytes and token; Acquire pairs
                                // with the Release that published `old`,
                                // grounding the retire below.
                                .compare_exchange(w, live_word(item), Ordering::AcqRel, Ordering::Acquire)
                                .is_ok()
                            {
                                Item::retire(guard, &self.slab, old);
                                return true;
                            }
                            // Raced with another writer: re-check; the
                            // token test decides next round.
                        }
                        ItemState::Tomb => return false,
                        ItemState::Moved => continue,
                    }
                }
                Find::Absent { .. } => return false,
                Find::Frozen | Find::Forwarded => {
                    unreachable!("locate_for_write resolves these")
                }
            }
        }
    }

    /// Phase-B resolution of one staged RMW op. `fallback` runs the
    /// classic in-guard loop when the speculation cannot apply (terminal
    /// stage outcomes short-circuit through `miss`/`fail`).
    fn finish_staged_rmw<T>(
        &self,
        key: &[u8],
        hash: u64,
        stage: Stage,
        guard: &Guard,
        on_success: impl FnOnce(Option<u64>) -> T,
        miss: T,
        fail: impl FnOnce(StoreOutcome) -> T,
        fallback: impl FnOnce() -> T,
    ) -> T {
        match stage {
            Stage::RmwReady {
                token,
                item,
                counter,
            } => {
                if self.install_staged_rmw(key, hash, token, item, guard) {
                    on_success(counter)
                } else {
                    // Token moved (or the key vanished) between the
                    // pre-read and our turn: drop the speculative item
                    // and rerun the read-stage-install loop in place.
                    // SAFETY: the speculative item was never published —
                    // no reader can hold it, free directly.
                    unsafe { Item::dealloc(&self.slab, item) };
                    self.note_rmw_speculation_miss();
                    fallback()
                }
            }
            Stage::RmwMiss | Stage::RmwAbort => miss,
            Stage::RmwFail(e) => fail(e),
            Stage::RmwDependent => fallback(),
            Stage::Pass | Stage::Store(_) => unreachable!("not an RMW stage"),
        }
    }

    /// Guard-passing lookup core (metrics-free): the body of [`Cache::get`]
    /// minus pinning and counting, shared by the single-key path and the
    /// batched fast path. Returns the hit's `(flags, cas, data)` with the
    /// value bytes **borrowed at the guard's lifetime** — zero copy.
    ///
    /// SOUNDNESS of the `'g` borrow: the returned slice points into the
    /// item's slab chunk. Every path that unpublishes a live item —
    /// overwrite ([`FleecCache::store_prealloc`]), delete, eviction,
    /// expiry, migration swap-out and `flush_all` — retires it through
    /// [`Item::retire`], i.e. through the EBR collector; nothing frees a
    /// *published* item's chunk directly. A retired item's chunk is only
    /// reused after a grace period no pinned guard straddles, so while
    /// `guard` stays pinned the bytes cannot be freed or recycled, no
    /// matter what concurrent writers do to the key. (Direct
    /// `slab.free` calls exist only for items that were never published:
    /// failed-store leftovers and lost staged-RMW speculations.) This is
    /// what lets the batched read path lend these slices across the API
    /// boundary ([`crate::cache::BatchSink::value`]) for the remainder
    /// of the batch.
    fn get_view<'g>(&self, key: &[u8], hash: u64, guard: &'g Guard) -> Option<(u32, u64, &'g [u8])> {
        let mut t = self.root(guard);
        loop {
            match search(t, hash, key, false, guard) {
                Find::Found(n) => {
                    // SAFETY: nodes are unlinked before EBR retirement and
                    // we hold a guard.
                    let node = unsafe { &*n };
                    let w = node.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(item) => {
                            // SAFETY: live item observed under the guard;
                            // see the SOUNDNESS note in the fn doc.
                            let hdr = unsafe { &*item };
                            if is_expired(hdr.deadline) {
                                self.expire_node(node, w, item, guard);
                                return None;
                            }
                            // SAFETY: the `'g` borrow is sound per the
                            // SOUNDNESS note in the fn doc — every
                            // unpublish retires through EBR, so the bytes
                            // outlive the guard.
                            // guard-stable: the lent slice lives in the
                            // item's slab chunk; retirement is deferred
                            // past every pinned guard.
                            let data: &'g [u8] = unsafe { Item::data(item) };
                            self.touch_clock(t, hash);
                            return Some((hdr.flags, hdr.cas, data));
                        }
                        ItemState::Tomb => return None,
                        ItemState::Moved => {
                            let next = t.next.load(Ordering::Acquire);
                            if next.is_null() {
                                return None;
                            }
                            // SAFETY: guard-protected successor table —
                            // chain tables retire only through EBR.
                            t = unsafe { &*next };
                        }
                    }
                }
                Find::Forwarded => {
                    let next = t.next.load(Ordering::Acquire);
                    if next.is_null() {
                        return None;
                    }
                    // SAFETY: guard-protected successor table, as above.
                    t = unsafe { &*next };
                }
                Find::Absent { .. } | Find::Frozen => return None,
            }
        }
    }

    /// Owning wrapper over [`FleecCache::get_view`].
    fn get_in(&self, key: &[u8], hash: u64, guard: &Guard) -> Option<GetResult> {
        self.get_view(key, hash, guard).map(|(flags, cas, data)| GetResult {
            data: data.to_vec(),
            flags,
            cas,
        })
    }

    /// Guard-passing delete core (metrics-free); see [`Cache::delete`].
    fn delete_in(&self, key: &[u8], hash: u64, guard: &Guard) -> bool {
        loop {
            let (_, find) = self.locate_for_write(hash, key, guard);
            match find {
                Find::Found(n) => {
                    // SAFETY: nodes are unlinked before EBR retirement and
                    // we hold a guard.
                    let node = unsafe { &*n };
                    let w = node.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(item) => {
                            if node
                                .item
                                // ord: AcqRel — Acquire pairs with the
                                // Release that published `item`; Release
                                // publishes the tombstone to racing
                                // writers.
                                .compare_exchange(w, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                                .is_ok()
                            {
                                Item::retire(guard, &self.slab, item);
                                // ord: relaxed-ok — accounting counter;
                                // stats tolerate racy snapshots.
                                self.items.fetch_sub(1, Ordering::Relaxed);
                                Self::try_mark(node);
                                // Nudge physical cleanup.
                                let _ = search(self.root(guard), hash, key, false, guard);
                                return true;
                            }
                        }
                        ItemState::Tomb => return false,
                        ItemState::Moved => continue,
                    }
                }
                Find::Absent { .. } => return false,
                _ => unreachable!(),
            }
        }
    }

    /// Read-modify-write with the CAS-token race detector:
    /// `f(flags, deadline, old_bytes)` computes the replacement
    /// `(value, flags, deadline)`; `None` aborts. Used by incr/decr,
    /// append/prepend and touch.
    fn rmw(
        &self,
        key: &[u8],
        f: impl Fn(u32, u32, &[u8]) -> Option<(Vec<u8>, u32, u32)>,
    ) -> RmwResult {
        let hash = hash_key(key);
        loop {
            // Phase 1 (pinned): snapshot the current item. Shares
            // [`FleecCache::rmw_snapshot`] with the batched staging path
            // so the two can never drift semantically.
            let snap = {
                let guard = self.collector.pin();
                self.rmw_snapshot(key, hash, &guard)
            };
            let (token, flags, deadline, data) = match snap {
                RmwSnap::Live {
                    token,
                    flags,
                    deadline,
                    data,
                } => (token, flags, deadline, data),
                _ => return RmwResult::NotFound,
            };
            // Phase 2 (unpinned): compute + allocate. The CAS token is
            // stamped at install time (inside `install_staged_rmw`), so a
            // failed allocation consumes no token — identically to the
            // batched staging path.
            let (new_value, new_flags, new_deadline) = match f(flags, deadline, &data) {
                Some(v) => v,
                None => return RmwResult::Aborted,
            };
            let item = match self.alloc_item_pressured(&new_value, new_flags, new_deadline, 0) {
                Ok(i) => i,
                Err(e) => return RmwResult::Failed(e),
            };
            // Phase 3 (pinned): install iff the token still matches —
            // the same token-guarded install the batched path uses.
            let guard = self.collector.pin();
            if self.install_staged_rmw(key, hash, token, item, &guard) {
                return RmwResult::Done(new_value);
            }
            // Token moved under us: free the speculative item and retry.
            // SAFETY: the speculative item was never published — no reader
            // can hold it, free directly.
            unsafe { Item::dealloc(&self.slab, item) };
        }
    }
}

/// Store precondition selector.
#[derive(Clone, Copy, PartialEq)]
enum StoreMode {
    Set,
    Add,
    Replace,
    Cas(u64),
}

/// Outcome of [`FleecCache::rmw`].
enum RmwResult {
    Done(Vec<u8>),
    NotFound,
    Aborted,
    Failed(StoreOutcome),
}

impl Cache for FleecCache {
    fn engine_name(&self) -> &'static str {
        "fleec"
    }

    /// The batched fast path: the whole batch crosses the engine once,
    /// results stream into `sink`, in batch order.
    ///
    /// * **One EBR guard** is pinned for the execution of the entire
    ///   batch (a sequential run pins once per op); ops that pin
    ///   internally nest re-entrantly at zero cost. Batches containing
    ///   RMW ops pin one *additional* short-lived guard up front (phase
    ///   A0 below) — never more than two top-level pins per batch.
    /// * **GET hits are delivered zero-copy**: [`BatchSink::value`] gets
    ///   the item's slab bytes directly ([`FleecCache::get_view`]). The
    ///   batch guard keeps every lent slice stable until the batch
    ///   returns — overwrites and evictions only retire items through
    ///   EBR — so the engine never materializes an owned value on the
    ///   read path.
    /// * Keys are **pre-hashed** up front and the bucket heads touched in
    ///   ascending bucket order, so execution finds the hot cache lines
    ///   resident.
    /// * Items for plain storage ops are **pre-allocated before pinning**
    ///   — allocation is the one step that may need to force reclamation,
    ///   which wants quiescence. (Under memory pressure this phase may
    ///   pin internally to evict; the pin bound holds on the uncontended
    ///   fast path.)
    /// * **RMW ops are staged like plain stores** (phase A0): their
    ///   current values are pre-read under the up-front guard, the
    ///   replacement items allocated *outside* any guard, and installed
    ///   at their turn iff the key's CAS token is unchanged — so batched
    ///   RMW no longer allocates under the held guard and epoch
    ///   advancement under memory pressure matches sequential execution.
    ///   An op whose key an earlier op in the same batch writes (or whose
    ///   token moved concurrently) reruns the classic read-stage-install
    ///   loop at its turn instead, which preserves exact sequential
    ///   semantics at the cost of allocating under the guard for that op
    ///   only.
    /// * Metrics are **batched**: one sharded-counter add per counter per
    ///   batch instead of one per op.
    ///
    /// Execution order is strictly the batch order — results and final
    /// state are identical to running the ops sequentially, including
    /// the `cas`-token sequence (tokens are stamped at install time) —
    /// **absent memory pressure**. At the memory limit one deliberate
    /// deviation remains: pre-allocation can trigger eviction before the
    /// batch's reads run, so eviction victims and `OutOfMemory` outcomes
    /// may differ from a sequential run. (Failed allocations consume no
    /// CAS token on either path — both stamp at install time.)
    fn execute_batch_into(&self, ops: &[Op<'_>], sink: &mut dyn BatchSink) {
        if ops.is_empty() {
            return;
        }
        let hashes: Vec<u64> = ops.iter().map(|op| hash_key(op.key())).collect();

        // Phase A0 (pinned briefly, only when the batch has RMW ops):
        // snapshot the value each *independent* RMW op will transform.
        // An RMW op behind an in-batch write to its key is marked
        // dependent instead — it must observe that write, not this
        // snapshot.
        let has_rmw = ops.iter().any(is_rmw);
        let mut snaps: Vec<RmwSnap> = Vec::new();
        if has_rmw {
            snaps.reserve_exact(ops.len());
            let guard = self.collector.pin();
            let mut written: Vec<&[u8]> = Vec::new();
            for (i, op) in ops.iter().enumerate() {
                let key = op.key();
                let snap = if is_rmw(op) {
                    if written.iter().any(|w| *w == key) {
                        RmwSnap::Dependent
                    } else {
                        self.rmw_snapshot(key, hashes[i], &guard)
                    }
                } else {
                    RmwSnap::Pass
                };
                snaps.push(snap);
                if !op.is_read() {
                    written.push(key);
                }
            }
        }

        // Phase A (unpinned): validate keys, pre-allocate storage items
        // and RMW replacement items. `staged[i]` holds each op's staging
        // state; allocation here may force reclamation/eviction, which is
        // exactly why no guard is held.
        let mut staged: Vec<Stage> = Vec::with_capacity(ops.len());
        let mut sets = 0u64;
        for (i, op) in ops.iter().enumerate() {
            let stage = match *op {
                Op::Set {
                    key,
                    value,
                    flags,
                    exptime,
                }
                | Op::Add {
                    key,
                    value,
                    flags,
                    exptime,
                }
                | Op::Replace {
                    key,
                    value,
                    flags,
                    exptime,
                }
                | Op::CasOp {
                    key,
                    value,
                    flags,
                    exptime,
                    ..
                } => {
                    if key.len() > MAX_KEY_LEN || key.is_empty() {
                        Stage::Store(Err(StoreOutcome::NotStored))
                    } else {
                        sets += 1;
                        let deadline = deadline_from_exptime(exptime);
                        // CAS token 0 here; store_prealloc stamps the real
                        // one at install time to keep sequential ordering.
                        Stage::Store(self.alloc_item_pressured(value, flags, deadline, 0))
                    }
                }
                Op::Append { .. }
                | Op::Prepend { .. }
                | Op::Incr { .. }
                | Op::Decr { .. }
                | Op::Touch { .. } => {
                    self.stage_rmw(op, std::mem::replace(&mut snaps[i], RmwSnap::Pass))
                }
                _ => Stage::Pass,
            };
            staged.push(stage);
        }

        // Phase B (pinned once): prefetch bucket heads, then execute in
        // batch order under the single guard, delivering straight into
        // the sink (value bytes lent from the slab — the guard keeps
        // them stable for the rest of the batch).
        let (mut gets, mut hits, mut misses, mut deletes) = (0u64, 0u64, 0u64, 0u64);
        // Sampled clock: one relaxed tick decides whether this batch
        // reads `Instant::now` at all; non-sampled batches pay one
        // predictable branch per op and nothing else.
        let timed = self.latency.sample_batch(self.config.latency_sample);
        {
            let guard = self.collector.pin();
            // Touch every bucket head in ascending bucket order (grouped
            // duplicates collapse into one line): a sequential sweep the
            // prefetcher can follow, instead of the batch's random walk.
            // Pointless for a singleton batch — execution follows
            // immediately — so depth-1 callers skip the sort entirely.
            if ops.len() > 1 {
                let t = self.root(&guard);
                let mut order: Vec<u32> = (0..ops.len() as u32).collect();
                order.sort_unstable_by_key(|&i| t.index(hashes[i as usize]));
                for &i in &order {
                    // ord: relaxed-ok — cache-line prefetch; the value is
                    // discarded and re-loaded with Acquire at execution.
                    let _ = t.buckets[t.index(hashes[i as usize])].load(Ordering::Relaxed);
                }
            }
            for (i, op) in ops.iter().enumerate() {
                let t0 = if timed { Some(std::time::Instant::now()) } else { None };
                let hash = hashes[i];
                match *op {
                    Op::Get { key } => {
                        gets += 1;
                        match self.get_view(key, hash, &guard) {
                            Some((flags, cas, data)) => {
                                hits += 1;
                                sink.value(i, key, flags, cas, data);
                            }
                            None => {
                                misses += 1;
                                sink.miss(i);
                            }
                        }
                    }
                    Op::Set { key, .. } => sink.store(
                        i,
                        self.finish_staged(key, hash, staged[i], StoreMode::Set, &guard),
                    ),
                    Op::Add { key, .. } => sink.store(
                        i,
                        self.finish_staged(key, hash, staged[i], StoreMode::Add, &guard),
                    ),
                    Op::Replace { key, .. } => sink.store(
                        i,
                        self.finish_staged(key, hash, staged[i], StoreMode::Replace, &guard),
                    ),
                    Op::CasOp { key, cas, .. } => sink.store(
                        i,
                        self.finish_staged(key, hash, staged[i], StoreMode::Cas(cas), &guard),
                    ),
                    Op::Delete { key } => {
                        deletes += 1;
                        sink.deleted(i, self.delete_in(key, hash, &guard));
                    }
                    // RMW ops: install the phase-A staged replacement
                    // (token-guarded); dependent/conflicted ops rerun the
                    // classic loop under the outer guard (re-entrant pin).
                    Op::Append { key, suffix } => sink.store(
                        i,
                        self.finish_staged_rmw(
                            key,
                            hash,
                            staged[i],
                            &guard,
                            |_| StoreOutcome::Stored,
                            StoreOutcome::NotStored,
                            |e| e,
                            || self.append(key, suffix),
                        ),
                    ),
                    Op::Prepend { key, prefix } => sink.store(
                        i,
                        self.finish_staged_rmw(
                            key,
                            hash,
                            staged[i],
                            &guard,
                            |_| StoreOutcome::Stored,
                            StoreOutcome::NotStored,
                            |e| e,
                            || self.prepend(key, prefix),
                        ),
                    ),
                    Op::Incr { key, delta } => sink.counter(
                        i,
                        self.finish_staged_rmw(
                            key,
                            hash,
                            staged[i],
                            &guard,
                            |counter| counter,
                            None,
                            |_| None,
                            || self.incr(key, delta),
                        ),
                    ),
                    Op::Decr { key, delta } => sink.counter(
                        i,
                        self.finish_staged_rmw(
                            key,
                            hash,
                            staged[i],
                            &guard,
                            |counter| counter,
                            None,
                            |_| None,
                            || self.decr(key, delta),
                        ),
                    ),
                    Op::Touch { key, exptime } => sink.touched(
                        i,
                        self.finish_staged_rmw(
                            key,
                            hash,
                            staged[i],
                            &guard,
                            |_| true,
                            false,
                            |_| false,
                            || self.touch(key, exptime),
                        ),
                    ),
                }
                if let Some(t0) = t0 {
                    self.latency
                        .record(op.class(), t0.elapsed().as_nanos() as u64);
                }
            }
        }

        // Phase C: one counter update each for the whole batch.
        if gets > 0 {
            self.metrics.gets.add(gets);
            self.metrics.hits.add(hits);
            self.metrics.misses.add(misses);
        }
        if sets > 0 {
            self.metrics.sets.add(sets);
        }
        if deletes > 0 {
            self.metrics.deletes.add(deletes);
        }
    }

    fn get(&self, key: &[u8]) -> Option<GetResult> {
        self.metrics.gets.inc();
        let hash = hash_key(key);
        let guard = self.collector.pin();
        let r = self.get_in(key, hash, &guard);
        if r.is_some() {
            self.metrics.hits.inc();
        } else {
            self.metrics.misses.inc();
        }
        r
    }

    fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Set)
    }

    fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Add)
    }

    fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Replace)
    }

    fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Cas(cas))
    }

    fn append(&self, key: &[u8], suffix: &[u8]) -> StoreOutcome {
        match self.rmw(key, |flags, deadline, old| {
            let mut v = Vec::with_capacity(old.len() + suffix.len());
            v.extend_from_slice(old);
            v.extend_from_slice(suffix);
            Some((v, flags, deadline))
        }) {
            RmwResult::Done(_) => StoreOutcome::Stored,
            RmwResult::NotFound => StoreOutcome::NotStored,
            RmwResult::Aborted => StoreOutcome::NotStored,
            RmwResult::Failed(e) => e,
        }
    }

    fn prepend(&self, key: &[u8], prefix: &[u8]) -> StoreOutcome {
        match self.rmw(key, |flags, deadline, old| {
            let mut v = Vec::with_capacity(old.len() + prefix.len());
            v.extend_from_slice(prefix);
            v.extend_from_slice(old);
            Some((v, flags, deadline))
        }) {
            RmwResult::Done(_) => StoreOutcome::Stored,
            RmwResult::NotFound => StoreOutcome::NotStored,
            RmwResult::Aborted => StoreOutcome::NotStored,
            RmwResult::Failed(e) => e,
        }
    }

    fn delete(&self, key: &[u8]) -> bool {
        self.metrics.deletes.inc();
        let hash = hash_key(key);
        let guard = self.collector.pin();
        self.delete_in(key, hash, &guard)
    }

    fn incr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut result = None;
        let out = self.rmw(key, |flags, deadline, old| {
            let n = parse_counter(old)?;
            let v = n.wrapping_add(delta);
            Some((v.to_string().into_bytes(), flags, deadline))
        });
        if let RmwResult::Done(v) = out {
            result = std::str::from_utf8(&v).ok()?.parse().ok();
        }
        result
    }

    fn decr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut result = None;
        let out = self.rmw(key, |flags, deadline, old| {
            let n = parse_counter(old)?;
            let v = n.saturating_sub(delta);
            Some((v.to_string().into_bytes(), flags, deadline))
        });
        if let RmwResult::Done(v) = out {
            result = std::str::from_utf8(&v).ok()?.parse().ok();
        }
        result
    }

    fn touch(&self, key: &[u8], exptime: u32) -> bool {
        let deadline = deadline_from_exptime(exptime);
        matches!(
            self.rmw(key, |flags, _old_deadline, old| Some((old.to_vec(), flags, deadline))),
            RmwResult::Done(_)
        )
    }

    fn flush_all(&self) {
        let guard = self.collector.pin();
        let mut t = self.root(&guard);
        loop {
            for idx in 0..t.len() {
                self.evict_bucket_for_flush(t, idx, &guard);
            }
            let next = t.next.load(Ordering::Acquire);
            if next.is_null() {
                break;
            }
            // SAFETY: guard-protected successor table — chain tables
            // retire only through EBR.
            t = unsafe { &*next };
        }
    }

    fn item_count(&self) -> usize {
        // ord: relaxed-ok — approximate counter by contract.
        self.items.load(Ordering::Relaxed)
    }

    fn bucket_count(&self) -> usize {
        let guard = self.collector.pin();
        self.root(&guard).len()
    }

    fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            metrics: self.metrics.snapshot(),
            items: self.item_count(),
            buckets: self.bucket_count(),
            mem_used: self.mem_used(),
            mem_limit: self.mem_limit(),
            latency: self.latency.snapshot(),
            internals: crate::cache::substrate_internals(&self.collector, &self.slab),
            slabs: crate::cache::slab_class_snapshots(&self.slab),
        }
    }

    fn mem_used(&self) -> usize {
        self.slab
            .class_stats()
            .iter()
            .map(|c| c.live_chunks * c.chunk_size)
            .sum()
    }

    fn mem_limit(&self) -> usize {
        self.config.mem_limit
    }

    fn tenant_slabs(&self) -> Vec<Arc<crate::slab::Slab>> {
        vec![Arc::clone(&self.slab)]
    }

    fn maintenance(&self) {
        let guard = self.collector.pin();
        let root = self.root(&guard);
        let next = root.next.load(Ordering::Acquire);
        if !next.is_null() {
            // SAFETY: guard-protected successor table — chain tables
            // retire only through EBR.
            let next_ref = unsafe { &*next };
            for idx in 0..root.len() {
                migrate_bucket(root, idx, next_ref, &self.slab, &self.items, &guard);
            }
            self.try_promote(&guard);
        }
    }

    fn clock_snapshot(&self) -> Option<Vec<u8>> {
        let guard = self.collector.pin();
        let t = self.root(&guard);
        Some(
            t.clocks
                .iter()
                // ord: relaxed-ok — diagnostic snapshot of the CLOCK
                // values; racy by nature.
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
        )
    }

    fn set_evict_params(&self, decay: u8, batch: u32) {
        // ord: relaxed-ok — tuning knobs (both stores); no data is
        // ordered against them.
        self.evict_decay.store(decay.max(1), Ordering::Relaxed);
        // ord: relaxed-ok — as above.
        self.evict_batch.store(batch.max(1), Ordering::Relaxed);
    }
}

impl FleecCache {
    /// `flush_all` helper: evict ignoring CLOCK values (no metrics
    /// eviction accounting — protocol flush is not cache pressure).
    fn evict_bucket_for_flush(&self, t: &Table, idx: usize, guard: &Guard) {
        let head = t.buckets[idx].load(Ordering::Acquire);
        if crate::sync::tagged::tag_of(head) != 0 {
            return;
        }
        let mut cur = crate::sync::tagged::untagged(head) as *mut Node;
        while !cur.is_null() {
            // SAFETY: nodes are unlinked before EBR retirement and we
            // hold a guard.
            let node = unsafe { &*cur };
            let next = node.next.load(Ordering::Acquire);
            let w = node.item.load(Ordering::Acquire);
            if let ItemState::Live(item) = decode_item(w) {
                if node
                    .item
                    // ord: AcqRel — Acquire pairs with the Release that
                    // published `item`; Release publishes the tombstone
                    // to racing writers.
                    .compare_exchange(w, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    Item::retire(guard, &self.slab, item);
                    // ord: relaxed-ok — accounting counter; stats
                    // tolerate racy snapshots.
                    self.items.fetch_sub(1, Ordering::Relaxed);
                    Self::try_mark(node);
                }
            }
            cur = crate::sync::tagged::untagged(next) as *mut Node;
        }
        // ord: relaxed-ok — CLOCK eviction heuristic reset.
        t.clocks[idx].store(0, Ordering::Relaxed);
    }
}

impl Drop for FleecCache {
    fn drop(&mut self) {
        // Exclusive access: free the whole table chain. Nodes are freed by
        // Table::drop; item chunks die with the slab pages; anything
        // retired into the collector frees when the collector drains.
        let mut t = *self.table.get_mut();
        while !t.is_null() {
            // SAFETY: `&mut self` in drop — exclusive access; every table
            // in the chain is owned by the cache until this point.
            let boxed = unsafe { Box::from_raw(t) };
            // ord: relaxed-ok — exclusive access in drop.
            t = boxed.next.load(Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheConfig, OpResult};

    fn small() -> FleecCache {
        FleecCache::new(CacheConfig::small())
    }

    #[test]
    fn set_get_roundtrip_with_metadata() {
        let c = small();
        assert_eq!(c.set(b"k", b"value", 77, 0), StoreOutcome::Stored);
        let r = c.get(b"k").unwrap();
        assert_eq!(r.data, b"value");
        assert_eq!(r.flags, 77);
        assert!(r.cas > 0);
        assert_eq!(c.item_count(), 1);
    }

    #[test]
    fn set_overwrites_and_bumps_cas() {
        let c = small();
        c.set(b"k", b"v1", 0, 0);
        let cas1 = c.get(b"k").unwrap().cas;
        c.set(b"k", b"v2", 0, 0);
        let r = c.get(b"k").unwrap();
        assert_eq!(r.data, b"v2");
        assert!(r.cas > cas1);
        assert_eq!(c.item_count(), 1, "overwrite must not grow the count");
    }

    #[test]
    fn add_replace_semantics() {
        let c = small();
        assert_eq!(c.replace(b"k", b"x", 0, 0), StoreOutcome::NotFound);
        assert_eq!(c.add(b"k", b"1", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.add(b"k", b"2", 0, 0), StoreOutcome::NotStored);
        assert_eq!(c.replace(b"k", b"3", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"3");
    }

    #[test]
    fn cas_token_gating() {
        let c = small();
        c.set(b"k", b"v1", 0, 0);
        let tok = c.get(b"k").unwrap().cas;
        assert_eq!(c.cas(b"k", b"v2", 0, 0, tok), StoreOutcome::Stored);
        assert_eq!(c.cas(b"k", b"v3", 0, 0, tok), StoreOutcome::Exists);
        assert_eq!(c.cas(b"missing", b"x", 0, 0, 1), StoreOutcome::NotFound);
        assert_eq!(c.get(b"k").unwrap().data, b"v2");
    }

    #[test]
    fn delete_then_reinsert() {
        let c = small();
        c.set(b"k", b"v", 0, 0);
        assert!(c.delete(b"k"));
        assert!(!c.delete(b"k"));
        assert!(c.get(b"k").is_none());
        assert_eq!(c.item_count(), 0);
        assert_eq!(c.set(b"k", b"v2", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"v2");
    }

    #[test]
    fn incr_decr_arithmetic() {
        let c = small();
        c.set(b"n", b"10", 0, 0);
        assert_eq!(c.incr(b"n", 5), Some(15));
        assert_eq!(c.decr(b"n", 3), Some(12));
        assert_eq!(c.decr(b"n", 100), Some(0), "decr saturates at 0");
        assert_eq!(c.incr(b"missing", 1), None);
        c.set(b"s", b"not-a-number", 0, 0);
        assert_eq!(c.incr(b"s", 1), None);
    }

    #[test]
    fn append_prepend() {
        let c = small();
        assert_eq!(c.append(b"k", b"x"), StoreOutcome::NotStored);
        c.set(b"k", b"mid", 0, 0);
        assert_eq!(c.append(b"k", b"-end"), StoreOutcome::Stored);
        assert_eq!(c.prepend(b"k", b"start-"), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"start-mid-end");
    }

    #[test]
    fn flush_all_empties_cache() {
        let c = small();
        for i in 0..100u32 {
            c.set(format!("k{i}").as_bytes(), b"v", 0, 0);
        }
        assert_eq!(c.item_count(), 100);
        c.flush_all();
        assert_eq!(c.item_count(), 0);
        for i in 0..100u32 {
            assert!(c.get(format!("k{i}").as_bytes()).is_none());
        }
    }

    #[test]
    fn expansion_triggers_and_preserves_items() {
        let c = FleecCache::new(CacheConfig {
            initial_buckets: 8,
            ..CacheConfig::small()
        });
        let n = 200u32;
        for i in 0..n {
            assert_eq!(
                c.set(format!("exp-{i}").as_bytes(), &i.to_le_bytes(), 0, 0),
                StoreOutcome::Stored
            );
        }
        // Drive migration to completion.
        for _ in 0..8 {
            c.maintenance();
        }
        assert!(
            c.bucket_count() > 8,
            "table should have expanded: {} buckets",
            c.bucket_count()
        );
        for i in 0..n {
            let r = c.get(format!("exp-{i}").as_bytes());
            assert_eq!(
                r.map(|r| r.data),
                Some(i.to_le_bytes().to_vec()),
                "key exp-{i} lost across expansion"
            );
        }
        assert_eq!(c.metrics.snapshot().expansions >= 1, true);
    }

    #[test]
    fn eviction_frees_memory_when_full() {
        let c = FleecCache::new(CacheConfig {
            mem_limit: 1 << 20,
            initial_buckets: 64,
            ..CacheConfig::small()
        });
        // 4 KiB values: ~256 fit in 1 MiB; insert 2000.
        let v = vec![0xAA; 4096];
        let mut stored = 0;
        for i in 0..2000u32 {
            if c.set(format!("ev-{i}").as_bytes(), &v, 0, 0) == StoreOutcome::Stored {
                stored += 1;
            }
        }
        assert_eq!(stored, 2000, "eviction must keep sets succeeding");
        let m = c.metrics.snapshot();
        assert!(m.evictions > 0, "evictions must have happened");
        assert!(c.item_count() < 600, "item count bounded by memory");
    }

    #[test]
    fn expiry_is_lazy_but_observed() {
        let c = small();
        // deadline_from_exptime(1) = now+1s; uptime starts at 0 in tests,
        // so use a deadline already in the past via the absolute branch.
        c.set(b"k", b"v", 0, 0);
        assert!(c.get(b"k").is_some());
        // Touch to an absolute deadline of 1 second of uptime; if the
        // process has been up longer (tests run after other tests), it is
        // expired immediately; otherwise wait.
        assert!(c.touch(b"k", 40_000_000)); // absolute, far past start+30d rule? falls in "absolute" branch
        // absolute uptime 40M secs is in the future → still alive
        assert!(c.get(b"k").is_some());
    }

    #[test]
    fn concurrent_storm_no_corruption() {
        use crate::workload::{check_value, encode_key, fill_value, KEY_LEN};
        let c = Arc::new(FleecCache::new(CacheConfig {
            mem_limit: 8 << 20,
            initial_buckets: 64, // force expansions under load
            ..CacheConfig::small()
        }));
        let threads = 8;
        std::thread::scope(|s| {
            for t in 0..threads {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    let mut rng = crate::sync::Xoshiro256::seeded(t);
                    let mut key = [0u8; KEY_LEN];
                    let mut val = vec![0u8; 128];
                    for _ in 0..10_000 {
                        let id = rng.next_below(500);
                        let k = encode_key(&mut key, id);
                        match rng.next_below(10) {
                            0..=6 => {
                                if let Some(r) = c.get(k) {
                                    assert!(
                                        check_value(id, &r.data),
                                        "corrupted value for id {id}"
                                    );
                                }
                            }
                            7..=8 => {
                                let len = 32 + (id as usize % 96);
                                fill_value(id, &mut val[..len]);
                                assert_eq!(c.set(k, &val[..len], 0, 0), StoreOutcome::Stored);
                            }
                            _ => {
                                let _ = c.delete(k);
                            }
                        }
                    }
                });
            }
        });
        // Post-storm: every surviving key must be readable & uncorrupted.
        let mut key = [0u8; crate::workload::KEY_LEN];
        for id in 0..500 {
            let k = crate::workload::encode_key(&mut key, id);
            if let Some(r) = c.get(k) {
                assert!(crate::workload::check_value(id, &r.data));
            }
        }
        c.collector().force_reclaim(4);
    }

    #[test]
    fn batched_ops_execute_in_order_with_one_guard() {
        let c = small();
        let ops = [
            Op::Set {
                key: b"k",
                value: b"v1",
                flags: 0,
                exptime: 0,
            },
            Op::Get { key: b"k" },
            Op::Set {
                key: b"k",
                value: b"v2",
                flags: 0,
                exptime: 0,
            },
            Op::Get { key: b"k" },
            Op::Delete { key: b"k" },
            Op::Get { key: b"k" },
        ];
        let before = c.collector().top_level_pins();
        let rs = c.execute_batch(&ops);
        let after = c.collector().top_level_pins();
        if cfg!(debug_assertions) {
            assert_eq!(after - before, 1, "batch must pin exactly one guard");
        }
        assert_eq!(rs[0], OpResult::Store(StoreOutcome::Stored));
        match &rs[1] {
            OpResult::Value(Some(r)) => assert_eq!(r.data, b"v1"),
            other => panic!("{other:?}"),
        }
        match &rs[3] {
            OpResult::Value(Some(r)) => assert_eq!(r.data, b"v2"),
            other => panic!("{other:?}"),
        }
        assert_eq!(rs[4], OpResult::Deleted(true));
        assert_eq!(rs[5], OpResult::Value(None));
        // Batched metrics landed with per-batch adds, not per-op incs.
        let m = c.metrics.snapshot();
        assert_eq!((m.gets, m.hits, m.misses), (3, 2, 1));
        assert_eq!((m.sets, m.deletes), (2, 1));
    }

    #[test]
    fn clock_snapshot_reflects_activity() {
        let c = small();
        c.set(b"hot", b"v", 0, 0);
        for _ in 0..10 {
            c.get(b"hot");
        }
        let clocks = c.clock_snapshot().unwrap();
        assert!(clocks.iter().any(|&v| v > 0), "some bucket must be warm");
        assert!(clocks.iter().all(|&v| v <= c.config.clock_max));
    }
}
