//! FLeeC — the paper's lock-free cache engine.
//!
//! One lock-free hash table with the CLOCK eviction policy *embedded*
//! (one multi-bit CLOCK value per bucket), Harris-list buckets,
//! DEBRA-variant epoch reclamation and non-blocking expansion. There is
//! no LRU list and no stop-the-world resize: every Memcached structure
//! the paper identifies as blocking is replaced.
//!
//! Mutation linearizes on the node's *item word* (see [`node`]): `set`
//! publishes a freshly slab-allocated item with one CAS, `delete`
//! tombstones with one CAS, and migration `swap`s items out — so writers,
//! evictors and migrators can all race without losing updates.
//!
//! Memory pressure flows the paper's way: allocation failure first forces
//! the reclamation scheme forward (freeing memory that is merely waiting
//! on a grace period), and only then advances the CLOCK hand to evict.

pub mod node;
pub mod table;

use std::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::cache::{
    deadline_from_exptime, hash_key, is_expired, Cache, CacheConfig, GetResult, StoreOutcome,
    MAX_KEY_LEN,
};
use crate::ebr::{Collector, Guard};
use crate::metrics::EngineMetrics;
use crate::slab::{Slab, SlabConfig};

use node::{decode_item, live_word, Item, ItemState, Node, DEL, FRZ, ITEM_HEADER, TOMB_WORD};
use table::{migrate_bucket, search, Find, Table};

/// Allocation-retry rounds before a store reports `OutOfMemory`.
const OOM_ROUNDS: usize = 8;

/// The FLeeC cache engine.
pub struct FleecCache {
    collector: Arc<Collector>,
    slab: Arc<Slab>,
    /// Root of the table chain (EBR-protected).
    table: AtomicPtr<Table>,
    /// Live entries across the chain.
    items: AtomicUsize,
    /// Monotonic CAS-token source (also the RMW race detector).
    cas_counter: AtomicU64,
    metrics: EngineMetrics,
    config: CacheConfig,
    /// Planner-tunable eviction parameters.
    evict_decay: AtomicU8,
    evict_batch: AtomicU32,
}

impl FleecCache {
    /// Build an engine from `config`.
    pub fn new(config: CacheConfig) -> Self {
        let buckets = config.initial_buckets.next_power_of_two();
        let slab = Arc::new(Slab::new(SlabConfig {
            mem_limit: config.mem_limit,
            ..SlabConfig::default()
        }));
        FleecCache {
            collector: Arc::new(Collector::default()),
            slab,
            table: AtomicPtr::new(Table::alloc(buckets)),
            items: AtomicUsize::new(0),
            cas_counter: AtomicU64::new(0),
            metrics: EngineMetrics::default(),
            evict_batch: AtomicU32::new(config.evict_batch),
            evict_decay: AtomicU8::new(1),
            config,
        }
    }

    /// The EBR collector (shared with the coordinator).
    pub fn collector(&self) -> &Arc<Collector> {
        &self.collector
    }

    /// The slab allocator (stats).
    pub fn slab(&self) -> &Arc<Slab> {
        &self.slab
    }

    #[inline]
    fn root<'g>(&self, _guard: &'g Guard) -> &'g Table {
        // SAFETY: the root table is only retired after being unlinked, and
        // we hold a guard.
        unsafe { &*self.table.load(Ordering::Acquire) }
    }

    /// Bump a bucket's CLOCK to the maximum (recently used). Load-first so
    /// hot buckets don't redirty the cache line on every hit.
    #[inline]
    fn touch_clock(&self, t: &Table, hash: u64) {
        let c = &t.clocks[t.index(hash)];
        let max = self.config.clock_max;
        if c.load(Ordering::Relaxed) != max {
            c.store(max, Ordering::Relaxed);
        }
    }

    /// Mark a bucket mildly used (fresh insert: CLOCK 1 if previously 0,
    /// giving new items one sweep of protection without outranking hot
    /// buckets — the paper's multi-bit popularity distinction).
    #[inline]
    fn seed_clock(&self, t: &Table, hash: u64) {
        let c = &t.clocks[t.index(hash)];
        let _ = c.compare_exchange(0, 1, Ordering::Relaxed, Ordering::Relaxed);
    }

    /// Set the DEL mark on `node` unless its links are frozen.
    /// Returns false when frozen (caller must help migration).
    fn try_mark(node: &Node) -> bool {
        let mut w = node.next.load(Ordering::Acquire);
        loop {
            if w & DEL != 0 {
                return true;
            }
            if w & FRZ != 0 {
                return false;
            }
            match node
                .next
                .compare_exchange_weak(w, w | DEL, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => return true,
                Err(cur) => w = cur,
            }
        }
    }

    /// Follow/assist the expansion chain until a write-search lands.
    fn locate_for_write<'g>(&self, hash: u64, key: &[u8], guard: &'g Guard) -> (&'g Table, Find) {
        let mut t = self.root(guard);
        loop {
            match search(t, hash, key, true, guard) {
                Find::Frozen => {
                    let next = t.next.load(Ordering::Acquire);
                    debug_assert!(!next.is_null());
                    let next_ref = unsafe { &*next };
                    migrate_bucket(t, t.index(hash), next_ref, &self.slab, &self.items, guard);
                    self.try_promote(guard);
                    t = next_ref;
                }
                Find::Forwarded => {
                    let next = t.next.load(Ordering::Acquire);
                    debug_assert!(!next.is_null());
                    t = unsafe { &*next };
                }
                found => return (t, found),
            }
        }
    }

    /// If the root table is fully migrated, swing the root to its
    /// successor and retire the old generation.
    fn try_promote(&self, guard: &Guard) {
        let root = self.table.load(Ordering::Acquire);
        let t = unsafe { &*root };
        if !t.fully_migrated() {
            return;
        }
        let next = t.next.load(Ordering::Acquire);
        if next.is_null() {
            return;
        }
        if self
            .table
            .compare_exchange(root, next, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            unsafe { guard.defer_drop_box(root) };
        }
    }

    /// Install a successor table when the load factor crosses the paper's
    /// 1.5 threshold.
    fn maybe_expand(&self, guard: &Guard) {
        let t = self.root(guard);
        let items = self.items.load(Ordering::Relaxed);
        if (items as f64) <= self.config.load_factor * t.len() as f64 {
            return;
        }
        if !t.next.load(Ordering::Acquire).is_null() {
            // An expansion is already in flight: keep it moving (help one
            // bucket per overloaded insert) and promote when done, so
            // chained expansions never stall waiting for the maintenance
            // thread.
            let next = unsafe { &*t.next.load(Ordering::Acquire) };
            let idx = t.hand.fetch_add(1, Ordering::Relaxed) & t.mask;
            migrate_bucket(t, idx, next, &self.slab, &self.items, guard);
            self.try_promote(guard);
            return;
        }
        let new = Table::alloc(t.len() * 2);
        match t.next.compare_exchange(
            std::ptr::null_mut(),
            new,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => {
                self.metrics.expansions.inc();
            }
            Err(_) => unsafe {
                drop(Box::from_raw(new));
            },
        }
    }

    /// Allocate an item, driving reclamation and eviction on pressure.
    /// Runs UNPINNED (reclamation needs quiescence).
    fn alloc_item_pressured(
        &self,
        value: &[u8],
        flags: u32,
        deadline: u32,
        cas: u64,
    ) -> Result<*mut Item, StoreOutcome> {
        if ITEM_HEADER + value.len() > self.slab.chunk_size((self.slab.class_count() - 1) as u8) {
            return Err(StoreOutcome::TooLarge);
        }
        for round in 0..OOM_ROUNDS {
            if let Some(item) = Item::alloc(&self.slab, value, flags, deadline, cas) {
                return Ok(item);
            }
            self.metrics.oom_stalls.inc();
            // Paper order: reclaim limbo memory first (it is free memory
            // merely awaiting a grace period), evict only if that fails.
            self.collector.request_reclaim();
            self.collector.force_reclaim(2);
            if let Some(item) = Item::alloc(&self.slab, value, flags, deadline, cas) {
                return Ok(item);
            }
            {
                let guard = self.collector.pin();
                let batch = self.evict_batch.load(Ordering::Relaxed) as usize;
                self.evict_some(batch * (round + 1), &guard);
            }
            self.collector.force_reclaim(2);
        }
        Err(StoreOutcome::OutOfMemory)
    }

    /// Advance the CLOCK hand, decrementing per-bucket values and evicting
    /// the contents of zero-valued buckets, until `want` items were freed
    /// or two full revolutions found nothing.
    ///
    /// During expansion the sweep starts at the *tail* of the table chain
    /// (where migrated items live) and falls back to older generations
    /// for their unmigrated remainder — otherwise a mostly-forwarded root
    /// would starve eviction while memory sits in the successor.
    pub fn evict_some(&self, want: usize, guard: &Guard) -> usize {
        // Collect the generation chain (expansion depth is ~1–2).
        let mut chain: Vec<&Table> = Vec::with_capacity(2);
        let mut t = self.root(guard);
        loop {
            chain.push(t);
            let next = t.next.load(Ordering::Acquire);
            if next.is_null() {
                break;
            }
            t = unsafe { &*next };
        }
        let decay = self.evict_decay.load(Ordering::Relaxed).max(1);
        let mut freed = 0usize;
        for t in chain.iter().rev() {
            let size = t.len();
            let mut scanned = 0usize;
            while freed < want && scanned < 2 * size {
                let idx = t.hand.fetch_add(1, Ordering::Relaxed) & t.mask;
                scanned += 1;
                let c = t.clocks[idx].load(Ordering::Relaxed);
                if c > 0 {
                    // Racy decrement is fine: losing a race just means
                    // another sweeper already decremented.
                    let _ = t.clocks[idx].compare_exchange(
                        c,
                        c.saturating_sub(decay),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    );
                    continue;
                }
                freed += self.evict_bucket(t, idx, guard);
            }
            if freed >= want {
                break;
            }
        }
        freed
    }

    /// Tombstone every live item in one bucket. Returns items freed.
    fn evict_bucket(&self, t: &Table, idx: usize, guard: &Guard) -> usize {
        let head = t.buckets[idx].load(Ordering::Acquire);
        if crate::sync::tagged::tag_of(head) != 0 {
            return 0; // frozen/forwarded: migration owns it
        }
        let mut freed = 0;
        let mut cur = crate::sync::tagged::untagged(head) as *mut Node;
        while !cur.is_null() {
            let node = unsafe { &*cur };
            let next = node.next.load(Ordering::Acquire);
            if next & DEL == 0 {
                let w = node.item.load(Ordering::Acquire);
                if let ItemState::Live(item) = decode_item(w) {
                    if node
                        .item
                        .compare_exchange(w, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        Item::retire(guard, &self.slab, item);
                        self.items.fetch_sub(1, Ordering::Relaxed);
                        self.metrics.evictions.inc();
                        Self::try_mark(node);
                        freed += 1;
                    }
                }
            }
            cur = crate::sync::tagged::untagged(next) as *mut Node;
        }
        freed
    }

    /// Lazily expire `node` (tombstone + retire). Returns true if we won.
    fn expire_node(&self, node: &Node, item_word: usize, item: *mut Item, guard: &Guard) -> bool {
        if node
            .item
            .compare_exchange(item_word, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            Item::retire(guard, &self.slab, item);
            self.items.fetch_sub(1, Ordering::Relaxed);
            self.metrics.expired.inc();
            Self::try_mark(node);
            true
        } else {
            false
        }
    }

    /// Shared store path. `mode` gates the precondition:
    /// set = unconditional, add = only-if-absent, replace = only-if-present,
    /// cas = only-if-token-matches.
    fn store(
        &self,
        key: &[u8],
        value: &[u8],
        flags: u32,
        exptime: u32,
        mode: StoreMode,
    ) -> StoreOutcome {
        if key.len() > MAX_KEY_LEN || key.is_empty() {
            return StoreOutcome::NotStored;
        }
        self.metrics.sets.inc();
        let deadline = deadline_from_exptime(exptime);
        let cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
        let item = match self.alloc_item_pressured(value, flags, deadline, cas) {
            Ok(i) => i,
            Err(e) => return e,
        };
        let hash = hash_key(key);
        let guard = self.collector.pin();
        let mut shell: *mut Node = std::ptr::null_mut();
        let outcome = loop {
            let (t, find) = self.locate_for_write(hash, key, &guard);
            match find {
                Find::Found(n) => {
                    let node = unsafe { &*n };
                    let w = node.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(old) => {
                            // Preconditions against the live value.
                            let expired = is_expired(unsafe { (*old).deadline });
                            if expired && self.expire_node(node, w, old, &guard) {
                                continue; // now absent; loop decides
                            }
                            match mode {
                                StoreMode::Add => break StoreOutcome::NotStored,
                                StoreMode::Cas(expect) if unsafe { (*old).cas } != expect => {
                                    break StoreOutcome::Exists;
                                }
                                _ => {}
                            }
                            if node
                                .item
                                .compare_exchange(w, live_word(item), Ordering::AcqRel, Ordering::Acquire)
                                .is_ok()
                            {
                                Item::retire(&guard, &self.slab, old);
                                self.touch_clock(t, hash);
                                break StoreOutcome::Stored;
                            }
                            // Raced with another writer/evictor: retry.
                        }
                        ItemState::Tomb => {
                            // Logically deleted node: finish its removal,
                            // then the key is absent.
                            if !Self::try_mark(node) {
                                continue; // frozen: next round helps
                            }
                            match mode {
                                StoreMode::Replace => break StoreOutcome::NotFound,
                                StoreMode::Cas(_) => break StoreOutcome::NotFound,
                                _ => continue,
                            }
                        }
                        ItemState::Moved => continue, // follow the chain
                    }
                }
                Find::Absent { pred, succ_word } => {
                    match mode {
                        StoreMode::Replace => break StoreOutcome::NotFound,
                        StoreMode::Cas(_) => break StoreOutcome::NotFound,
                        _ => {}
                    }
                    if shell.is_null() {
                        shell = Node::alloc(hash, key, item);
                    }
                    unsafe { (*shell).next.store(succ_word, Ordering::Relaxed) };
                    if unsafe {
                        (*pred).compare_exchange(
                            succ_word,
                            shell as usize,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                    }
                    .is_ok()
                    {
                        shell = std::ptr::null_mut(); // published
                        self.items.fetch_add(1, Ordering::Relaxed);
                        self.seed_clock(t, hash);
                        self.maybe_expand(&guard);
                        break StoreOutcome::Stored;
                    }
                }
                Find::Frozen | Find::Forwarded => unreachable!("locate_for_write resolves these"),
            }
        };
        // Unpublished leftovers.
        if !shell.is_null() {
            unsafe { drop(Box::from_raw(shell)) };
        }
        if outcome != StoreOutcome::Stored {
            unsafe { self.slab.free(item as *mut u8, (*item).class) };
        }
        outcome
    }

    /// Read-modify-write with the CAS-token race detector:
    /// `f(flags, deadline, old_bytes)` computes the replacement
    /// `(value, flags, deadline)`; `None` aborts. Used by incr/decr,
    /// append/prepend and touch.
    fn rmw(
        &self,
        key: &[u8],
        f: impl Fn(u32, u32, &[u8]) -> Option<(Vec<u8>, u32, u32)>,
    ) -> RmwResult {
        let hash = hash_key(key);
        loop {
            // Phase 1 (pinned): snapshot the current item.
            let snapshot = {
                let guard = self.collector.pin();
                let mut t = self.root(&guard);
                loop {
                    match search(t, hash, key, false, &guard) {
                        Find::Found(n) => {
                            let node = unsafe { &*n };
                            let w = node.item.load(Ordering::Acquire);
                            match decode_item(w) {
                                ItemState::Live(item) => {
                                    let hdr = unsafe { &*item };
                                    if is_expired(hdr.deadline) {
                                        self.expire_node(node, w, item, &guard);
                                        break None;
                                    }
                                    let data = unsafe { Item::data(item) }.to_vec();
                                    break Some((hdr.cas, hdr.flags, hdr.deadline, data));
                                }
                                ItemState::Tomb => break None,
                                ItemState::Moved => {
                                    let next = t.next.load(Ordering::Acquire);
                                    if next.is_null() {
                                        break None;
                                    }
                                    t = unsafe { &*next };
                                }
                            }
                        }
                        Find::Forwarded => {
                            let next = t.next.load(Ordering::Acquire);
                            if next.is_null() {
                                break None;
                            }
                            t = unsafe { &*next };
                        }
                        _ => break None,
                    }
                }
            };
            let (token, flags, deadline, data) = match snapshot {
                Some(s) => s,
                None => return RmwResult::NotFound,
            };
            // Phase 2 (unpinned): compute + allocate.
            let (new_value, new_flags, new_deadline) = match f(flags, deadline, &data) {
                Some(v) => v,
                None => return RmwResult::Aborted,
            };
            let new_cas = self.cas_counter.fetch_add(1, Ordering::Relaxed) + 1;
            let item = match self.alloc_item_pressured(&new_value, new_flags, new_deadline, new_cas)
            {
                Ok(i) => i,
                Err(e) => return RmwResult::Failed(e),
            };
            // Phase 3 (pinned): install iff the token still matches.
            let guard = self.collector.pin();
            let (_, find) = self.locate_for_write(hash, key, &guard);
            if let Find::Found(n) = find {
                let node = unsafe { &*n };
                let w = node.item.load(Ordering::Acquire);
                if let ItemState::Live(old) = decode_item(w) {
                    if unsafe { (*old).cas } == token
                        && node
                            .item
                            .compare_exchange(w, live_word(item), Ordering::AcqRel, Ordering::Acquire)
                            .is_ok()
                    {
                        Item::retire(&guard, &self.slab, old);
                        return RmwResult::Done(new_value);
                    }
                }
            }
            // Token moved under us: free the speculative item and retry.
            unsafe { self.slab.free(item as *mut u8, (*item).class) };
        }
    }
}

/// Store precondition selector.
#[derive(Clone, Copy, PartialEq)]
enum StoreMode {
    Set,
    Add,
    Replace,
    Cas(u64),
}

/// Outcome of [`FleecCache::rmw`].
enum RmwResult {
    Done(Vec<u8>),
    NotFound,
    Aborted,
    Failed(StoreOutcome),
}

impl Cache for FleecCache {
    fn engine_name(&self) -> &'static str {
        "fleec"
    }

    fn get(&self, key: &[u8]) -> Option<GetResult> {
        self.metrics.gets.inc();
        let hash = hash_key(key);
        let guard = self.collector.pin();
        let mut t = self.root(&guard);
        loop {
            match search(t, hash, key, false, &guard) {
                Find::Found(n) => {
                    let node = unsafe { &*n };
                    let w = node.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(item) => {
                            let hdr = unsafe { &*item };
                            if is_expired(hdr.deadline) {
                                self.expire_node(node, w, item, &guard);
                                self.metrics.misses.inc();
                                return None;
                            }
                            let data = unsafe { Item::data(item) }.to_vec();
                            let result = GetResult {
                                flags: hdr.flags,
                                cas: hdr.cas,
                                data,
                            };
                            self.touch_clock(t, hash);
                            self.metrics.hits.inc();
                            return Some(result);
                        }
                        ItemState::Tomb => {
                            self.metrics.misses.inc();
                            return None;
                        }
                        ItemState::Moved => {
                            let next = t.next.load(Ordering::Acquire);
                            if next.is_null() {
                                self.metrics.misses.inc();
                                return None;
                            }
                            t = unsafe { &*next };
                        }
                    }
                }
                Find::Forwarded => {
                    let next = t.next.load(Ordering::Acquire);
                    if next.is_null() {
                        self.metrics.misses.inc();
                        return None;
                    }
                    t = unsafe { &*next };
                }
                Find::Absent { .. } | Find::Frozen => {
                    self.metrics.misses.inc();
                    return None;
                }
            }
        }
    }

    fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Set)
    }

    fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Add)
    }

    fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Replace)
    }

    fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> StoreOutcome {
        self.store(key, value, flags, exptime, StoreMode::Cas(cas))
    }

    fn append(&self, key: &[u8], suffix: &[u8]) -> StoreOutcome {
        match self.rmw(key, |flags, deadline, old| {
            let mut v = Vec::with_capacity(old.len() + suffix.len());
            v.extend_from_slice(old);
            v.extend_from_slice(suffix);
            Some((v, flags, deadline))
        }) {
            RmwResult::Done(_) => StoreOutcome::Stored,
            RmwResult::NotFound => StoreOutcome::NotStored,
            RmwResult::Aborted => StoreOutcome::NotStored,
            RmwResult::Failed(e) => e,
        }
    }

    fn prepend(&self, key: &[u8], prefix: &[u8]) -> StoreOutcome {
        match self.rmw(key, |flags, deadline, old| {
            let mut v = Vec::with_capacity(old.len() + prefix.len());
            v.extend_from_slice(prefix);
            v.extend_from_slice(old);
            Some((v, flags, deadline))
        }) {
            RmwResult::Done(_) => StoreOutcome::Stored,
            RmwResult::NotFound => StoreOutcome::NotStored,
            RmwResult::Aborted => StoreOutcome::NotStored,
            RmwResult::Failed(e) => e,
        }
    }

    fn delete(&self, key: &[u8]) -> bool {
        self.metrics.deletes.inc();
        let hash = hash_key(key);
        let guard = self.collector.pin();
        loop {
            let (_, find) = self.locate_for_write(hash, key, &guard);
            match find {
                Find::Found(n) => {
                    let node = unsafe { &*n };
                    let w = node.item.load(Ordering::Acquire);
                    match decode_item(w) {
                        ItemState::Live(item) => {
                            if node
                                .item
                                .compare_exchange(w, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                                .is_ok()
                            {
                                Item::retire(&guard, &self.slab, item);
                                self.items.fetch_sub(1, Ordering::Relaxed);
                                Self::try_mark(node);
                                // Nudge physical cleanup.
                                let _ = search(self.root(&guard), hash, key, false, &guard);
                                return true;
                            }
                        }
                        ItemState::Tomb => return false,
                        ItemState::Moved => continue,
                    }
                }
                Find::Absent { .. } => return false,
                _ => unreachable!(),
            }
        }
    }

    fn incr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut result = None;
        let out = self.rmw(key, |flags, deadline, old| {
            let n: u64 = std::str::from_utf8(old).ok()?.trim().parse().ok()?;
            let v = n.wrapping_add(delta);
            Some((v.to_string().into_bytes(), flags, deadline))
        });
        if let RmwResult::Done(v) = out {
            result = std::str::from_utf8(&v).ok()?.parse().ok();
        }
        result
    }

    fn decr(&self, key: &[u8], delta: u64) -> Option<u64> {
        let mut result = None;
        let out = self.rmw(key, |flags, deadline, old| {
            let n: u64 = std::str::from_utf8(old).ok()?.trim().parse().ok()?;
            let v = n.saturating_sub(delta);
            Some((v.to_string().into_bytes(), flags, deadline))
        });
        if let RmwResult::Done(v) = out {
            result = std::str::from_utf8(&v).ok()?.parse().ok();
        }
        result
    }

    fn touch(&self, key: &[u8], exptime: u32) -> bool {
        let deadline = deadline_from_exptime(exptime);
        matches!(
            self.rmw(key, |flags, _old_deadline, old| Some((old.to_vec(), flags, deadline))),
            RmwResult::Done(_)
        )
    }

    fn flush_all(&self) {
        let guard = self.collector.pin();
        let mut t = self.root(&guard);
        loop {
            for idx in 0..t.len() {
                self.evict_bucket_for_flush(t, idx, &guard);
            }
            let next = t.next.load(Ordering::Acquire);
            if next.is_null() {
                break;
            }
            t = unsafe { &*next };
        }
    }

    fn item_count(&self) -> usize {
        self.items.load(Ordering::Relaxed)
    }

    fn bucket_count(&self) -> usize {
        let guard = self.collector.pin();
        self.root(&guard).len()
    }

    fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    fn mem_used(&self) -> usize {
        self.slab
            .class_stats()
            .iter()
            .map(|c| c.live_chunks * c.chunk_size)
            .sum()
    }

    fn maintenance(&self) {
        let guard = self.collector.pin();
        let root = self.root(&guard);
        let next = root.next.load(Ordering::Acquire);
        if !next.is_null() {
            let next_ref = unsafe { &*next };
            for idx in 0..root.len() {
                migrate_bucket(root, idx, next_ref, &self.slab, &self.items, &guard);
            }
            self.try_promote(&guard);
        }
    }

    fn clock_snapshot(&self) -> Option<Vec<u8>> {
        let guard = self.collector.pin();
        let t = self.root(&guard);
        Some(
            t.clocks
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
        )
    }

    fn set_evict_params(&self, decay: u8, batch: u32) {
        self.evict_decay.store(decay.max(1), Ordering::Relaxed);
        self.evict_batch.store(batch.max(1), Ordering::Relaxed);
    }
}

impl FleecCache {
    /// `flush_all` helper: evict ignoring CLOCK values (no metrics
    /// eviction accounting — protocol flush is not cache pressure).
    fn evict_bucket_for_flush(&self, t: &Table, idx: usize, guard: &Guard) {
        let head = t.buckets[idx].load(Ordering::Acquire);
        if crate::sync::tagged::tag_of(head) != 0 {
            return;
        }
        let mut cur = crate::sync::tagged::untagged(head) as *mut Node;
        while !cur.is_null() {
            let node = unsafe { &*cur };
            let next = node.next.load(Ordering::Acquire);
            let w = node.item.load(Ordering::Acquire);
            if let ItemState::Live(item) = decode_item(w) {
                if node
                    .item
                    .compare_exchange(w, TOMB_WORD, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    Item::retire(guard, &self.slab, item);
                    self.items.fetch_sub(1, Ordering::Relaxed);
                    Self::try_mark(node);
                }
            }
            cur = crate::sync::tagged::untagged(next) as *mut Node;
        }
        t.clocks[idx].store(0, Ordering::Relaxed);
    }
}

impl Drop for FleecCache {
    fn drop(&mut self) {
        // Exclusive access: free the whole table chain. Nodes are freed by
        // Table::drop; item chunks die with the slab pages; anything
        // retired into the collector frees when the collector drains.
        let mut t = *self.table.get_mut();
        while !t.is_null() {
            let boxed = unsafe { Box::from_raw(t) };
            t = boxed.next.load(Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheConfig;

    fn small() -> FleecCache {
        FleecCache::new(CacheConfig::small())
    }

    #[test]
    fn set_get_roundtrip_with_metadata() {
        let c = small();
        assert_eq!(c.set(b"k", b"value", 77, 0), StoreOutcome::Stored);
        let r = c.get(b"k").unwrap();
        assert_eq!(r.data, b"value");
        assert_eq!(r.flags, 77);
        assert!(r.cas > 0);
        assert_eq!(c.item_count(), 1);
    }

    #[test]
    fn set_overwrites_and_bumps_cas() {
        let c = small();
        c.set(b"k", b"v1", 0, 0);
        let cas1 = c.get(b"k").unwrap().cas;
        c.set(b"k", b"v2", 0, 0);
        let r = c.get(b"k").unwrap();
        assert_eq!(r.data, b"v2");
        assert!(r.cas > cas1);
        assert_eq!(c.item_count(), 1, "overwrite must not grow the count");
    }

    #[test]
    fn add_replace_semantics() {
        let c = small();
        assert_eq!(c.replace(b"k", b"x", 0, 0), StoreOutcome::NotFound);
        assert_eq!(c.add(b"k", b"1", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.add(b"k", b"2", 0, 0), StoreOutcome::NotStored);
        assert_eq!(c.replace(b"k", b"3", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"3");
    }

    #[test]
    fn cas_token_gating() {
        let c = small();
        c.set(b"k", b"v1", 0, 0);
        let tok = c.get(b"k").unwrap().cas;
        assert_eq!(c.cas(b"k", b"v2", 0, 0, tok), StoreOutcome::Stored);
        assert_eq!(c.cas(b"k", b"v3", 0, 0, tok), StoreOutcome::Exists);
        assert_eq!(c.cas(b"missing", b"x", 0, 0, 1), StoreOutcome::NotFound);
        assert_eq!(c.get(b"k").unwrap().data, b"v2");
    }

    #[test]
    fn delete_then_reinsert() {
        let c = small();
        c.set(b"k", b"v", 0, 0);
        assert!(c.delete(b"k"));
        assert!(!c.delete(b"k"));
        assert!(c.get(b"k").is_none());
        assert_eq!(c.item_count(), 0);
        assert_eq!(c.set(b"k", b"v2", 0, 0), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"v2");
    }

    #[test]
    fn incr_decr_arithmetic() {
        let c = small();
        c.set(b"n", b"10", 0, 0);
        assert_eq!(c.incr(b"n", 5), Some(15));
        assert_eq!(c.decr(b"n", 3), Some(12));
        assert_eq!(c.decr(b"n", 100), Some(0), "decr saturates at 0");
        assert_eq!(c.incr(b"missing", 1), None);
        c.set(b"s", b"not-a-number", 0, 0);
        assert_eq!(c.incr(b"s", 1), None);
    }

    #[test]
    fn append_prepend() {
        let c = small();
        assert_eq!(c.append(b"k", b"x"), StoreOutcome::NotStored);
        c.set(b"k", b"mid", 0, 0);
        assert_eq!(c.append(b"k", b"-end"), StoreOutcome::Stored);
        assert_eq!(c.prepend(b"k", b"start-"), StoreOutcome::Stored);
        assert_eq!(c.get(b"k").unwrap().data, b"start-mid-end");
    }

    #[test]
    fn flush_all_empties_cache() {
        let c = small();
        for i in 0..100u32 {
            c.set(format!("k{i}").as_bytes(), b"v", 0, 0);
        }
        assert_eq!(c.item_count(), 100);
        c.flush_all();
        assert_eq!(c.item_count(), 0);
        for i in 0..100u32 {
            assert!(c.get(format!("k{i}").as_bytes()).is_none());
        }
    }

    #[test]
    fn expansion_triggers_and_preserves_items() {
        let c = FleecCache::new(CacheConfig {
            initial_buckets: 8,
            ..CacheConfig::small()
        });
        let n = 200u32;
        for i in 0..n {
            assert_eq!(
                c.set(format!("exp-{i}").as_bytes(), &i.to_le_bytes(), 0, 0),
                StoreOutcome::Stored
            );
        }
        // Drive migration to completion.
        for _ in 0..8 {
            c.maintenance();
        }
        assert!(
            c.bucket_count() > 8,
            "table should have expanded: {} buckets",
            c.bucket_count()
        );
        for i in 0..n {
            let r = c.get(format!("exp-{i}").as_bytes());
            assert_eq!(
                r.map(|r| r.data),
                Some(i.to_le_bytes().to_vec()),
                "key exp-{i} lost across expansion"
            );
        }
        assert_eq!(c.metrics.snapshot().expansions >= 1, true);
    }

    #[test]
    fn eviction_frees_memory_when_full() {
        let c = FleecCache::new(CacheConfig {
            mem_limit: 1 << 20,
            initial_buckets: 64,
            ..CacheConfig::small()
        });
        // 4 KiB values: ~256 fit in 1 MiB; insert 2000.
        let v = vec![0xAA; 4096];
        let mut stored = 0;
        for i in 0..2000u32 {
            if c.set(format!("ev-{i}").as_bytes(), &v, 0, 0) == StoreOutcome::Stored {
                stored += 1;
            }
        }
        assert_eq!(stored, 2000, "eviction must keep sets succeeding");
        let m = c.metrics.snapshot();
        assert!(m.evictions > 0, "evictions must have happened");
        assert!(c.item_count() < 600, "item count bounded by memory");
    }

    #[test]
    fn expiry_is_lazy_but_observed() {
        let c = small();
        // deadline_from_exptime(1) = now+1s; uptime starts at 0 in tests,
        // so use a deadline already in the past via the absolute branch.
        c.set(b"k", b"v", 0, 0);
        assert!(c.get(b"k").is_some());
        // Touch to an absolute deadline of 1 second of uptime; if the
        // process has been up longer (tests run after other tests), it is
        // expired immediately; otherwise wait.
        assert!(c.touch(b"k", 40_000_000)); // absolute, far past start+30d rule? falls in "absolute" branch
        // absolute uptime 40M secs is in the future → still alive
        assert!(c.get(b"k").is_some());
    }

    #[test]
    fn concurrent_storm_no_corruption() {
        use crate::workload::{check_value, encode_key, fill_value, KEY_LEN};
        let c = Arc::new(FleecCache::new(CacheConfig {
            mem_limit: 8 << 20,
            initial_buckets: 64, // force expansions under load
            ..CacheConfig::small()
        }));
        let threads = 8;
        std::thread::scope(|s| {
            for t in 0..threads {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    let mut rng = crate::sync::Xoshiro256::seeded(t);
                    let mut key = [0u8; KEY_LEN];
                    let mut val = vec![0u8; 128];
                    for _ in 0..10_000 {
                        let id = rng.next_below(500);
                        let k = encode_key(&mut key, id);
                        match rng.next_below(10) {
                            0..=6 => {
                                if let Some(r) = c.get(k) {
                                    assert!(
                                        check_value(id, &r.data),
                                        "corrupted value for id {id}"
                                    );
                                }
                            }
                            7..=8 => {
                                let len = 32 + (id as usize % 96);
                                fill_value(id, &mut val[..len]);
                                assert_eq!(c.set(k, &val[..len], 0, 0), StoreOutcome::Stored);
                            }
                            _ => {
                                let _ = c.delete(k);
                            }
                        }
                    }
                });
            }
        });
        // Post-storm: every surviving key must be readable & uncorrupted.
        let mut key = [0u8; crate::workload::KEY_LEN];
        for id in 0..500 {
            let k = crate::workload::encode_key(&mut key, id);
            if let Some(r) = c.get(k) {
                assert!(crate::workload::check_value(id, &r.data));
            }
        }
        c.collector().force_reclaim(4);
    }

    #[test]
    fn clock_snapshot_reflects_activity() {
        let c = small();
        c.set(b"hot", b"v", 0, 0);
        for _ in 0..10 {
            c.get(b"hot");
        }
        let clocks = c.clock_snapshot().unwrap();
        assert!(clocks.iter().any(|&v| v > 0), "some bucket must be warm");
        assert!(clocks.iter().all(|&v| v <= c.config.clock_max));
    }
}
