//! FLeeC item and node representation.
//!
//! A cache entry is split in two:
//!
//! * the **item** — header + value bytes in one slab chunk. Items are
//!   immutable after publication; every mutation allocates a fresh item
//!   and swings the node's `item` word, so readers never observe torn
//!   values and CAS semantics (`gets`/`cas`) fall out of pointer identity.
//! * the **node** — the Harris-list entry owning the key. Its `item` word
//!   packs a state tag in the low bits of the item pointer:
//!   `LIVE(ptr)` / `TOMB` (logically deleted) / `MOVED` (transferred to
//!   the successor table during non-blocking expansion).
//!
//! The `item` word is the linearization point for set/delete/cas, which is
//! what makes eviction, deletion and migration commute safely: whoever
//! swaps the word owns the old item and is responsible for retiring it.

use std::sync::atomic::AtomicUsize;
use std::sync::Arc;

use crate::ebr::Guard;
use crate::slab::Slab;
use crate::sync::tagged::{tag_of, untagged};

/// `next`-word tag: node is logically deleted (Harris mark).
pub const DEL: usize = 0b01;
/// `next`-word tag: node's links are frozen for migration.
pub const FRZ: usize = 0b10;

/// `item`-word state tags.
pub const STATE_LIVE: usize = 0b00;
pub const STATE_TOMB: usize = 0b01;
pub const STATE_MOVED: usize = 0b10;

/// Packed `TOMB` word (no pointer payload).
pub const TOMB_WORD: usize = STATE_TOMB;
/// Packed `MOVED` word.
pub const MOVED_WORD: usize = STATE_MOVED;

/// Item header; value bytes follow contiguously in the same slab chunk.
#[repr(C)]
pub struct Item {
    pub vlen: u32,
    pub flags: u32,
    pub cas: u64,
    /// Absolute uptime deadline (0 = never expires).
    pub deadline: u32,
    /// Slab class the chunk came from (needed to free it).
    pub class: u8,
    /// Owning tenant (multi-tenant plane). Stamped at allocation from
    /// the thread-local current tenant and read back at free time,
    /// because EBR reclamation runs on whichever thread flushes the
    /// deferral queue — the header byte, not the freeing thread, is the
    /// source of truth for attribution.
    pub tenant: u8,
    _pad: [u8; 2],
}

pub const ITEM_HEADER: usize = std::mem::size_of::<Item>();

impl Item {
    /// Allocate an item from the slab and copy `value` in. `None` under
    /// memory pressure.
    // guard-stable: the returned chunk is exclusively owned (unpublished)
    // until the caller installs it in a node's item word; after
    // publication its bytes never change — mutation swings the word to a
    // fresh item and the old one is only freed via [`Item::retire`]
    // through EBR, so guard-holding readers keep a byte-stable view.
    pub fn alloc(
        slab: &Slab,
        value: &[u8],
        flags: u32,
        deadline: u32,
        cas: u64,
    ) -> Option<*mut Item> {
        let total = ITEM_HEADER + value.len();
        let (ptr, class) = slab.alloc(total)?;
        let tenant = crate::slab::tenant::current();
        slab.note_tenant_alloc(tenant, class);
        let item = ptr as *mut Item;
        // SAFETY: `ptr` is a fresh chunk of ≥ `total` bytes from
        // `slab.alloc`, exclusively ours — the header write and the value
        // copy stay in bounds and race with nothing.
        unsafe {
            item.write(Item {
                vlen: value.len() as u32,
                flags,
                cas,
                deadline,
                class,
                tenant,
                _pad: [0; 2],
            });
            std::ptr::copy_nonoverlapping(value.as_ptr(), ptr.add(ITEM_HEADER), value.len());
        }
        Some(item)
    }

    /// Free an item chunk, unwinding its tenant attribution — the single
    /// choke point every item free goes through (directly for
    /// exclusively-owned unpublished items, via [`Item::retire`]'s
    /// reclaimer for published ones), so per-tenant accounting can never
    /// drift from the chunks actually held.
    ///
    /// # Safety
    /// `ptr` must be an item from `slab` that the caller exclusively
    /// owns: either never published, or won via the item-word swap with
    /// its grace period already elapsed.
    pub unsafe fn dealloc(slab: &Slab, ptr: *mut Item) {
        let class = (*ptr).class;
        slab.note_tenant_free((*ptr).tenant, class);
        slab.free(ptr as *mut u8, class);
    }

    /// The value bytes of an item.
    ///
    /// # Safety
    /// `ptr` must be a live item protected by an EBR guard.
    // guard-stable: the slice lends the item's slab bytes. Items are
    // immutable after publication and unpublish only via [`Item::retire`]
    // (EBR), so while the caller's guard is pinned the bytes cannot be
    // freed or rewritten — the PR-5 read-path contract.
    pub unsafe fn data<'a>(ptr: *const Item) -> &'a [u8] {
        let vlen = (*ptr).vlen as usize;
        std::slice::from_raw_parts((ptr as *const u8).add(ITEM_HEADER), vlen)
    }

    /// Total slab bytes the item occupies.
    pub fn footprint(ptr: *const Item) -> usize {
        // SAFETY: callers pass an item that is either exclusively owned
        // (pre-publication) or guard-protected; the header is initialized
        // by `Item::alloc` and immutable thereafter.
        unsafe { ITEM_HEADER + (*ptr).vlen as usize }
    }

    /// Retire an item: after a grace period the chunk returns to `slab`.
    /// The `Arc` travels through the context word so the slab (and its
    /// pages) outlive every retired chunk no matter the drop order.
    pub fn retire(guard: &Guard, slab: &Arc<Slab>, ptr: *mut Item) {
        // SAFETY: the reclaimer runs only after the grace period; `p` is the
        // retired chunk and `ctx` the Arc<Slab> leaked below, so the
        // free targets live pages of the right slab.
        unsafe fn reclaim(p: *mut u8, ctx: usize) {
            let slab = Arc::from_raw(ctx as *const Slab);
            Item::dealloc(&slab, p as *mut Item);
            // `slab` Arc dropped here; last one frees the pages.
        }
        let ctx = Arc::into_raw(Arc::clone(slab)) as usize;
        let bytes = Item::footprint(ptr);
        // SAFETY: the caller won the item-word swap, so it exclusively
        // owns `ptr`'s retirement; no new reference can be created once
        // the word no longer carries the pointer.
        unsafe { guard.defer(ptr as *mut u8, ctx, bytes, reclaim) };
    }
}

/// Pack a live item pointer into an `item` word.
#[inline]
pub fn live_word(item: *mut Item) -> usize {
    debug_assert_eq!(item as usize & 0b11, 0);
    item as usize | STATE_LIVE
}

/// Decode an `item` word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ItemState {
    Live(*mut Item),
    Tomb,
    Moved,
}

#[inline]
pub fn decode_item(word: usize) -> ItemState {
    match tag_of(word) & 0b11 {
        STATE_LIVE => ItemState::Live(untagged(word) as *mut Item),
        STATE_TOMB => ItemState::Tomb,
        _ => ItemState::Moved,
    }
}

/// One Harris-list node. Nodes own their key; items are slab chunks hung
/// off the `item` word.
pub struct Node {
    pub hash: u64,
    /// Successor pointer | [`DEL`] | [`FRZ`].
    pub next: AtomicUsize,
    /// Packed item word (see [`decode_item`]).
    pub item: AtomicUsize,
    pub key: Box<[u8]>,
}

impl Node {
    /// Heap-allocate a node holding `item` (already slab-allocated).
    // guard-stable: returns an exclusively-owned, unpublished node; once
    // inserted into a bucket it is only freed through EBR retirement
    // after a successful unlink, never under a live guard.
    pub fn alloc(hash: u64, key: &[u8], item: *mut Item) -> *mut Node {
        Box::into_raw(Box::new(Node {
            hash,
            next: AtomicUsize::new(0),
            item: AtomicUsize::new(live_word(item)),
            key: key.to_vec().into_boxed_slice(),
        }))
    }

    /// Ordering key within a bucket: (hash, key bytes).
    #[inline]
    pub fn order(&self) -> (u64, &[u8]) {
        (self.hash, &self.key)
    }

    /// Whether this node matches (hash, key).
    #[inline]
    pub fn matches(&self, hash: u64, key: &[u8]) -> bool {
        self.hash == hash && *self.key == *key
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;
    use crate::ebr::Collector;
    use crate::slab::SlabConfig;

    #[test]
    fn item_roundtrips_value_and_metadata() {
        let slab = Slab::new(SlabConfig::small(1 << 20));
        let item = Item::alloc(&slab, b"hello world", 42, 7, 99).unwrap();
        unsafe {
            assert_eq!(Item::data(item), b"hello world");
            assert_eq!((*item).flags, 42);
            assert_eq!((*item).deadline, 7);
            assert_eq!((*item).cas, 99);
            assert_eq!(Item::footprint(item), ITEM_HEADER + 11);
            Item::dealloc(&slab, item);
        }
    }

    #[test]
    fn item_word_encoding() {
        let fake = 0x7000_0000_1000usize as *mut Item;
        assert_eq!(decode_item(live_word(fake)), ItemState::Live(fake));
        assert_eq!(decode_item(TOMB_WORD), ItemState::Tomb);
        assert_eq!(decode_item(MOVED_WORD), ItemState::Moved);
    }

    #[test]
    fn retire_keeps_slab_alive_until_reclaim() {
        let collector = Collector::default();
        let slab = Slab::new(SlabConfig::small(1 << 20));
        let item = Item::alloc(&slab, b"x", 0, 0, 1).unwrap();
        {
            let g = collector.pin();
            Item::retire(&g, &slab, item);
        }
        // Drop our slab handle before reclamation: the ctx Arc must keep
        // the pages alive until the deferred free runs.
        let weak = Arc::downgrade(&slab);
        drop(slab);
        assert!(weak.upgrade().is_some(), "retired item must hold the slab");
        collector.force_reclaim(3);
        assert!(weak.upgrade().is_none(), "slab released after reclaim");
    }

    #[test]
    fn node_ordering_and_matching() {
        let slab = Slab::new(SlabConfig::small(1 << 20));
        let item = Item::alloc(&slab, b"v", 0, 0, 1).unwrap();
        let n = Node::alloc(7, b"abc", item);
        unsafe {
            assert!((*n).matches(7, b"abc"));
            assert!(!(*n).matches(7, b"abd"));
            assert!(!(*n).matches(8, b"abc"));
            assert_eq!((*n).order(), (7, b"abc" as &[u8]));
            let boxed = Box::from_raw(n);
            if let ItemState::Live(p) = decode_item(boxed.item.load(Ordering::Relaxed)) {
                Item::dealloc(&slab, p);
            }
        }
    }
}
