//! FLeeC's hash table: lock-free buckets with an embedded CLOCK array and
//! non-blocking expansion.
//!
//! ## Bucket lists
//! Each bucket head is one atomic word pointing at a Harris list ordered
//! by `(hash, key)`. Deletion is logical-then-physical via the `DEL` mark;
//! traversals unlink marked nodes opportunistically.
//!
//! ## Embedded CLOCK (the paper's eviction design)
//! A parallel `AtomicU8` array holds one multi-bit CLOCK value per bucket
//! — the paper's *medium-grained* compromise: per-item CLOCK would make
//! the eviction sweep chase list pointers through cold memory, while
//! per-bucket values keep the sweep inside a contiguous array (cache
//! friendly), and the 1.5 load factor bounds each value to ≈1.5 items.
//! Hits store `clock_max`; the sweep decrements and evicts buckets that
//! reach zero. Everything is plain atomics — any number of threads may
//! sweep concurrently.
//!
//! ## Non-blocking expansion
//! When the cache installs a successor table (2× buckets), old buckets
//! migrate one at a time, cooperatively:
//!
//! 1. **Freeze** the bucket head (`BUCKET_FROZEN` tag) — head insertions
//!    now fail their CAS and help.
//! 2. **Freeze the links**: set the `FRZ` bit on every node's `next` so
//!    mid-list insertions/unlinks fail too (Braginsky & Petrank-style
//!    freezing). The list is now immutable *structurally*; item words
//!    stay mutable.
//! 3. **Transfer items**: `swap` each node's item word to `MOVED`; the
//!    winner of each swap re-inserts the live item into the successor
//!    table. Writers that lose the race observe `MOVED` and retry in the
//!    new table, so no update is ever lost.
//! 4. **Forward**: CAS the head to `BUCKET_FORWARD`; the winner retires
//!    the frozen node chain through EBR.
//!
//! Readers never block: a frozen bucket is still searchable, a forwarded
//! bucket redirects to the successor. A `get` racing step 3 may miss an
//! item mid-flight — acceptable for a cache (documented in DESIGN.md §4).

use std::sync::atomic::{AtomicPtr, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::ebr::Guard;
use crate::slab::Slab;
use crate::sync::tagged::{tag_of, untagged};

use super::node::{decode_item, Item, ItemState, Node, DEL, FRZ, MOVED_WORD};

/// Bucket-head tag: bucket is being migrated (head immutable).
pub const BUCKET_FROZEN: usize = 0b01;
/// Bucket-head tag: bucket fully migrated; look in `next` table.
pub const BUCKET_FORWARD: usize = 0b10;
/// The packed forward word.
pub const FORWARD_WORD: usize = BUCKET_FORWARD;

/// One table generation: bucket heads + CLOCK values + successor link.
pub struct Table {
    pub mask: usize,
    pub buckets: Box<[AtomicUsize]>,
    /// The embedded eviction state: one multi-bit CLOCK value per bucket.
    pub clocks: Box<[AtomicU8]>,
    /// Eviction hand (bucket index, wraps with the mask).
    pub hand: AtomicUsize,
    /// Successor table during expansion (null otherwise).
    pub next: AtomicPtr<Table>,
    /// Buckets already forwarded; expansion completes at `len()`.
    pub migrated: AtomicUsize,
}

impl Table {
    /// Allocate a table with `size` buckets (power of two).
    // guard-stable: returns an exclusively-owned, unpublished table; once
    // installed (as the root or a `next` successor) it is only freed
    // after migration completes via EBR retirement or at cache drop,
    // never while a guard may still traverse it.
    pub fn alloc(size: usize) -> *mut Table {
        assert!(size.is_power_of_two());
        let buckets = (0..size)
            .map(|_| AtomicUsize::new(0))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        let clocks = (0..size)
            .map(|_| AtomicU8::new(0))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Box::into_raw(Box::new(Table {
            mask: size - 1,
            buckets,
            clocks,
            hand: AtomicUsize::new(0),
            next: AtomicPtr::new(std::ptr::null_mut()),
            migrated: AtomicUsize::new(0),
        }))
    }

    /// Bucket count.
    #[inline]
    pub fn len(&self) -> usize {
        self.mask + 1
    }

    /// Bucket index for a hash.
    #[inline]
    pub fn index(&self, hash: u64) -> usize {
        (hash as usize) & self.mask
    }

    /// Whether every bucket has been forwarded.
    pub fn fully_migrated(&self) -> bool {
        self.migrated.load(Ordering::Acquire) == self.len()
    }
}

/// Where a bucket traversal ended up.
pub enum Find {
    /// Node with this exact key (pointer valid under the guard).
    Found(*mut Node),
    /// Key absent; `pred` is the link to CAS for an ordered insert and
    /// `succ_word` the exact word it held (tag 0).
    Absent {
        pred: *const AtomicUsize,
        succ_word: usize,
    },
    /// Bucket is frozen (mutations must help + retry in the successor).
    Frozen,
    /// Bucket fully forwarded to the successor table.
    Forwarded,
}

/// Search `table[idx]` for `(hash, key)`.
///
/// Unlinks marked nodes along the way (only while the bucket is unfrozen).
/// `for_write` controls whether a frozen bucket is an error ([`Find::Frozen`])
/// or still searchable (reads).
pub fn search(
    table: &Table,
    hash: u64,
    key: &[u8],
    for_write: bool,
    guard: &Guard,
) -> Find {
    let bucket = &table.buckets[table.index(hash)];
    'retry: loop {
        let head = bucket.load(Ordering::Acquire);
        match tag_of(head) {
            BUCKET_FORWARD => return Find::Forwarded,
            BUCKET_FROZEN if for_write => return Find::Frozen,
            _ => {}
        }
        let frozen = tag_of(head) == BUCKET_FROZEN;
        let mut pred: *const AtomicUsize = bucket;
        let mut pred_is_frozen = frozen;
        let mut curr_word = if frozen { untagged(head) } else { head };
        loop {
            let curr = untagged(curr_word) as *mut Node;
            if curr.is_null() {
                if frozen || pred_is_frozen {
                    // Exhausted a (partially) frozen list without finding
                    // the key: writers must help; readers follow — the
                    // item may already live in the successor.
                    return if for_write { Find::Frozen } else { Find::Forwarded };
                }
                return Find::Absent {
                    pred,
                    succ_word: curr_word,
                };
            }
            // SAFETY: `curr` was read from a live link under the guard.
            let node = unsafe { &*curr };
            let next = node.next.load(Ordering::Acquire);
            if next & DEL != 0 {
                // Logically deleted. Unlink if the structure is mutable.
                if next & FRZ == 0 && !pred_is_frozen && !frozen {
                    let clean = untagged(next);
                    // SAFETY: `pred` points into a guard-protected node
                    // (or the bucket head).
                    match unsafe {
                        // ord: Release publishes the shortened chain;
                        // Acquire counterpart: bucket/link loads in
                        // search and migrate_bucket.
                        (*pred).compare_exchange(curr_word, clean, Ordering::AcqRel, Ordering::Acquire)
                    } {
                        Ok(_) => {
                            // Unlinked: retire the node (its item was
                            // already retired by whoever tombstoned it).
                            // SAFETY: we won the unlink CAS — sole retirer
                            // of a Box-allocated node now unreachable.
                            unsafe { guard.defer_drop_box(curr) };
                            curr_word = clean;
                            continue;
                        }
                        Err(_) => continue 'retry,
                    }
                }
                // Frozen or racing: just step over it.
                pred = &node.next;
                pred_is_frozen = true;
                curr_word = untagged(next);
                continue;
            }
            match node.order() {
                o if o < (hash, key) => {
                    pred = &node.next;
                    pred_is_frozen = next & FRZ != 0;
                    // DEL is clear here, so this is the exact stored word
                    // when unfrozen (what an insert CAS must expect) and a
                    // clean pointer when frozen (read-only traversal).
                    curr_word = untagged(next);
                    continue;
                }
                o if o == (hash, key) => return Find::Found(curr),
                _ => {
                    if frozen || pred_is_frozen {
                        if for_write {
                            return Find::Frozen;
                        }
                        // Read miss in a frozen prefix: the key may have
                        // been migrated already.
                        return Find::Forwarded;
                    }
                    return Find::Absent {
                        pred,
                        succ_word: curr_word,
                    };
                }
            }
        }
    }
}

/// Migrate one bucket of `table` into `next_table` (idempotent, any number
/// of helpers). Returns once the bucket is forwarded.
pub fn migrate_bucket(
    table: &Table,
    idx: usize,
    next_table: &Table,
    slab: &Arc<Slab>,
    items_delta: &AtomicUsize,
    guard: &Guard,
) {
    let bucket = &table.buckets[idx];
    // Phase 1: freeze the head.
    let head = loop {
        let w = bucket.load(Ordering::Acquire);
        match tag_of(w) {
            BUCKET_FORWARD => return,
            BUCKET_FROZEN => break untagged(w),
            _ => {
                if bucket
                    // ord: Release publishes the freeze so helpers see a
                    // consistent head; Acquire counterpart: the bucket
                    // loads in search/migrate_bucket.
                    .compare_exchange(w, untagged(w) | BUCKET_FROZEN, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    break untagged(w);
                }
            }
        }
    };

    // Phase 2: freeze every link so the structure is immutable.
    let mut cur = head as *mut Node;
    while !cur.is_null() {
        // SAFETY: the chain hangs off a frozen head and is only retired
        // by the phase-4 winner through EBR; our guard protects it.
        let node = unsafe { &*cur };
        let mut w = node.next.load(Ordering::Acquire);
        while w & FRZ == 0 {
            match node
                .next
                // ord: Release publishes the frozen link; Acquire
                // counterpart: next-loads in search (step-over path) and
                // the phase-3 walk below.
                .compare_exchange_weak(w, w | FRZ, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => {
                    w |= FRZ;
                }
                Err(cur_w) => w = cur_w,
            }
        }
        cur = untagged(w) as *mut Node;
    }

    // Phase 3: transfer live items.
    let mut cur = head as *mut Node;
    while !cur.is_null() {
        // SAFETY: same chain as phase 2, still guard-protected.
        let node = unsafe { &*cur };
        let next = node.next.load(Ordering::Acquire);
        if next & DEL == 0 {
            // ord: AcqRel swap — Acquire sees the writer's Release that
            // published the item; Release makes MOVED (and our transfer)
            // visible to writers whose item-word CAS now fails.
            let prev = node.item.swap(MOVED_WORD, Ordering::AcqRel);
            if let ItemState::Live(item) = decode_item(prev) {
                insert_migrated(next_table, node.hash, &node.key, item, slab, items_delta, guard);
            }
        } else {
            // Deleted node: make sure the word is MOVED so late writers
            // bounce to the successor rather than resurrecting it.
            // ord: AcqRel — same pairing as the live-item swap above.
            node.item.swap(MOVED_WORD, Ordering::AcqRel);
        }
        cur = untagged(next) as *mut Node;
    }

    // Phase 4: forward the bucket; the winner retires the chain.
    if bucket
        .compare_exchange(
            head | BUCKET_FROZEN,
            FORWARD_WORD,
            // ord: Release publishes the completed transfer before the
            // forward word; Acquire counterpart: bucket loads in search
            // that redirect to the successor.
            Ordering::AcqRel,
            Ordering::Acquire,
        )
        .is_ok()
    {
        // ord: AcqRel — Release orders this bucket's forward before the
        // count; Acquire counterpart: fully_migrated()'s load, so a true
        // result proves every forward happened-before.
        table.migrated.fetch_add(1, Ordering::AcqRel);
        let mut cur = head as *mut Node;
        while !cur.is_null() {
            // SAFETY: forward CAS won — we are the sole retirer of the
            // frozen chain; the guard keeps it live while we walk it.
            let node = unsafe { &*cur };
            let next = untagged(node.next.load(Ordering::Acquire)) as *mut Node;
            // SAFETY: each node is a Box unreachable from the forwarded
            // bucket; retired exactly once by the CAS winner.
            unsafe { guard.defer_drop_box(cur) };
            cur = next;
        }
    }
}

/// Insert an already-allocated item into `table` during migration. If the
/// key already exists (a writer beat the migration), the *newer* value
/// wins and the migrated item is retired instead.
fn insert_migrated(
    table: &Table,
    hash: u64,
    key: &[u8],
    item: *mut Item,
    slab: &Arc<Slab>,
    items_delta: &AtomicUsize,
    guard: &Guard,
) {
    let mut node: *mut Node = std::ptr::null_mut();
    loop {
        match search(table, hash, key, true, guard) {
            Find::Found(_) => {
                // A racing writer already stored a newer value there.
                Item::retire(guard, slab, item);
                // ord: relaxed-ok — item-count accounting only.
                items_delta.fetch_sub(1, Ordering::Relaxed);
                break;
            }
            Find::Absent { pred, succ_word } => {
                if node.is_null() {
                    node = Node::alloc(hash, key, item);
                }
                // SAFETY: `node` is ours until the CAS below publishes it.
                // ord: relaxed-ok — pre-publication store; the Release
                // CAS below publishes it.
                unsafe { (*node).next.store(succ_word, Ordering::Relaxed) };
                // SAFETY: `pred` points into a guard-protected node (or
                // the bucket head) returned by search.
                if unsafe {
                    // ord: Release publishes the node's writes; Acquire
                    // counterpart: link loads in search.
                    (*pred).compare_exchange(succ_word, node as usize, Ordering::AcqRel, Ordering::Acquire)
                }
                .is_ok()
                {
                    break;
                }
            }
            Find::Frozen | Find::Forwarded => {
                // The *successor* is itself expanding; follow its chain.
                let next = table.next.load(Ordering::Acquire);
                assert!(!next.is_null(), "frozen bucket without successor");
                // Free the node shell if we allocated one for this table.
                if !node.is_null() {
                    // SAFETY: the CAS never succeeded, so the node was
                    // never published — still exclusively ours.
                    unsafe { drop(Box::from_raw(node)) };
                }
                insert_migrated(
                    // SAFETY: a non-null successor stays live while our
                    // guard is pinned (tables retire through EBR).
                    unsafe { &*next },
                    hash,
                    key,
                    item,
                    slab,
                    items_delta,
                    guard,
                );
                break;
            }
        }
    }
    // Mildly warm: a migrated bucket starts with CLOCK = 1, matching the
    // "not recently used but present" state.
    let idx = table.index(hash);
    // ord: relaxed-ok — CLOCK values are eviction heuristics; no memory
    // is published through them.
    let _ = table.clocks[idx].compare_exchange(0, 1, Ordering::Relaxed, Ordering::Relaxed);
}

impl Drop for Table {
    fn drop(&mut self) {
        // Exclusive: free any remaining chains. Items inside nodes are
        // slab chunks — freed when the slab drops its pages, or already
        // retired; nodes are ours.
        for bucket in self.buckets.iter() {
            // ord: relaxed-ok — `&mut self` in drop; no concurrent
            // writers exist (applies to every load in this fn).
            let mut cur = untagged(bucket.load(Ordering::Relaxed)) as *mut Node;
            // ord: relaxed-ok — exclusive access in drop.
            if tag_of(bucket.load(Ordering::Relaxed)) == BUCKET_FORWARD {
                continue;
            }
            while !cur.is_null() {
                // SAFETY: exclusive access in drop; every non-forwarded
                // chain node is a Box owned by this table alone.
                let node = unsafe { Box::from_raw(cur) };
                // ord: relaxed-ok — exclusive access in drop.
                cur = untagged(node.next.load(Ordering::Relaxed)) as *mut Node;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::hash_key;
    use crate::ebr::Collector;
    use crate::slab::SlabConfig;

    fn setup() -> (Arc<Collector>, Arc<Slab>, *mut Table) {
        (
            Collector::default(),
            Slab::new(SlabConfig::small(1 << 20)),
            Table::alloc(8),
        )
    }

    fn insert_fresh(
        table: &Table,
        slab: &Arc<Slab>,
        key: &[u8],
        val: &[u8],
        guard: &Guard,
    ) -> bool {
        let hash = hash_key(key);
        loop {
            match search(table, hash, key, true, guard) {
                Find::Found(_) => return false,
                Find::Absent { pred, succ_word } => {
                    let item = Item::alloc(slab, val, 0, 0, 1).unwrap();
                    let node = Node::alloc(hash, key, item);
                    unsafe { (*node).next.store(succ_word, Ordering::Relaxed) };
                    if unsafe {
                        (*pred).compare_exchange(
                            succ_word,
                            node as usize,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                    }
                    .is_ok()
                    {
                        return true;
                    }
                    unsafe {
                        let b = Box::from_raw(node);
                        if let ItemState::Live(p) = decode_item(b.item.load(Ordering::Relaxed)) {
                            Item::dealloc(slab, p);
                        }
                    }
                }
                _ => panic!("unexpected frozen/forwarded in fresh table"),
            }
        }
    }

    fn lookup(table: &Table, key: &[u8], guard: &Guard) -> Option<Vec<u8>> {
        let hash = hash_key(key);
        match search(table, hash, key, false, guard) {
            Find::Found(n) => {
                let w = unsafe { (*n).item.load(Ordering::Acquire) };
                match decode_item(w) {
                    ItemState::Live(item) => Some(unsafe { Item::data(item) }.to_vec()),
                    _ => None,
                }
            }
            _ => None,
        }
    }

    #[test]
    fn insert_and_lookup_across_buckets() {
        let (collector, slab, table) = setup();
        let table_ref = unsafe { &*table };
        let g = collector.pin();
        for i in 0..64u32 {
            let key = format!("key-{i}");
            assert!(insert_fresh(table_ref, &slab, key.as_bytes(), &i.to_le_bytes(), &g));
        }
        for i in 0..64u32 {
            let key = format!("key-{i}");
            assert_eq!(
                lookup(table_ref, key.as_bytes(), &g),
                Some(i.to_le_bytes().to_vec())
            );
        }
        assert_eq!(lookup(table_ref, b"missing", &g), None);
        drop(g);
        unsafe { drop(Box::from_raw(table)) };
    }

    #[test]
    fn duplicate_insert_is_rejected() {
        let (collector, slab, table) = setup();
        let table_ref = unsafe { &*table };
        let g = collector.pin();
        assert!(insert_fresh(table_ref, &slab, b"dup", b"1", &g));
        assert!(!insert_fresh(table_ref, &slab, b"dup", b"2", &g));
        drop(g);
        unsafe { drop(Box::from_raw(table)) };
    }

    #[test]
    fn migration_transfers_live_items() {
        let (collector, slab, table) = setup();
        let table_ref = unsafe { &*table };
        let next = Table::alloc(16);
        let next_ref = unsafe { &*next };
        let items = AtomicUsize::new(0);
        {
            let g = collector.pin();
            for i in 0..32u32 {
                let key = format!("mig-{i}");
                insert_fresh(table_ref, &slab, key.as_bytes(), &i.to_le_bytes(), &g);
            }
            table_ref.next.store(next, Ordering::Release);
            for idx in 0..table_ref.len() {
                migrate_bucket(table_ref, idx, next_ref, &slab, &items, &g);
            }
            assert!(table_ref.fully_migrated());
            for i in 0..32u32 {
                let key = format!("mig-{i}");
                assert_eq!(
                    lookup(next_ref, key.as_bytes(), &g),
                    Some(i.to_le_bytes().to_vec()),
                    "item lost in migration"
                );
            }
            // Old buckets all forward.
            for b in table_ref.buckets.iter() {
                assert_eq!(tag_of(b.load(Ordering::Relaxed)), BUCKET_FORWARD);
            }
        }
        collector.force_reclaim(4);
        unsafe {
            drop(Box::from_raw(table));
            drop(Box::from_raw(next));
        }
    }

    #[test]
    fn migration_is_idempotent_with_concurrent_helpers() {
        let (collector, slab, table) = setup();
        let table_ref = unsafe { &*table };
        let next = Table::alloc(16);
        let items = AtomicUsize::new(0);
        {
            let g = collector.pin();
            for i in 0..64u32 {
                let key = format!("cm-{i}");
                insert_fresh(table_ref, &slab, key.as_bytes(), &i.to_le_bytes(), &g);
            }
        }
        table_ref.next.store(next, Ordering::Release);
        // 4 helper threads race over every bucket.
        let table_addr = table as usize;
        let next_addr = next as usize;
        let items_ref: &'static AtomicUsize = unsafe { std::mem::transmute(&items) };
        std::thread::scope(|s| {
            for _ in 0..4 {
                let collector = Arc::clone(&collector);
                let slab = Arc::clone(&slab);
                s.spawn(move || {
                    let g = collector.pin();
                    let t = unsafe { &*(table_addr as *const Table) };
                    let n = unsafe { &*(next_addr as *const Table) };
                    for idx in 0..t.len() {
                        migrate_bucket(t, idx, n, &slab, items_ref, &g);
                    }
                });
            }
        });
        assert!(table_ref.fully_migrated());
        {
            let g = collector.pin();
            let next_ref = unsafe { &*next };
            for i in 0..64u32 {
                let key = format!("cm-{i}");
                assert_eq!(
                    lookup(next_ref, key.as_bytes(), &g),
                    Some(i.to_le_bytes().to_vec())
                );
            }
        }
        collector.force_reclaim(4);
        unsafe {
            drop(Box::from_raw(table));
            drop(Box::from_raw(next));
        }
    }
}
