//! Low-level concurrency utilities shared by every lock-free structure.
//!
//! Nothing here is FLeeC-specific: [`tagged`] packs mark/tag bits into
//! pointer-sized atomic words (the representation both the Harris list and
//! the FLeeC value-state word use), [`backoff`] is a bounded exponential
//! spin backoff for CAS retry loops, and [`rng`] provides the small fast
//! PRNGs (SplitMix64 / xoshiro256**) used by the workload generator, the
//! property-test harness and randomized probe points — the offline crate
//! set has no `rand`, so these are implemented here.

pub mod backoff;
pub mod rng;
pub mod tagged;

pub use backoff::Backoff;
pub use rng::{SplitMix64, Xoshiro256};
pub use tagged::{untagged, with_tag, tag_of, TAG_MASK};
