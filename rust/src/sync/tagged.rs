//! Tag-bit packing for pointer-sized atomic words.
//!
//! All nodes handled by the lock-free structures are allocated with at
//! least 8-byte alignment, leaving the low 3 bits of every pointer free.
//! The Harris list uses bit 0 as the *logical deletion* mark; the FLeeC
//! hash table additionally uses bits 0–1 of its *value-state* word to
//! distinguish `LIVE` / `TOMBSTONE` / `MOVED` states and bit 0 of a
//! *bucket head* word as the `FROZEN` mark during non-blocking expansion.
//!
//! Keeping the helpers free-standing (rather than a wrapper type) lets the
//! data-structure code spell out exactly which bit means what at each use
//! site, which is where lock-free bugs hide.

/// Mask covering the tag bits available in an aligned pointer.
pub const TAG_MASK: usize = 0b111;

/// Strip all tag bits, leaving the raw pointer value.
#[inline(always)]
pub fn untagged(word: usize) -> usize {
    word & !TAG_MASK
}

/// Combine a raw pointer value with a tag (must fit in [`TAG_MASK`]).
#[inline(always)]
pub fn with_tag(ptr: usize, tag: usize) -> usize {
    debug_assert_eq!(ptr & TAG_MASK, 0, "pointer not aligned for tagging");
    debug_assert_eq!(tag & !TAG_MASK, 0, "tag does not fit in the low bits");
    ptr | tag
}

/// Extract the tag bits of a packed word.
#[inline(always)]
pub fn tag_of(word: usize) -> usize {
    word & TAG_MASK
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_tag_and_pointer() {
        let fake_ptr = 0x7f00_dead_b000usize; // 8-aligned
        for tag in 0..=TAG_MASK {
            let w = with_tag(fake_ptr, tag);
            assert_eq!(untagged(w), fake_ptr);
            assert_eq!(tag_of(w), tag);
        }
    }

    #[test]
    fn untagged_of_null_is_null() {
        assert_eq!(untagged(0), 0);
        assert_eq!(tag_of(0), 0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn tagging_unaligned_pointer_panics_in_debug() {
        let _ = with_tag(0x1001, 1);
    }
}
