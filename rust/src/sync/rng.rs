//! Small, fast, reproducible PRNGs.
//!
//! The offline crate set carries `rand_core` but no generator
//! implementations, so the two standard algorithms used throughout the
//! repo live here: [`SplitMix64`] for seeding / cheap one-off streams and
//! [`Xoshiro256`] (xoshiro256\*\*) as the workhorse for the workload
//! generator and the property-test harness. Both match the reference
//! implementations by Blackman & Vigna, which the unit tests pin with
//! known-answer vectors.

/// SplitMix64 — tiny 64-bit generator, primarily used to expand a user
/// seed into xoshiro state (the construction Vigna recommends).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from an arbitrary seed (0 is fine).
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256\*\* — fast general-purpose 64-bit generator with 256-bit
/// state; passes BigCrush and is the default in several language runtimes.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference recommendation; any seed
    /// (including 0) yields a valid non-zero state.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256 {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Construct from raw state (must not be all zero).
    pub fn from_state(s: [u64; 4]) -> Self {
        assert!(s.iter().any(|&w| w != 0), "xoshiro state must be non-zero");
        Xoshiro256 { s }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform value in `[0, bound)` via Lemire's multiply-shift reduction
    /// (unbiased enough for workload generation; exactness is not needed).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Known-answer test against the reference C splitmix64 with seed 0:
    /// first outputs are e220a8397b1dcdaf, 6e789e6aa1b965f4.
    #[test]
    fn splitmix64_reference_vectors() {
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xe220_a839_7b1d_cdaf);
        assert_eq!(g.next_u64(), 0x6e78_9e6a_a1b9_65f4);
    }

    /// xoshiro256** from state {1,2,3,4}: first outputs are 11520, 0,
    /// 1509978240 (hand-derived from the reference update rule).
    #[test]
    fn xoshiro_reference_vectors() {
        let mut g = Xoshiro256::from_state([1, 2, 3, 4]);
        assert_eq!(g.next_u64(), 11520);
        assert_eq!(g.next_u64(), 0);
        assert_eq!(g.next_u64(), 1509978240);
    }

    #[test]
    fn next_below_respects_bound() {
        let mut g = Xoshiro256::seeded(42);
        for _ in 0..10_000 {
            assert!(g.next_below(7) < 7);
        }
    }

    #[test]
    fn next_f64_in_unit_interval_and_spread() {
        let mut g = Xoshiro256::seeded(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn seeded_streams_are_deterministic() {
        let a: Vec<u64> = {
            let mut g = Xoshiro256::seeded(123);
            (0..16).map(|_| g.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut g = Xoshiro256::seeded(123);
            (0..16).map(|_| g.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
