//! Bounded exponential backoff for CAS retry loops.
//!
//! On the single-core CI host a failed CAS means another thread holds the
//! cache line *and* the core, so yielding early matters more than spinning;
//! the backoff therefore escalates from `spin_loop` hints to
//! `thread::yield_now` after a few rounds. The thresholds follow
//! crossbeam's well-tested constants.

use std::hint;
use std::thread;

const SPIN_LIMIT: u32 = 6;
const YIELD_LIMIT: u32 = 10;

/// Exponential backoff helper. Create one per retry loop.
#[derive(Debug, Default)]
pub struct Backoff {
    step: u32,
}

impl Backoff {
    /// A fresh backoff with zero accumulated delay.
    #[inline]
    pub fn new() -> Self {
        Backoff { step: 0 }
    }

    /// Back off after a failed CAS: spin with increasing intensity, then
    /// start yielding the core once contention looks persistent.
    #[inline]
    pub fn spin(&mut self) {
        if self.step <= SPIN_LIMIT {
            for _ in 0..(1u32 << self.step) {
                hint::spin_loop();
            }
        } else {
            thread::yield_now();
        }
        if self.step <= YIELD_LIMIT {
            self.step += 1;
        }
    }

    /// Whether the loop has been contended long enough that callers doing
    /// optional work (e.g. helping expansion) should just do it.
    #[inline]
    pub fn is_completed(&self) -> bool {
        self.step > YIELD_LIMIT
    }

    /// Reset after a successful step so unrelated retries start cheap.
    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalates_and_reports_completion() {
        let mut b = Backoff::new();
        assert!(!b.is_completed());
        for _ in 0..=YIELD_LIMIT {
            b.spin();
        }
        assert!(b.is_completed());
        b.reset();
        assert!(!b.is_completed());
    }
}
