//! `fleec-audit` — an in-repo static analyzer for lock-free discipline.
//!
//! FLeeC's correctness story rests on hand-maintained invariants: every
//! `unsafe` site has a safety argument, every release-side memory
//! ordering names the acquire it pairs with (the map lives in
//! `docs/concurrency.md`), and every API that lends guard-scoped memory
//! restates the byte-stability contract of the zero-copy read path.
//! This module makes those conventions machine-checked: a dependency-free
//! analyzer (small line-aware lexer + comment-adjacency rules) that walks
//! `rust/src/**` and reports violations as both human diagnostics and a
//! JSON report.
//!
//! Three entry points:
//! * [`audit_source`] — rules over one in-memory file (unit-test
//!   fixtures, editor integrations);
//! * [`audit_tree`] — walk a source root and audit every `.rs` file;
//! * the `fleec-audit` binary (`src/bin/fleec-audit.rs`) — CLI wrapper
//!   used by CI (`--deny-warnings --json …`).
//!
//! The test gate `tests/audit.rs` runs [`audit_tree`] over this crate's
//! own `src/` and fails on any unwaived finding, so `cargo test -q`
//! enforces the discipline without any extra CI plumbing.

pub mod lexer;
pub mod rules;

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{audit_source, Finding, Rule, Severity};

/// The result of auditing a tree: every finding plus walk statistics.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub lines_scanned: usize,
}

impl Report {
    pub fn errors(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Error)
            .count()
    }

    pub fn warnings(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Warning)
            .count()
    }

    /// Human-readable diagnostics, one `file:line: severity[rule] msg`
    /// per finding, followed by a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let _ = writeln!(
                out,
                "{}:{}: {}[{}] {}",
                f.file,
                f.line,
                f.severity.label(),
                f.rule.key(),
                f.message
            );
        }
        let _ = writeln!(
            out,
            "fleec-audit: {} error(s), {} warning(s) across {} file(s) / {} line(s)",
            self.errors(),
            self.warnings(),
            self.files_scanned,
            self.lines_scanned
        );
        out
    }

    /// Serialize as JSON (hand-rolled — the crate is dependency-free).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        let _ = writeln!(out, "  \"files_scanned\": {},", self.files_scanned);
        let _ = writeln!(out, "  \"lines_scanned\": {},", self.lines_scanned);
        let _ = writeln!(out, "  \"errors\": {},", self.errors());
        let _ = writeln!(out, "  \"warnings\": {},", self.warnings());
        out.push_str("  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\n    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"severity\": {}, \
                 \"message\": {}}}",
                json_str(&f.file),
                f.line,
                json_str(f.rule.key()),
                json_str(f.severity.label()),
                json_str(&f.message)
            );
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Recursively collect `.rs` files under `root`, sorted for determinism.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(root)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Audit every `.rs` file under `root` (typically the crate's `src/`).
pub fn audit_tree(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut report = Report::default();
    for path in files {
        let src = fs::read_to_string(&path)?;
        report.files_scanned += 1;
        report.lines_scanned += src.lines().count();
        let label = path.to_string_lossy();
        report.findings.extend(audit_source(&label, &src));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_escapes_and_counts() {
        let mut r = Report::default();
        r.files_scanned = 1;
        r.lines_scanned = 2;
        r.findings = audit_source("src/ebr/x.rs", "unsafe fn f(s: &str) {} // has \"quote\n");
        assert_eq!(r.errors(), 1);
        let j = r.to_json();
        assert!(j.contains("\"errors\": 1"));
        assert!(j.contains("\"rule\": \"safety\""));
        // Valid JSON shape: balanced braces/brackets at least.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn render_has_summary_line() {
        let r = Report::default();
        assert!(r.render().contains("0 error(s), 0 warning(s)"));
    }
}
