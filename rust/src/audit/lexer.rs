//! A small line-aware Rust lexer for [`fleec-audit`](crate::audit).
//!
//! The audit rules are *comment-adjacency* rules ("this line of code must
//! carry that tag"), so the lexer does not build a token tree — it splits
//! every source line into a **code channel** and a **comment channel**:
//!
//! * `code` — the line's source text with comments removed and the
//!   *contents* of string/char literals blanked out (quotes kept). Token
//!   scans over this channel can never be fooled by `"unsafe"` inside a
//!   string or `// Ordering::Release` inside a comment.
//! * `comment` — the concatenated text of every comment overlapping the
//!   line (line comments, doc comments, block comments — including the
//!   interior lines of a multi-line `/* … */`).
//!
//! Handled Rust surface: nested block comments, string literals with
//! escapes, raw strings (`r"…"`, `r#"…"#`, any hash depth), byte
//! strings/chars, char literals (including escapes), and the char-vs-
//! lifetime ambiguity of `'` (`'a'` is a literal, `<'a>` is not).
//!
//! The lexer is intentionally *forgiving*: on malformed input it degrades
//! to treating the rest of the file as code, which at worst produces an
//! extra finding — never a silently skipped one.

/// One source line, split into its code and comment channels.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Source text minus comments, with literal contents blanked.
    pub code: String,
    /// Concatenated comment text overlapping this line.
    pub comment: String,
}

impl Line {
    /// True when the line carries no code (blank, or comment-only).
    pub fn is_code_blank(&self) -> bool {
        self.code.trim().is_empty()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Code,
    LineComment,
    /// Nested depth of `/* … */`.
    BlockComment(u32),
    /// Inside `"…"` (escape-aware).
    Str,
    /// Inside `r##"…"##` with the given hash count.
    RawStr(u32),
    /// Inside `'…'` (escape-aware).
    CharLit,
}

/// Split `src` into per-line code/comment channels.
pub fn lex(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<Line> = vec![Line::default()];
    let mut mode = Mode::Code;
    let mut i = 0usize;

    // Push helpers operate on the last (current) line.
    macro_rules! code {
        ($c:expr) => {
            lines.last_mut().unwrap().code.push($c)
        };
    }
    macro_rules! comment {
        ($c:expr) => {
            lines.last_mut().unwrap().comment.push($c)
        };
    }

    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            lines.push(Line::default());
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied();
                match c {
                    '/' if next == Some('/') => {
                        mode = Mode::LineComment;
                        comment!('/');
                        comment!('/');
                        i += 2;
                    }
                    '/' if next == Some('*') => {
                        mode = Mode::BlockComment(1);
                        comment!('/');
                        comment!('*');
                        i += 2;
                    }
                    '"' => {
                        mode = Mode::Str;
                        code!('"');
                        i += 1;
                    }
                    'r' | 'b' if !prev_is_ident(&lines) => {
                        // Possible raw/byte literal prefix: r"…", r#"…"#,
                        // b"…", br#"…"#, b'…'.
                        if let Some((consumed, m)) = match_literal_prefix(&chars, i) {
                            for _ in 0..consumed {
                                code!(chars[i]); // prefix chars + opening quote(s)
                                i += 1;
                            }
                            mode = m;
                        } else {
                            code!(c);
                            i += 1;
                        }
                    }
                    '\'' => {
                        // Char literal vs lifetime. A literal is '<esc>' or
                        // 'x' (any single char followed by a closing quote);
                        // everything else ('a in generics, '_ etc.) is a
                        // lifetime and stays in the code channel.
                        let is_char_lit = match next {
                            Some('\\') => true,
                            Some(_) => chars.get(i + 2) == Some(&'\''),
                            None => false,
                        };
                        code!('\'');
                        i += 1;
                        if is_char_lit {
                            mode = Mode::CharLit;
                        }
                    }
                    _ => {
                        code!(c);
                        i += 1;
                    }
                }
            }
            Mode::LineComment => {
                comment!(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(depth + 1);
                    comment!('/');
                    comment!('*');
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    comment!('*');
                    comment!('/');
                    i += 2;
                    mode = if depth > 1 {
                        Mode::BlockComment(depth - 1)
                    } else {
                        Mode::Code
                    };
                } else {
                    comment!(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // Skip the escaped char — unless it is a newline
                    // (line-continuation), which must still split lines.
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    code!('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1; // blank out content
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && raw_str_closes(&chars, i, hashes) {
                    code!('"');
                    for _ in 0..hashes {
                        code!('#');
                    }
                    i += 1 + hashes as usize;
                    mode = Mode::Code;
                } else {
                    i += 1; // blank out content
                }
            }
            Mode::CharLit => {
                if c == '\\' {
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '\'' {
                    code!('\'');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines
}

/// Whether the last emitted code char continues an identifier — used to
/// tell the literal prefix `r` in `r"…"` from the trailing `r` of `for`.
fn prev_is_ident(lines: &[Line]) -> bool {
    lines
        .last()
        .and_then(|l| l.code.chars().last())
        .is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// At `chars[i]` (an `r` or `b` not continuing an identifier), detect a
/// raw/byte literal opener. Returns `(chars_to_consume, next_mode)` where
/// the consumed span covers the prefix and the opening quote(s).
fn match_literal_prefix(chars: &[char], i: usize) -> Option<(usize, Mode)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    let raw = chars.get(j) == Some(&'r');
    if raw {
        j += 1;
        let mut hashes = 0u32;
        while chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if chars.get(j) == Some(&'"') {
            return Some((j - i + 1, Mode::RawStr(hashes)));
        }
        return None; // e.g. `r#ident` raw identifier — leave as code
    }
    match chars.get(j) {
        Some('"') => Some((j - i + 1, Mode::Str)),
        Some('\'') => Some((j - i + 1, Mode::CharLit)),
        _ => None,
    }
}

/// At a `"` inside a raw string with `hashes` hashes, check the closer.
fn raw_str_closes(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn splits_code_and_line_comment() {
        let lines = lex("let x = 1; // SAFETY: fine\n");
        assert_eq!(lines[0].code, "let x = 1; ");
        assert!(lines[0].comment.contains("SAFETY: fine"));
    }

    #[test]
    fn string_contents_are_blanked() {
        let lines = lex("let s = \"unsafe Ordering::Release // ord:\";");
        assert_eq!(lines[0].code, "let s = \"\";");
        assert!(lines[0].comment.is_empty());
    }

    #[test]
    fn raw_string_with_hashes() {
        let lines = lex("let s = r#\"has \"quotes\" and unsafe\"#; let y = 2;");
        assert_eq!(lines[0].code, "let s = r#\"\"#; let y = 2;");
    }

    #[test]
    fn multiline_string_blanks_interior_lines() {
        let c = code_of("let s = \"line one\nunsafe line two\";\nlet z = 3;");
        assert_eq!(c[0], "let s = \"");
        assert_eq!(c[1], "\";");
        assert_eq!(c[2], "let z = 3;");
    }

    #[test]
    fn nested_block_comment() {
        let lines = lex("a /* outer /* inner */ still */ b");
        assert_eq!(lines[0].code.replace(' ', ""), "ab");
        assert!(lines[0].comment.contains("inner"));
    }

    #[test]
    fn multiline_block_comment_marks_every_line() {
        let lines = lex("code(); /* SAFETY:\nspans lines */ tail();");
        assert!(lines[0].comment.contains("SAFETY:"));
        assert!(lines[1].comment.contains("spans lines"));
        assert_eq!(lines[1].code.trim(), "tail();");
    }

    #[test]
    fn lifetime_is_not_a_char_literal() {
        let lines = lex("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(lines[0].code.contains("&'a str"));
    }

    #[test]
    fn char_literal_contents_blanked() {
        let lines = lex("let c = 'u'; let esc = '\\n'; let q = '\"';");
        // The quote inside the char literal must not open a string.
        assert!(lines[0].code.contains("let esc"));
        assert!(lines[0].code.contains("let q"));
        assert!(!lines[0].code.contains('u'));
    }

    #[test]
    fn byte_string_and_byte_char() {
        let lines = lex("let b = b\"unsafe\"; let c = b'x'; for_ = 1;");
        assert_eq!(lines[0].code, "let b = b\"\"; let c = b''; for_ = 1;");
    }

    #[test]
    fn ident_ending_in_r_does_not_open_raw_string() {
        // `for` ends in `r`; the following `"` is a plain string.
        let lines = lex("for x in bar\"s\" {}");
        assert_eq!(lines[0].code, "for x in bar\"\" {}");
    }

    #[test]
    fn doc_comments_land_in_comment_channel() {
        let lines = lex("/// # Safety\n/// callers must hold the guard\nunsafe fn f() {}");
        assert!(lines[0].comment.contains("# Safety"));
        assert!(lines[1].comment.contains("guard"));
        assert!(lines[2].code.contains("unsafe fn"));
    }
}
